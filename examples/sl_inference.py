"""SL-based task inference (paper Fig 5): the model's tunable stack is split
across a chain of 4 "clients" (devices), activations hop via D2D
(collective_permute), the end point's result returns to the start point.

Uses 4 virtual host devices — the XLA flag below must precede jax import.

  python examples/sl_inference.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import dataclasses
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, "src")

from repro.configs.base import get_config
from repro.core.comm import CostModel, sl_round_cost
from repro.core.sl_pipeline import (pipeline_classify, simulate_sl,
                                    split_for_stages)
from repro.data.synthetic import ClassificationTask
from repro.models import model as M

N_STAGES = 4

cfg = get_config("vit-edge").reduced().with_(n_layers=4, dtype="float32")
cfg = cfg.with_(peft=dataclasses.replace(cfg.peft, head_dim_out=5))
params = M.init(cfg, jax.random.PRNGKey(0))
task = ClassificationTask(5, cfg.vocab_size, 32, seed=0)

mesh = jax.make_mesh((N_STAGES,), ("stage",))
stages = split_for_stages(params, cfg, N_STAGES)
print(f"[sl] split {cfg.n_layers} layers across {N_STAGES} clients "
      f"({cfg.n_layers // N_STAGES} layers each)")

# batched inference requests from the start point (jitted once, reused)
infer = jax.jit(lambda p, st, toks: pipeline_classify(
    p, st, toks, cfg, mesh, n_microbatches=4))
for req in range(3):
    batch = task.dataset(16, seed=req)
    t0 = time.time()
    logits = jax.block_until_ready(
        infer(params, stages, jnp.asarray(batch["tokens"])))
    dt = time.time() - t0
    acc = float(np.mean(np.argmax(np.asarray(logits), -1) == batch["label"]))
    print(f"[sl] request {req}: 16 samples in {dt:.2f}s, acc={acc:.2f} "
          f"(untuned adapters — see hfsl_finetune.py)")

# verify against the monolithic model
mono = M.classify(params, {"tokens": jnp.asarray(batch["tokens"])}, cfg)
err = float(np.abs(np.asarray(mono) - np.asarray(logits)).max())
print(f"[sl] pipelined == monolithic: max err {err:.2e}")

# the paper's §III-D.2 metrics for this round, priced on the wireless model
trace = simulate_sl(cfg, batch=16, seq=32, n_clients=N_STAGES, training=False)
cost = sl_round_cost(trace, CostModel(),
                     model_delivery_bytes=0)   # adapters pre-delivered
print(f"[sl] per-request metrics (6G wireless pricing): "
      f"latency={cost.latency_s*1e3:.1f}ms comm={cost.comm_bytes/1e3:.0f}KB "
      f"energy={cost.energy_j:.3f}J mem={cost.memory_bytes/1e3:.0f}KB")
