"""Integrated fine-tuning-and-inference runtime demo (paper §IV + §V-F,
executed against REAL models instead of the paper's constant profits).

Two domain edge models share one frozen FM. A demand stream arrives; the
MLCP policy decides per round whether to serve (profit = measured accuracy)
or fine-tune (pay the upgrade cost, raise future accuracy).

  PYTHONPATH=src python examples/integrated_runtime.py
"""
import dataclasses
import sys

sys.path.insert(0, "src")

from repro.configs.base import get_config
from repro.core.integrated import IntegratedRuntime
from repro.core.scheduler import msip_policy, SchedulerEnv
from repro.data.synthetic import ClassificationTask

cfg = get_config("vit-edge").reduced().with_(dtype="float32", vocab_size=64)
cfg = cfg.with_(peft=dataclasses.replace(cfg.peft, head_dim_out=5))
tasks = {
    "nlp": ClassificationTask(5, 64, 48, class_strength=0.6, seed=0),
    "cv": ClassificationTask(5, 64, 48, class_strength=0.6, seed=7),
}
demand = ["nlp"] * 2 + ["cv"] + ["nlp"] * 7          # nlp-heavy stream

print("== MLCP (proposed): may sacrifice early rounds to fine-tune ==")
rt = IntegratedRuntime(cfg, tasks, n_clusters=2, steps_per_upgrade=60,
                       serve_batch=32, upgrade_cost=30.0, seed=0)
print(f"   cold-start accuracy: "
      f"{ {n: round(d.accuracy, 2) for n, d in rt.domains.items()} }")
for r in rt.run(demand):
    rate = (f"ex/s {r.cost.ex_per_s:7.1f}" if r.action == "upgrade"
            else f"tok/s {r.cost.tok_per_s:6.1f}")
    print(f"   round {r.round:2d}: {r.action:8s} {r.domain:4s} "
          f"profit {r.profit:+7.1f}  acc {r.accuracy:.2f}  {rate}  "
          f"cum {r.cumulative:8.1f}")
print(f"   MLCP total: {rt.total_profit():.1f}")

print("\n== MSIP (greedy): never fine-tunes ==")
rt2 = IntegratedRuntime(cfg, tasks, n_clusters=2, steps_per_upgrade=60,
                        serve_batch=32, upgrade_cost=30.0, seed=0)
greedy = msip_policy(SchedulerEnv(demand=tuple(0 for _ in demand),
                                  n_devices=2))
rt2.run(demand, policy=greedy)
print(f"   MSIP total: {rt2.total_profit():.1f}")

win = rt.total_profit() - rt2.total_profit()
print(f"\n== integrated fine-tuning+inference gain: {win:+.1f} "
      f"({'MLCP pays off' if win > 0 else 'greedy wins this stream'}) ==")
print("   (unlike the paper's constant-profit Table V, profits here come from")
print("    MEASURED accuracy — MLCP's edge depends on the real gain curve of")
print("    fine-tuning, which the DP's value model must estimate)")
