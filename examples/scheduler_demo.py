"""Integrated fine-tuning-or-inference scheduling demo (paper §IV-C, §V-F).

Reproduces Table V / Fig 8, then goes beyond the paper: stochastic demand
handled by value iteration, and a sweep of upgrade costs showing when
fine-tuning stops paying for itself.

  PYTHONPATH=src python examples/scheduler_demo.py
"""
import sys

import numpy as np

sys.path.insert(0, "src")

from repro.core.scheduler import (SchedulerEnv, mlcp_policy,
                                  mlcp_value_iteration, msip_policy,
                                  paper_env, rs_policy, run_policy,
                                  total_profit)

env = paper_env()
print("== paper Table V (demand: A A B C C C C C C C) ==")
for name, pol in [("MLCP (proposed)", mlcp_policy(env)),
                  ("MSIP", msip_policy(env)), ("RS", rs_policy(env, 3))]:
    rec = run_policy(env, pol)
    trace = " ".join(
        (f"{'abc'[r.device]}/{r.profit}" if r.action == "upgrade"
         else f"{'ABC'[r.device]}/{r.profit}") for r in rec)
    print(f"  {name:16s} total={total_profit(rec):5d}  {trace}")

print("\n== cumulative profit per round (Fig 8) ==")
recs = {n: run_policy(env, p) for n, p in
        [("MLCP", mlcp_policy(env)), ("MSIP", msip_policy(env)),
         ("RS", rs_policy(env, 3))]}
print("  round: " + " ".join(f"{i+1:5d}" for i in range(env.horizon)))
for n, rec in recs.items():
    print(f"  {n:5s}: " + " ".join(f"{r.cumulative:5d}" for r in rec))

print("\n== beyond paper: stochastic demand (value iteration) ==")
rng = np.random.default_rng(0)
for probs in ([0.2, 0.1, 0.7], [0.34, 0.33, 0.33]):
    vi = mlcp_value_iteration(env, probs)
    totals = []
    for trial in range(200):
        demand = tuple(rng.choice(3, size=10, p=probs).tolist())
        e = SchedulerEnv(demand=demand)
        totals.append(total_profit(run_policy(e, vi)))
        oracle = total_profit(run_policy(e, mlcp_policy(e)))
    print(f"  p={probs}: VI mean profit {np.mean(totals):.0f} "
          f"(oracle DP on last draw: {oracle})")

print("\n== beyond paper: when does fine-tuning pay? (upgrade-cost sweep) ==")
for cost in (25, 50, 100, 200, 400):
    e = SchedulerEnv(demand=env.demand, upgrade_cost=cost)
    m = total_profit(run_policy(e, mlcp_policy(e)))
    g = total_profit(run_policy(e, msip_policy(e)))
    n_up = sum(r.action == "upgrade"
               for r in run_policy(e, mlcp_policy(e)))
    print(f"  upgrade_cost={cost:3d}: MLCP={m:5d} (upgrades={n_up}) "
          f"vs MSIP={g}  -> fine-tuning {'pays' if m > g else 'does not pay'}")
