"""Quickstart: the GaisNet loop in ~60 lines.

1. pretrain a tiny FM on the cloud corpus (LM task),
2. PEFT fine-tune it with HFSL across 4 client clusters (classification),
3. distribute only the adapters (parameter-efficient inference) and serve.

Runs on CPU in ~2 minutes:  PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses
import sys

import jax
import jax.numpy as jnp

sys.path.insert(0, "src")

from repro.configs.base import get_config
from repro.core import hfsl
from repro.core.peft import trainable_fraction, tree_bytes
from repro.data.noniid import partition_by_classes
from repro.data.pipeline import cluster_batches
from repro.data.synthetic import ClassificationTask
from repro.models import model as M
from repro.optim.optimizers import adamw
from repro.optim.optimizers import apply_updates
from repro.core.peft import peft_value_and_grad

# 1. the edge foundation model (the paper's ViT-B/16 case study, tiny here)
# vocab 64 keeps per-sample token statistics dense enough to classify
cfg = get_config("vit-edge").reduced().with_(dtype="float32", vocab_size=64)
cfg = cfg.with_(peft=dataclasses.replace(cfg.peft, head_dim_out=5))
task = ClassificationTask(5, cfg.vocab_size, 64, class_strength=0.6, seed=0)

print("== pretraining (cloud tier: unlabeled corpus) ==")
params = M.init(cfg, jax.random.PRNGKey(0))
opt = adamw(3e-3)
vg = peft_value_and_grad(M.lm_loss, trainable="all")
opt_state = opt.init(params)
@jax.jit
def step(p, s, b):
    (loss, _), grads = vg(p, b, cfg)
    updates, s = opt.update(grads, s, p)
    return apply_updates(p, updates), s, loss
stream = task.pretrain_stream(16)
for i in range(250):
    params, opt_state, loss = step(params, opt_state, next(stream))
print(f"   pretrain loss: {float(loss):.3f}")

print("== HFSL fine-tuning (edge-end tier: 4 client clusters) ==")
print(f"   trainable fraction: {trainable_fraction(params):.3%} "
      f"(paper: 'less than 1%')")
data = task.dataset(400)
parts = partition_by_classes(data["label"], 4, classes_per_client=5)
it = cluster_batches(data, parts, batch_size=8)
fopt = adamw(5e-3)
state = hfsl.init_hfsl_state(jax.random.PRNGKey(1), cfg, 4, fopt,
                             lambda c, k: params)
hstep = jax.jit(hfsl.make_hfsl_step(cfg, fopt, M.classify_loss, sync_every=5))
for i in range(100):
    state, metrics = hstep(state, next(it))
    if (i + 1) % 20 == 0:
        print(f"   step {i+1}: loss {float(metrics['loss']):.3f} "
              f"(fedavg moves {hfsl.sync_bytes(state['adapters_c'])} B/sync)")

print("== parameter-efficient serving (end tier) ==")
tuned = hfsl.consensus_params(state)
print(f"   distributing adapters only: {tree_bytes(tuned['adapters'])} B "
      f"vs full model {tree_bytes(tuned)} B")
test = task.dataset(100, seed=9)
logits = M.classify(tuned, {k: jnp.asarray(v) for k, v in test.items()}, cfg)
acc = float(jnp.mean((jnp.argmax(logits, -1) == test["label"])))
print(f"   served accuracy on 100 fresh samples: {acc:.1%}")
