"""End-to-end driver: HFSL fine-tuning of a ~100M-parameter model for a few
hundred steps on CPU (deliverable b's end-to-end run).

The model is the paper's own case-study backbone at FULL size (vit-edge:
12L x 768d x 12H ~= 110M params). The backbone stays frozen (PEFT), so the
run is tractable on one CPU: forward+adapter-backward over 110M params.

  PYTHONPATH=src python examples/hfsl_finetune.py [--steps 200]
"""
import argparse
import dataclasses
import sys
import time

import jax
import jax.numpy as jnp

sys.path.insert(0, "src")

from repro.checkpoint import io as ckpt
from repro.configs.base import get_config
from repro.core import hfsl
from repro.core.peft import count_params, trainable_fraction, tree_bytes
from repro.core.relay import KnowledgeRelay
from repro.data.noniid import partition_by_classes
from repro.data.pipeline import cluster_batches
from repro.data.synthetic import ClassificationTask
from repro.models import model as M
from repro.optim.optimizers import adamw
from repro.optim.schedules import warmup_cosine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--clusters", type=int, default=4)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--sync-every", type=int, default=5)
    ap.add_argument("--ckpt", default="/tmp/gaisnet_adapters")
    args = ap.parse_args()

    # full ~110M-param backbone; vocab 64 so the synthetic task is
    # separable from pooled features (see benchmarks/common.py)
    cfg = get_config("vit-edge").with_(dtype="float32", vocab_size=64)
    cfg = cfg.with_(peft=dataclasses.replace(cfg.peft, head_dim_out=5))
    print(f"[hfsl] model: {cfg.name}, {cfg.param_count()/1e6:.0f}M backbone params")

    key = jax.random.PRNGKey(0)
    params = M.init(cfg, key)
    print(f"[hfsl] trainable fraction: {trainable_fraction(params):.4%} "
          f"({count_params(params['adapters'])/1e6:.2f}M adapter params)")

    task = ClassificationTask(5, cfg.vocab_size, args.seq,
                              class_strength=0.7, seed=0)
    data = task.dataset(200 * args.clusters)
    parts = partition_by_classes(data["label"], args.clusters, 5)
    it = cluster_batches(data, parts, args.batch)

    opt = adamw(warmup_cosine(5e-3, 20, args.steps))
    state = hfsl.init_hfsl_state(key, cfg, args.clusters, opt,
                                 lambda c, k: params)
    step = jax.jit(hfsl.make_hfsl_step(cfg, opt, M.classify_loss,
                                       sync_every=args.sync_every))

    # the edge server mediating the knowledge flow (paper Fig 3)
    relay = KnowledgeRelay(params["adapters"], ["case-study-domain"])
    relay.edge_deliver("case-study-domain", args.clusters)

    t0 = time.time()
    for i in range(args.steps):
        state, metrics = step(state, next(it))
        if (i + 1) % 20 == 0 or i == 0:
            dt = (time.time() - t0) / (i + 1)
            print(f"[hfsl] step {i+1:4d}/{args.steps} "
                  f"loss={float(metrics['loss']):.4f} ({dt:.2f}s/step)")

    tuned = hfsl.consensus_params(state)
    relay.edge_absorb("case-study-domain",
                      [jax.tree.map(lambda x: x[c], state["adapters_c"])
                       for c in range(args.clusters)])
    relay.cloud_aggregate()
    print(f"[hfsl] relay ledger: {dataclasses.asdict(relay.ledger)}")
    print(f"[hfsl] knowledge-flow cost: latency={relay.cost.latency_s:.2f}s "
          f"energy={relay.cost.energy_j:.1f}J comm={relay.cost.comm_bytes/1e6:.1f}MB")

    # eval + parameter-efficient checkpoint
    test = task.dataset(200, seed=7)
    logits = M.classify(tuned, {k: jnp.asarray(v) for k, v in test.items()}, cfg)
    acc = float(jnp.mean((jnp.argmax(logits, -1) == test["label"])))
    nb = ckpt.save_adapters(args.ckpt, tuned)
    print(f"[hfsl] final accuracy: {acc:.1%}; adapter ckpt {nb/1e6:.2f}MB "
          f"(full model would be {tree_bytes(tuned)/1e6:.0f}MB) -> {args.ckpt}")


if __name__ == "__main__":
    main()
