"""Split-learning serial pipeline (paper §III-C/III-D, Figs 4-5) — faithful form.

The paper splits the edge model's tunable stack across an intra-cluster
chain of clients; activations ("smashed data") hop client-to-client over
D2D links, gradients hop back. On TPU the chain is a 1-D `stage` mesh axis:

- each stage holds a contiguous slice of layers (client ≡ device),
- each D2D hop is one `jax.lax.ppermute` (GPipe-style microbatch schedule,
  bubble = S-1 steps),
- the paper's "feedback of inference results to the start point" is the
  final psum that replicates the end-point logits,
- SL *fine-tuning* is simply `jax.grad` through the pipelined forward: the
  transpose of ppermute sends gradients backwards hop-by-hop, which is
  exactly the paper's reverse smashed-data flow.

This module is the fidelity path, validated on small host-device meshes
(tests/test_sl_pipeline.py); the 512-chip production path replaces the
serial chain with tensor parallelism (DESIGN.md §2). A device-free
simulator with byte/latency accounting backs the paper-metric benchmarks.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.configs.base import ModelConfig
from repro.models.layers import embed, rmsnorm
from repro.models.transformer import _apply_seq
from repro.sharding.rules import ParamSpec, init_from_spec
from repro.models import model as model_lib


# ---------------------------------------------------------------------------
# Stage-sharded parameters
# ---------------------------------------------------------------------------

def split_for_stages(params: dict, cfg: ModelConfig, n_stages: int) -> dict:
    """Reshape the single scan group (L, ...) -> (S, L/S, ...) per leaf.

    Only single-group families (dense/vlm/moe/ssm) are supported in the
    faithful pipeline — matching the paper's homogeneous client chain.
    """
    layers = params["backbone"]["layers"]
    if set(layers) != {"g0"}:
        raise ValueError(
            f"pipeline supports single-group stacks, got groups "
            f"{sorted(layers)}")

    def resh(x):
        L = x.shape[0]
        if L % n_stages != 0:
            raise ValueError(
                f"layer count {L} not divisible by n_stages={n_stages}")
        return x.reshape(n_stages, L // n_stages, *x.shape[1:])

    stage_layers = jax.tree.map(resh, layers["g0"])
    stage_adapters = jax.tree.map(resh, params["adapters"]["stack"].get("g0", {}))
    return {"layers": stage_layers, "adapters": stage_adapters}


def pipeline_classify(params: dict, stage_tree: dict, tokens: jax.Array,
                      cfg: ModelConfig, mesh: Mesh, *,
                      n_microbatches: int = 4) -> jax.Array:
    """SL forward: tokens (B, S) -> class logits (B, n_out), pipelined.

    `params` supplies embed/final_norm/head (start & end point modules);
    `stage_tree` the stage-split layer stack (from split_for_stages).
    """
    S = mesh.shape["stage"]
    B = tokens.shape[0]
    M = n_microbatches
    if B % M != 0:
        raise ValueError(
            f"batch size {B} not divisible by n_microbatches={M}")
    mb = B // M
    kinds = ("moe",) if cfg.family == "moe" else (
        ("ssm",) if cfg.family == "ssm" else ("attn",))

    emb_tbl = params["backbone"]["embed"]
    fnorm = params["backbone"]["final_norm"]
    head = params["adapters"]["head"]
    toks_mb = tokens.reshape(M, mb, -1)
    positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)

    def stage_fn(layers, adapters, toks):
        # local slices: layers leaves (1, L/S, ...), toks replicated
        sid = jax.lax.axis_index("stage")
        layers = jax.tree.map(lambda x: x[0], layers)
        adapters = jax.tree.map(lambda x: x[0], adapters)
        d = cfg.d_model
        buf = jnp.zeros((mb, toks.shape[-1], d), jnp.dtype(cfg.dtype))
        outs = []

        def run_local(x):
            def body(x, layer):
                lp, la = layer
                for i, k in enumerate(kinds):
                    x, _, _ = _apply_seq(k, lp[f"s{i}"], la.get(f"s{i}", {}),
                                         x, cfg, positions=positions,
                                         make_cache=False)
                return x, None
            x, _ = jax.lax.scan(body, x, (layers, adapters))
            return x

        for t in range(M + S - 1):
            # start point: embed microbatch t (senses data, extracts features)
            if t < M:
                x0 = embed(emb_tbl, toks[t])
            else:
                x0 = jnp.zeros((mb, toks.shape[-1], d), jnp.dtype(cfg.dtype))
            x_in = jnp.where(sid == 0, x0, buf)
            y = run_local(x_in)
            # end point: head over the finished microbatch
            if t >= S - 1:
                pooled = jnp.mean(rmsnorm(fnorm, y).astype(jnp.float32), axis=1)
                logits = pooled @ head["w"] + head["b"]
                outs.append(jnp.where(sid == S - 1, logits, 0.0))
            # D2D hop: stage s -> s+1 (smashed data)
            buf = jax.lax.ppermute(y, "stage",
                                   [(i, (i + 1) % S) for i in range(S)])
        out = jnp.stack(outs)                              # (M, mb, n_out)
        # feedback to start point (paper: end point returns the result):
        # psum replicates — only the end stage holds nonzero logits.
        return jax.lax.psum(out, "stage")

    fn = shard_map(
        stage_fn, mesh=mesh,
        in_specs=(P("stage"), P("stage"), P()),
        out_specs=P(),
        check_rep=False,
    )
    out = fn(stage_tree["layers"], stage_tree["adapters"], toks_mb)
    return out.reshape(B, -1)


def make_sl_finetune_step(params: dict, cfg: ModelConfig, mesh: Mesh,
                          optimizer, *, n_microbatches: int = 4,
                          lr_trainables: str = "adapters"):
    """SL fine-tuning: grad flows backwards through the ppermute chain."""
    from repro.models.layers import cross_entropy

    def loss_fn(stage_adapters, head, stage_layers, batch):
        st = {"layers": stage_layers, "adapters": stage_adapters}
        p = {"backbone": params["backbone"],
             "adapters": {**params["adapters"], "head": head}}
        logits = pipeline_classify(p, st, batch["tokens"], cfg, mesh,
                                   n_microbatches=n_microbatches)
        return cross_entropy(logits, batch["label"])

    def step(stage_tree, head, opt_state, batch):
        (loss), grads = jax.value_and_grad(loss_fn, argnums=(0, 1))(
            stage_tree["adapters"], head, stage_tree["layers"], batch)
        g_ad, g_head = grads
        updates, opt_state = optimizer.update(
            {"a": g_ad, "h": g_head}, opt_state,
            {"a": stage_tree["adapters"], "h": head})
        from repro.optim.optimizers import apply_updates
        new = apply_updates({"a": stage_tree["adapters"], "h": head}, updates)
        return {**stage_tree, "adapters": new["a"]}, new["h"], opt_state, loss

    return step


# ---------------------------------------------------------------------------
# Device-free SL simulator (paper metrics: §III-C.2 / §III-D.2)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SLTrace:
    """Per-round accounting of one SL pass over a client chain."""
    hops: int
    smashed_bytes: int          # total D2D activation traffic (fwd)
    gradient_bytes: int         # reverse traffic (0 for inference)
    feedback_bytes: int         # end->start result feedback
    per_client_flops: list[int]
    peak_activation_bytes: int


def simulate_sl(cfg: ModelConfig, batch: int, seq: int, n_clients: int, *,
                training: bool) -> SLTrace:
    """Analytic trace of the paper's serial workflow for the cost model."""
    d = cfg.d_model
    act = batch * seq * d * jnp.dtype(cfg.dtype).itemsize
    hops = n_clients - 1
    layer_flops = 2 * batch * seq * (
        4 * d * d + 2 * d * cfg.d_ff) if cfg.d_ff else 2 * batch * seq * 4 * d * d
    per_layer = [layer_flops] * cfg.n_layers
    per_client = [int(sum(per_layer[i::n_clients]))
                  for i in range(n_clients)]  # round-robin layer split
    mult = 3 if training else 1              # fwd + bwd ~ 2x fwd
    n_out = max(cfg.peft.head_dim_out, 1)
    return SLTrace(
        hops=hops,
        smashed_bytes=int(act) * hops,
        gradient_bytes=int(act) * hops if training else 0,
        feedback_bytes=batch * n_out * 4,
        per_client_flops=[c * mult for c in per_client],
        peak_activation_bytes=int(act),
    )
