"""Data-free knowledge relay (paper §III-B, Fig 3).

The edge server is the buffer between GAI (cloud FM) and EI (end clusters):

- **cloud-edge subnetwork** (domain-across flow): the cloud delivers
  foundation adapters to each domain's edge model; edges upload their
  fine-tuned adapters; the cloud FedAvg-aggregates across domains.
- **edge-end subnetwork** (domain-specific flow): each edge delivers its
  domain adapters to its client clusters (HFSL handles the intra-domain
  training; see core/hfsl.py) and absorbs the aggregated result.

"Data-free" is structural: only adapter pytrees ever cross a tier boundary
— never tokens, activations, or labels. Every transfer is metered in bytes
(parameter-efficient vs parameter-full, §III-A.2) through core/comm.py.

Attached to a multi-tenant serving bank (core/adapter_bank.py, via
``attach_bank``), every edge-adapter update the relay performs —
cloud delivery and end-cluster absorption — is hot-published into the
domain's bank slot, so the serving tier always decodes with the adapters
the relay says are current. The relay stays authoritative: its version
counters are mirrored into the bank and its ledger meters the bytes; the
bank is just the device-resident serving copy.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.comm import CostModel, RoundCost, transfer_cost
from repro.core.peft import tree_bytes


@dataclasses.dataclass
class Ledger:
    cloud_to_edge: int = 0
    edge_to_cloud: int = 0
    edge_to_end: int = 0
    end_to_edge: int = 0
    transfers: int = 0

    def total(self) -> int:
        return (self.cloud_to_edge + self.edge_to_cloud
                + self.edge_to_end + self.end_to_edge)


def _avg(trees: list) -> dict:
    return jax.tree.map(
        lambda *xs: (sum(x.astype(jnp.float32) for x in xs)
                     / len(xs)).astype(xs[0].dtype), *trees)


class KnowledgeRelay:
    """Versioned adapter store for one cloud + N domain edges."""

    def __init__(self, cloud_adapters: dict, domains: list[str],
                 cost_model: Optional[CostModel] = None, bank=None):
        self.cloud = cloud_adapters
        self.cloud_version = 0
        self.edges = {d: jax.tree.map(lambda x: x, cloud_adapters)
                      for d in domains}
        self.edge_versions = {d: 0 for d in domains}
        self.ledger = Ledger()
        self.cm = cost_model or CostModel()
        self.cost = RoundCost(0, 0, 0, 0, 0)
        self.bank = None
        if bank is not None:
            self.attach_bank(bank)

    def attach_bank(self, bank) -> None:
        """Route this relay's edge updates into a serving AdapterBank:
        every deliver/absorb hot-publishes the domain's new adapters to its
        bank slot. The relay's edge_versions stay the authoritative logical
        versions; the bank's own counter just counts publishes to the slot
        (it may have other writers, e.g. integrated.upgrade)."""
        missing = [d for d in self.edges if d not in bank.domains]
        if missing:
            raise KeyError(f"bank has no slot for domains {missing}")
        self.bank = bank
        for d in self.edges:                   # seed serving with relay state
            self._publish(d)

    def _publish(self, domain: str) -> None:
        if self.bank is not None:
            self.bank.publish(domain, self.edges[domain])

    # -- cloud-edge subnetwork (domain-across, large-scale flow) ----------
    def cloud_deliver(self, domain: str) -> dict:
        """Cloud FM -> edge domain model (model delivery)."""
        nb = tree_bytes(self.cloud)
        self.ledger.cloud_to_edge += nb
        self.ledger.transfers += 1
        self.cost = self.cost + transfer_cost(nb, self.cm.backhaul)
        self.edges[domain] = jax.tree.map(lambda x: x, self.cloud)
        self.edge_versions[domain] = self.cloud_version
        self._publish(domain)
        return self.edges[domain]

    def cloud_aggregate(self, domains: Optional[list[str]] = None) -> dict:
        """Edges -> cloud: FedAvg over domain adapters (upload + aggregate)."""
        ds = domains or list(self.edges)
        for d in ds:
            nb = tree_bytes(self.edges[d])
            self.ledger.edge_to_cloud += nb
            self.ledger.transfers += 1
            self.cost = self.cost + transfer_cost(nb, self.cm.backhaul)
        self.cloud = _avg([self.edges[d] for d in ds])
        self.cloud_version += 1
        return self.cloud

    # -- edge-end subnetwork (domain-specific, small-scale flow) ----------
    def edge_deliver(self, domain: str, n_clusters: int) -> dict:
        """Edge -> clusters (segmentation & distribution, Fig 4 step 1)."""
        nb = tree_bytes(self.edges[domain]) * n_clusters
        self.ledger.edge_to_end += nb
        self.ledger.transfers += n_clusters
        self.cost = self.cost + transfer_cost(nb, self.cm.cs)
        return self.edges[domain]

    def edge_absorb(self, domain: str, cluster_adapters: list) -> dict:
        """Clusters -> edge: FedAvg (uploading & aggregation, Fig 4 step 4)."""
        for a in cluster_adapters:
            nb = tree_bytes(a)
            self.ledger.end_to_edge += nb
            self.ledger.transfers += 1
            self.cost = self.cost + transfer_cost(nb, self.cm.cs)
        self.edges[domain] = _avg(cluster_adapters)
        self.edge_versions[domain] += 1
        self._publish(domain)
        return self.edges[domain]
