"""Data-free knowledge relay (paper §III-B, Fig 3).

The edge server is the buffer between GAI (cloud FM) and EI (end clusters):

- **cloud-edge subnetwork** (domain-across flow): the cloud delivers
  foundation adapters to each domain's edge model; edges upload their
  fine-tuned adapters; the cloud FedAvg-aggregates across domains.
- **edge-end subnetwork** (domain-specific flow): each edge delivers its
  domain adapters to its client clusters (HFSL handles the intra-domain
  training; see core/hfsl.py) and absorbs the aggregated result.

"Data-free" is structural: only adapter pytrees ever cross a tier boundary
— never tokens, activations, or labels. Every transfer is metered in bytes
(parameter-efficient vs parameter-full, §III-A.2) through core/comm.py.

Attached to a multi-tenant serving bank (core/adapter_bank.py, via
``attach_bank``), every edge-adapter update the relay performs —
cloud delivery and end-cluster absorption — is hot-published into the
domain's bank slot, so the serving tier always decodes with the adapters
the relay says are current. The relay stays authoritative: its version
counters are mirrored into the bank and its ledger meters the bytes; the
bank is just the device-resident serving copy.

Constructed with a :class:`~repro.core.faults.FaultPlan`, every transfer
routes through a lossy link: attempts may be dropped or bit-corrupted per
the plan's schedule, per-leaf CRC32 checksums reject corrupted deliveries
(re-sending ONLY the rejected leaves — a flipped byte in one adapter leaf
does not re-ship the whole tree), and the relay retries with capped
exponential backoff. Retries and retransmitted bytes are ledgered
(``Ledger.retries`` / ``Ledger.retransmit_bytes`` and the matching
``RoundCost`` fields; retransmit accounting books just the resent
leaves); a transfer that exhausts ``max_retries`` raises
:class:`RelayTransferError`. Without a plan (or with an all-off plan) the
accounting is bitwise identical to the no-faults relay.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import telemetry
from repro.core.comm import CostModel, RoundCost, transfer_cost
from repro.core.faults import FaultPlan, leaf_checksums
from repro.core.peft import tree_bytes


class RelayTransferError(RuntimeError):
    """A relay transfer exhausted its retry budget on a lossy link."""


@dataclasses.dataclass
class Ledger:
    cloud_to_edge: int = 0
    edge_to_cloud: int = 0
    edge_to_end: int = 0
    end_to_edge: int = 0
    transfers: int = 0
    retries: int = 0            # retransmission attempts (beyond first try)
    retransmit_bytes: int = 0   # bytes re-sent on those retries

    def total(self) -> int:
        return (self.cloud_to_edge + self.edge_to_cloud
                + self.edge_to_end + self.end_to_edge)


def _avg(trees: list) -> dict:
    return jax.tree.map(
        lambda *xs: (sum(x.astype(jnp.float32) for x in xs)
                     / len(xs)).astype(xs[0].dtype), *trees)


class KnowledgeRelay:
    """Versioned adapter store for one cloud + N domain edges."""

    def __init__(self, cloud_adapters: dict, domains: list[str],
                 cost_model: Optional[CostModel] = None, bank=None, *,
                 faults: Optional[FaultPlan] = None, max_retries: int = 8,
                 backoff_s: float = 0.05, backoff_cap_s: float = 1.0):
        self.cloud = cloud_adapters
        self.cloud_version = 0
        self.edges = {d: jax.tree.map(lambda x: x, cloud_adapters)
                      for d in domains}
        self.edge_versions = {d: 0 for d in domains}
        self.ledger = Ledger()
        self.cm = cost_model or CostModel()
        self.cost = RoundCost(0, 0, 0, 0, 0)
        self.faults = faults
        self.max_retries = max_retries
        self.backoff_s = backoff_s
        self.backoff_cap_s = backoff_cap_s
        self._tid = 0              # monotonic transfer id (fault schedule key)
        self.bank = None
        if bank is not None:
            self.attach_bank(bank)

    def attach_bank(self, bank) -> None:
        """Route this relay's edge updates into a serving AdapterBank:
        every deliver/absorb hot-publishes the domain's new adapters to its
        bank slot. The relay's edge_versions stay the authoritative logical
        versions; the bank's own counter just counts publishes to the slot
        (it may have other writers, e.g. integrated.upgrade)."""
        missing = [d for d in self.edges if d not in bank.domains]
        if missing:
            raise KeyError(f"bank has no slot for domains {missing}")
        self.bank = bank
        for d in self.edges:                   # seed serving with relay state
            self._publish(d)

    def _publish(self, domain: str) -> None:
        if self.bank is not None:
            self.bank.publish(domain, self.edges[domain])

    def _transfer(self, nbytes: int, link, field: str, payload=None):
        """One logical transfer over a (possibly lossy) link.

        Books the wire bytes of each attempt against the ledger's
        ``field`` and the link's latency/energy into :attr:`cost`. Under
        an active fault plan, attempts may be dropped or corrupted, with
        capped exponential backoff latency added per retry. Corruption
        is rejected PER LEAF (:func:`faults.leaf_checksums`): only the
        leaves whose checksums mismatch stay pending, so a retransmit
        re-sends — and books — just the corrupted leaves, not the whole
        tree. A link drop loses the whole attempt (every pending leaf
        stays pending). Returns the delivered payload (the caller's tree
        — corrupted wire copies never survive the checksum)."""
        tid, self._tid = self._tid, self._tid + 1
        tel = telemetry.get()
        plan = self.faults
        if plan is None or not plan.active:
            self.ledger.transfers += 1
            setattr(self.ledger, field, getattr(self.ledger, field) + nbytes)
            self.cost = self.cost + transfer_cost(nbytes, link)
            tel.count("relay.transfers")
            tel.count(f"relay.bytes.{field}", nbytes)
            return payload
        leaves: list = []
        leaf_chk: list = []
        leaf_nb: list = []
        if payload is not None:
            leaves = jax.tree.leaves(payload)
            leaf_chk = leaf_checksums(payload)
            leaf_nb = [int(np.asarray(jax.device_get(x)).nbytes)
                       for x in leaves]
        # pending = leaf indices still owed to the receiver; the first
        # attempt ships everything (nbytes), later attempts ship only
        # what the last checksum compare rejected
        pending = list(range(len(leaves)))
        pending_nb = nbytes
        with tel.span("relay.transfer", field=field, bytes=nbytes,
                      tid=tid) as sp:
            for attempt in range(self.max_retries + 1):
                if attempt > 0:
                    self.ledger.retries += 1
                    self.ledger.retransmit_bytes += pending_nb
                    # capped exponential base, scaled by the plan's seeded
                    # jitter draw for THIS (transfer, attempt): retries
                    # across concurrent transfers spread out instead of
                    # thundering in lockstep, and replaying the same plan
                    # re-books the exact same latency (jitter is part of
                    # the schedule, not noise)
                    backoff = min(self.backoff_s * 2.0 ** (attempt - 1),
                                  self.backoff_cap_s) \
                        * (1.0 + plan.retry_jitter(tid, attempt))
                    self.cost = self.cost + RoundCost(
                        backoff, 0.0, 0.0, 0, 0, retries=1,
                        retransmit_bytes=pending_nb)
                    tel.count("relay.retries")
                    tel.count("relay.retransmit_bytes", pending_nb)
                    tel.observe("relay.backoff_s", backoff)
                self.ledger.transfers += 1
                setattr(self.ledger, field,
                        getattr(self.ledger, field) + pending_nb)
                self.cost = self.cost + transfer_cost(pending_nb, link)
                tel.count("relay.transfers")
                tel.count(f"relay.bytes.{field}", pending_nb)
                lost = plan.link_drops(tid, attempt)
                if lost:
                    tel.count("relay.link_drops")
                if not lost and pending \
                        and plan.payload_corrupted(tid, attempt):
                    # the wire copy of the PENDING leaves arrives
                    # corrupted; compare per leaf and keep only the
                    # rejected leaves (and their bytes) for the resend
                    recv = plan.corrupt_payload(
                        [leaves[i] for i in pending], tid, attempt)
                    bad = [i for i, c in zip(pending, leaf_checksums(recv))
                           if c != leaf_chk[i]]
                    if bad:
                        tel.count("relay.checksum_rejects")
                        tel.count("relay.corrupt_leaves", len(bad))
                        pending = bad
                        pending_nb = sum(leaf_nb[i] for i in bad)
                        lost = True
                if not lost:
                    sp.set(attempts=attempt + 1)
                    return payload
            sp.set(attempts=self.max_retries + 1, gave_up=True)
        tel.count("relay.gave_up")
        raise RelayTransferError(
            f"transfer {tid} ({field}, {nbytes} B) dropped "
            f"{self.max_retries + 1} times; giving up")

    # -- cloud-edge subnetwork (domain-across, large-scale flow) ----------
    def cloud_deliver(self, domain: str) -> dict:
        """Cloud FM -> edge domain model (model delivery)."""
        nb = tree_bytes(self.cloud)
        recv = self._transfer(nb, self.cm.backhaul, "cloud_to_edge",
                              payload=self.cloud)
        self.edges[domain] = jax.tree.map(lambda x: x, recv)
        self.edge_versions[domain] = self.cloud_version
        self._publish(domain)
        return self.edges[domain]

    def cloud_aggregate(self, domains: Optional[list[str]] = None) -> dict:
        """Edges -> cloud: FedAvg over domain adapters (upload + aggregate)."""
        ds = domains or list(self.edges)
        received = [self._transfer(tree_bytes(self.edges[d]),
                                   self.cm.backhaul, "edge_to_cloud",
                                   payload=self.edges[d]) for d in ds]
        self.cloud = _avg(received)
        self.cloud_version += 1
        return self.cloud

    # -- edge-end subnetwork (domain-specific, small-scale flow) ----------
    def edge_deliver(self, domain: str, n_clusters: int) -> dict:
        """Edge -> clusters (segmentation & distribution, Fig 4 step 1)."""
        per = tree_bytes(self.edges[domain])
        if self.faults is None or not self.faults.active:
            # one batched cost booking (bitwise-identical to the no-faults
            # relay); tids still advance so later faulted runs line up
            nb = per * n_clusters
            self.ledger.edge_to_end += nb
            self.ledger.transfers += n_clusters
            self._tid += n_clusters
            self.cost = self.cost + transfer_cost(nb, self.cm.cs)
            tel = telemetry.get()
            tel.count("relay.transfers", n_clusters)
            tel.count("relay.bytes.edge_to_end", nb)
            return self.edges[domain]
        for _ in range(n_clusters):
            self._transfer(per, self.cm.cs, "edge_to_end",
                           payload=self.edges[domain])
        return self.edges[domain]

    def edge_absorb(self, domain: str, cluster_adapters: list) -> dict:
        """Clusters -> edge: FedAvg (uploading & aggregation, Fig 4 step 4)."""
        received = [self._transfer(tree_bytes(a), self.cm.cs, "end_to_edge",
                                   payload=a) for a in cluster_adapters]
        self.edges[domain] = _avg(received)
        self.edge_versions[domain] += 1
        self._publish(domain)
        return self.edges[domain]
