"""Hybrid Federated Split Learning trainer (paper §III-C, Fig 4).

The paper's fine-tuning workflow maps onto the TPU mesh as follows:

- **FL inter-cluster parallelism**: every index along the (`pod`, `data`)
  mesh axes is one fine-tuning client cluster. The tunable adapters carry a
  leading ``cluster`` dim (sharded over those axes), so each cluster trains
  its *own* adapter replica on its *own* data shard — zero cross-cluster
  traffic during local steps. The frozen backbone is shared (FSDP-sharded).
- **FedAvg sync**: every ``sync_every`` steps the adapter replicas are
  averaged over the cluster dim (one all-reduce of adapter-sized bytes —
  the paper's "uploading and aggregation of end model"). Optimizer state
  stays cluster-local, as in standard FedAvg.
- **SL intra-cluster seriality** becomes tensor parallelism over `model`
  inside each cluster for production (see core/sl_pipeline.py for the
  faithful serial form).

With ``sync_every=1`` this degenerates to synchronous data-parallel PEFT;
with one cluster it degenerates to SL, matching §III-C.1's remark.

Two execution engines share one step body (:func:`_make_step_body`):

- :func:`make_hfsl_step` — ONE step per call (legacy; one jitted dispatch +
  host sync per step).
- :func:`make_hfsl_round` — K steps in ONE jitted ``lax.scan`` dispatch, the
  fine-tuning twin of models/model.py::generate_scan. FedAvg fires *inside*
  the scan at ``sync_every`` boundaries of the carried step counter; batches
  are gathered from a device-resident bank (data/pipeline.py::BatchBank) by
  the scanned step index, so no host transfer happens inside a round.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import telemetry
from repro.optim.optimizers import Optimizer, apply_updates, clip_by_global_norm
from repro.sharding.rules import (ParamSpec, dim_sharding, hfsl_round_rules,
                                  named_shardings, shard, use_rules)


# ---------------------------------------------------------------------------
# Specs / init
# ---------------------------------------------------------------------------

def _cluster_stack(tree, n: int):
    """Leading `cluster` dim on every adapter ParamSpec.

    Inner `fsdp` axes are dropped: `cluster` already consumes the
    (pod, data) mesh axes, and a spec may not map a mesh axis twice.
    """
    def f(s: ParamSpec) -> ParamSpec:
        inner = tuple(None if a == "fsdp" else a for a in s.axes) if s.axes \
            else tuple([None] * len(s.shape))
        return ParamSpec((n, *s.shape), s.dtype, ("cluster", *inner),
                         init=s.init, scale=s.scale)
    return jax.tree.map(f, tree, is_leaf=lambda x: isinstance(x, ParamSpec))


def hfsl_state_spec(cfg, n_clusters: int, optimizer: Optimizer,
                    model_spec_fn: Callable) -> dict:
    """ParamSpec tree of the full HFSL train state (dry-run compatible).

    Optimizer state is declared by structural analogy: AdamW keeps two f32
    moments per adapter leaf (+ step), SGD keeps zero or one.
    """
    ms = model_spec_fn(cfg)
    adapters_c = _cluster_stack(ms["adapters"], n_clusters)

    def f32_like(s: ParamSpec) -> ParamSpec:
        return ParamSpec(s.shape, jnp.float32, s.axes, init="zeros")

    opt = {
        "step": ParamSpec((n_clusters,), jnp.int32, ("cluster",), init="zeros"),
        "m": jax.tree.map(f32_like, adapters_c,
                          is_leaf=lambda x: isinstance(x, ParamSpec)),
        "v": jax.tree.map(f32_like, adapters_c,
                          is_leaf=lambda x: isinstance(x, ParamSpec)),
    }
    return {
        "backbone": ms["backbone"],
        "adapters_c": adapters_c,
        "opt": opt,
        "step": ParamSpec((), jnp.int32, (), init="zeros"),
    }


def hfsl_state_shardings(cfg, n_clusters: int, optimizer: Optimizer,
                         model_spec_fn: Callable, mesh,
                         rules: Optional[dict] = None) -> dict:
    """NamedSharding tree for the full HFSL train state on ``mesh``.

    Derived from :func:`hfsl_state_spec` via rules.partition_specs: the
    adapter replicas / optimizer moments put their leading ``cluster`` dim
    on the (`pod`, `data`) axes, the backbone FSDP-shards where dims
    divide. This is both what init-time ``jax.device_put`` should place
    (sharded jit inputs must already match the pinned in_shardings) and
    what make_hfsl_round(mesh=...) pins — the two agree by construction.
    """
    rules = rules or hfsl_round_rules(cfg.family)
    spec = hfsl_state_spec(cfg, n_clusters, optimizer, model_spec_fn)
    return named_shardings(spec, mesh, rules)


def init_hfsl_state(key: jax.Array, cfg, n_clusters: int,
                    optimizer: Optimizer, model_init_fn: Callable) -> dict:
    params = model_init_fn(cfg, key)
    adapters_c = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n_clusters, *x.shape)),
        params["adapters"])
    # cluster replicas start identical (edge model delivery, Fig 4 step 1)
    return {
        "backbone": params["backbone"],
        "adapters_c": adapters_c,
        "opt": jax.vmap(optimizer.init)(adapters_c),
        "step": jnp.zeros((), jnp.int32),
    }


# ---------------------------------------------------------------------------
# Train step
# ---------------------------------------------------------------------------

def fedavg(adapters_c):
    """FedAvg over the cluster dim: mean, broadcast back to every cluster."""
    return jax.tree.map(
        lambda x: jnp.broadcast_to(
            jnp.mean(x.astype(jnp.float32), axis=0, keepdims=True),
            x.shape).astype(x.dtype),
        adapters_c)


def _make_cluster_update(cfg, optimizer: Optimizer, loss_fn: Callable,
                         clip_norm: float, microbatches: int) -> Callable:
    """Per-cluster local step: grads (optionally accumulated over
    ``microbatches`` splits of the cluster batch) -> one optimizer update."""

    def one_cluster(backbone, adapters, opt_state, batch):
        def inner(a, mb):
            return loss_fn({"backbone": backbone, "adapters": a}, mb, cfg)

        vg = jax.value_and_grad(inner, has_aux=True)
        if microbatches <= 1:
            (loss, aux), grads = vg(adapters, batch)
        else:
            def split(x):
                if x.shape[0] % microbatches:
                    raise ValueError(
                        f"cluster batch {x.shape[0]} not divisible by "
                        f"microbatches={microbatches}")
                return x.reshape(microbatches, x.shape[0] // microbatches,
                                 *x.shape[1:])

            mbs = jax.tree.map(split, batch)
            mb0 = jax.tree.map(lambda x: x[0], mbs)
            (l_av, aux_av), g_av = jax.eval_shape(vg, adapters, mb0)
            zeros = lambda t: jax.tree.map(
                lambda s: jnp.zeros(s.shape, s.dtype), t)

            def mb_body(carry, mb):
                gs, ls, axs = carry
                (l, ax), g = vg(adapters, mb)
                return (jax.tree.map(jnp.add, gs, g), ls + l,
                        jax.tree.map(jnp.add, axs, ax)), None

            (gs, ls, axs), _ = jax.lax.scan(
                mb_body, (zeros(g_av), jnp.zeros(l_av.shape, l_av.dtype),
                          zeros(aux_av)), mbs)
            inv = 1.0 / microbatches
            # mean-of-means == full-batch mean for equal splits
            grads = jax.tree.map(lambda g: (g * inv).astype(g.dtype), gs)
            loss = ls * inv
            aux = jax.tree.map(lambda v: v * inv, axs)
        if clip_norm:
            grads, _ = clip_by_global_norm(grads, clip_norm)
        updates, opt_state = optimizer.update(grads, opt_state, adapters)
        adapters = apply_updates(adapters, updates)
        return adapters, opt_state, loss, aux

    return one_cluster


def fedavg_masked(adapters_c, mask):
    """Partial-participation FedAvg: mean over the clusters ``mask`` keeps,
    broadcast back to those clusters ONLY — a masked-out (dropped or
    straggling) cluster's replica passes through bit-unchanged. With an
    all-ones mask this is bitwise :func:`fedavg`: the weighted sum·/cnt
    form compiles to (ulp-level) different arithmetic than ``jnp.mean``
    once fused into a round's scan, so the full-participation case runtime-
    selects the plain-mean graph instead of trusting float identities."""
    m = mask.astype(jnp.float32)
    cnt = jnp.maximum(jnp.sum(m), 1.0)      # 0 survivors -> no-op round
    full = jnp.all(m > 0)

    def f(x):
        mm = m.reshape((-1,) + (1,) * (x.ndim - 1))
        xf = x.astype(jnp.float32)
        plain = jnp.mean(xf, axis=0, keepdims=True)
        masked = jnp.sum(xf * mm, axis=0, keepdims=True) / cnt
        avg = jnp.broadcast_to(jnp.where(full, plain, masked),
                               x.shape).astype(x.dtype)
        return jnp.where(mm > 0, avg, x)

    return jax.tree.map(f, adapters_c)


def _clusters_finite(tree) -> jax.Array:
    """Per-cluster all-leaves-finite flag (n_clusters,) for cluster-leading
    trees — the in-scan guard's verdict on each cluster's update."""
    oks = [jnp.all(jnp.isfinite(x.astype(jnp.float32))
                   .reshape(x.shape[0], -1), axis=1)
           for x in jax.tree.leaves(tree)]
    return functools.reduce(jnp.logical_and, oks)


def _sync_at_boundary(adapters_c, new_step, *, sync_every: int,
                      always_sync: bool, mask=None):
    """FedAvg at ``sync_every`` multiples of the (possibly traced) counter.
    With ``mask`` (participation, (n,)), the masked FedAvg aggregates only
    surviving clusters and leaves the rest untouched."""
    avg = fedavg if mask is None else functools.partial(fedavg_masked,
                                                        mask=mask)
    if always_sync or sync_every == 1:
        return avg(adapters_c)
    do_sync = (new_step % sync_every) == 0
    synced = avg(adapters_c)
    return jax.tree.map(
        lambda s, a: jnp.where(do_sync, s, a), synced, adapters_c)


def _make_step_body(cfg, optimizer: Optimizer, loss_fn: Callable, *,
                    sync_every: int, clip_norm: float, always_sync: bool,
                    microbatches: int, spmd_axes=None,
                    faulted: bool = False) -> Callable:
    """``spmd_axes`` names the mesh axes carrying the cluster dim (mesh-
    native rounds): the cluster vmap runs with ``spmd_axis_name`` so the
    activation shard() constraints inside the per-cluster forward stay
    aligned — vmap inserts the mapped cluster dim into every inner spec
    instead of letting it shift the constraint onto the wrong dims.

    ``faulted=True`` returns the fault-tolerant step body
    ``step(state, batch, mask, corrupt)`` instead: a per-cluster
    participation ``mask`` (float (n,), >0 = present) gates both the local
    update and the FedAvg, a per-cluster ``corrupt`` flag NaN-poisons that
    cluster's computed update (core/faults.py), and an in-scan non-finite
    guard ``jnp.where``-skips any cluster whose update went NaN/inf — no
    host sync; the skip just keeps the pre-step replica. The differentiated
    per-cluster step is the SAME graph as the plain body (corruption is
    injected into the update epilogue, never into the grad computation), so
    with an all-ones mask and all-false corrupt the outputs are bitwise
    identical to the plain body (every guard reduces to a select of the
    updated branch)."""
    one_cluster = _make_cluster_update(cfg, optimizer, loss_fn, clip_norm,
                                       microbatches)

    def vstep(state, batch):
        return jax.vmap(one_cluster, in_axes=(None, 0, 0, 0),
                        spmd_axis_name=spmd_axes)(
            state["backbone"], state["adapters_c"], state["opt"], batch)

    def step(state: dict, batch: dict) -> tuple[dict, dict]:
        adapters_c, opt_c, loss_c, aux_c = vstep(state, batch)
        new_step = state["step"] + 1
        adapters_c = _sync_at_boundary(adapters_c, new_step,
                                       sync_every=sync_every,
                                       always_sync=always_sync)
        metrics = {"loss": jnp.mean(loss_c), "loss_per_cluster": loss_c}
        for k in (aux_c or {}):
            metrics[k] = jnp.mean(aux_c[k])
        return {**state, "adapters_c": adapters_c, "opt": opt_c,
                "step": new_step}, metrics

    def step_faulted(state: dict, batch: dict, mask, corrupt
                     ) -> tuple[dict, dict]:
        new_a, new_opt, loss_c, aux_c = vstep(state, batch)
        # gradient-corruption injection: a flagged cluster's update (and
        # loss) is NaN-poisoned AFTER the differentiated step, so the
        # unflagged clusters run the plain body's exact graph while the
        # guard below sees a genuinely non-finite update
        new_a = jax.tree.map(
            lambda x: jnp.where(
                corrupt.reshape((-1,) + (1,) * (x.ndim - 1)),
                jnp.asarray(jnp.nan, x.dtype), x)
            if jnp.issubdtype(x.dtype, jnp.floating) else x, new_a)
        loss_c = jnp.where(corrupt, jnp.asarray(jnp.nan, loss_c.dtype),
                           loss_c)
        part = mask > 0
        # non-finite guard: a cluster whose update (or loss) went NaN/inf
        # keeps its pre-step replica — computed in-scan, surfaced as counts
        ok = (_clusters_finite(new_a) & _clusters_finite(new_opt)
              & jnp.isfinite(loss_c))
        eff = part & ok

        def sel(new, old):
            return jax.tree.map(
                lambda n, o: jnp.where(
                    eff.reshape((-1,) + (1,) * (n.ndim - 1)), n, o), new, old)

        adapters_c = sel(new_a, state["adapters_c"])
        opt_c = sel(new_opt, state["opt"])
        new_step = state["step"] + 1
        adapters_c = _sync_at_boundary(adapters_c, new_step,
                                       sync_every=sync_every,
                                       always_sync=always_sync,
                                       mask=part.astype(jnp.float32))
        # metric means use where-masking (not multiply): a guarded cluster's
        # loss is literally NaN, and NaN * 0 would poison the mean. The
        # all-effective case selects the plain jnp.mean graph so fault-free
        # metrics match the plain body bitwise (same trick as fedavg_masked)
        denom = jnp.maximum(jnp.sum(eff.astype(jnp.float32)), 1.0)
        all_eff = jnp.all(eff)
        mmean = lambda v: jnp.where(
            all_eff, jnp.mean(v), jnp.sum(jnp.where(eff, v, 0.0)) / denom)
        n = part.shape[0]
        metrics = {"loss": mmean(loss_c),
                   "loss_per_cluster": loss_c,
                   "participating": jnp.sum(part.astype(jnp.int32)),
                   "skipped": jnp.sum((part & ~ok).astype(jnp.int32)),
                   "dropped": jnp.asarray(n, jnp.int32)
                   - jnp.sum(part.astype(jnp.int32))}
        for k in (aux_c or {}):
            metrics[k] = mmean(aux_c[k])
        return {**state, "adapters_c": adapters_c, "opt": opt_c,
                "step": new_step}, metrics

    return step_faulted if faulted else step


def make_hfsl_step(cfg, optimizer: Optimizer, loss_fn: Callable, *,
                   sync_every: int = 1, clip_norm: float = 0.0,
                   always_sync: bool = False,
                   microbatches: int = 1) -> Callable:
    """Build the jittable single HFSL train step (one dispatch per step).

    loss_fn(params, batch, cfg) -> (loss, aux). Batch leaves carry a leading
    cluster dim (see data/pipeline.cluster_batches). Prefer
    :func:`make_hfsl_round` on the hot path — it runs K of these per
    dispatch.
    """
    return _make_step_body(cfg, optimizer, loss_fn, sync_every=sync_every,
                           clip_norm=clip_norm, always_sync=always_sync,
                           microbatches=microbatches)


_TRAIN_KEYS = ("adapters_c", "opt", "step")    # donated; backbone never is


def make_hfsl_round(cfg, optimizer: Optimizer, loss_fn: Callable, *,
                    steps: int, sync_every: int = 1, clip_norm: float = 0.0,
                    always_sync: bool = False, microbatches: int = 1,
                    remat: Optional[bool] = None, jit: bool = True,
                    donate: bool = False, mesh=None,
                    rules: Optional[dict] = None,
                    state_spec: Optional[dict] = None) -> Callable:
    """Fused fine-tuning round: ``steps`` HFSL steps in ONE jitted dispatch.

    Returned ``round_fn(state, bank, offset=0) -> (state, metrics)``:

    - ``state`` — the init_hfsl_state dict; the carried ``state['step']``
      counter enters and leaves the scan, so FedAvg phase is preserved
      across rounds (pass the previous round's counter back in).
    - ``bank`` — device-resident batch bank: every leaf shaped
      ``(E, n_clusters, batch, ...)`` (data/pipeline.py::BatchBank.arrays).
      Step ``i`` trains on epoch row ``(offset + i) % E`` — the gather is
      indexed by the scanned step, so the whole round runs without a single
      host->device transfer.
    - ``metrics`` — the per-step metric dicts stacked to leading ``(steps,)``.

    ``microbatches`` accumulates gradients over that many equal splits of
    each cluster batch before the optimizer update (activation memory drops
    by the same factor; the update is numerically the full-batch one).
    ``remat`` is forwarded to ``loss_fn`` (e.g. model.lm_loss re-materializes
    the per-layer forward under ``jax.checkpoint``) for long-sequence LM
    fine-tuning; None leaves the loss untouched for losses without the knob.

    ``donate=True`` donates the round's *train-state* input buffers
    (adapters_c / opt / step — never the frozen backbone) to the jit, so
    XLA reuses them for the round's outputs instead of allocating a second
    full train state. Only enable it when the caller replaces its state
    with the returned one (e.g. ``integrated.upgrade``) — the input
    arrays are invalidated by the call. Parity/baseline harnesses that
    rerun from a kept initial state must leave it off.

    Numerics match ``steps`` sequential :func:`make_hfsl_step` calls on the
    same batches exactly — the two engines share one step body.

    ``mesh`` makes the round mesh-native: the jit's in/out shardings are
    pinned from rules.partition_specs over ``state_spec`` (the
    :func:`hfsl_state_spec` tree — required with ``mesh``), so the adapter
    replicas, optimizer moments, and the bank's batches keep their
    ``cluster`` dim resident on the (`pod`, `data`) axes across rounds (no
    per-round resharding, donation reuses the sharded buffers in place),
    and :func:`~repro.sharding.rules.use_rules` is active inside the
    dispatch so the loss forward's activation constraints resolve against
    ``rules`` (default: per-family hfsl_round_rules). Callers must place
    state and bank to match — :func:`hfsl_state_shardings` /
    ``BatchBank.pack(mesh=...)`` produce exactly these placements.
    """
    if remat is not None:
        loss_fn = functools.partial(loss_fn, remat=remat)
    if mesh is not None and state_spec is None:
        raise ValueError("make_hfsl_round(mesh=...) requires state_spec= "
                         "(the hfsl_state_spec tree) to derive the pinned "
                         "jit in/out shardings")
    rules = rules or (hfsl_round_rules(cfg.family) if mesh is not None
                      else None)
    spmd_axes = None
    if mesh is not None:
        # the mesh axes the cluster dim actually lands on (post
        # divisibility): threaded into the cluster vmap as spmd_axis_name
        n_clusters = state_spec["opt"]["step"].shape[0]
        cluster_spec = dim_sharding(mesh, n_clusters, "cluster",
                                    rules=rules).spec
        ax = cluster_spec[0] if len(cluster_spec) else None
        spmd_axes = ax if ax is None or isinstance(ax, tuple) else (ax,)
    def build_core(faulted: bool) -> Callable:
        step = _make_step_body(cfg, optimizer, loss_fn,
                               sync_every=sync_every, clip_norm=clip_norm,
                               always_sync=always_sync,
                               microbatches=microbatches,
                               spmd_axes=spmd_axes, faulted=faulted)

        def round_core(train: dict, backbone, bank: dict, offset,
                       mask=None, corrupt=None) -> tuple[dict, dict]:
            epoch = jax.tree.leaves(bank)[0].shape[0]
            off = jnp.asarray(offset, jnp.int32)

            def body(carry, i):
                batch = jax.tree.map(lambda x: x[(off + i) % epoch], bank)
                state = {**carry, "backbone": backbone}
                out, metrics = (step(state, batch, mask, corrupt) if faulted
                                else step(state, batch))
                return {k: out[k] for k in _TRAIN_KEYS}, metrics

            with use_rules(mesh, rules):
                return jax.lax.scan(body, train,
                                    jnp.arange(steps, dtype=jnp.int32))

        if not jit:
            return round_core
        # donate only the train state (argnum 0): the backbone rides as its
        # own argument precisely so it is excluded from donation — callers
        # keep serving from the same frozen backbone buffers.
        donate_argnums = (0,) if donate else ()
        if mesh is None:
            return jax.jit(round_core, donate_argnums=donate_argnums)
        state_sh = named_shardings(state_spec, mesh, rules)
        train_sh = {k: state_sh[k] for k in _TRAIN_KEYS}
        # the bank in_sharding is a pytree prefix: one sharding covers
        # every (steps, cluster, batch, ...) leaf — identical to what
        # BatchBank.pack(mesh=...) placed
        bank_sh = dim_sharding(mesh, n_clusters, "cluster", index=1,
                               rules=rules)
        in_sh = (train_sh, state_sh["backbone"], bank_sh, None) \
            + ((None, None) if faulted else ())
        return jax.jit(round_core, in_shardings=in_sh,
                       out_shardings=(train_sh, None),
                       donate_argnums=donate_argnums)

    # the plain core is the only one most callers ever touch; the faulted
    # core (participation mask + corruption flags + non-finite guard) is
    # built on first faulted call so the happy path stays byte-identical
    cores: dict[bool, Callable] = {False: build_core(False)}

    def round_fn(state: dict, bank: dict, offset=0, *, mask=None,
                 corrupt=None) -> tuple[dict, dict]:
        # clean-round fast path, decided on the HOST (mask/corrupt are
        # concrete FaultPlan schedules): a round where no fault fires runs
        # the plain compiled core — bitwise-identical by construction, not
        # by trusting float identities across two different XLA graphs
        clean = ((mask is None or bool((np.asarray(mask) > 0).all()))
                 and (corrupt is None or not bool(np.asarray(corrupt).any())))
        train = {k: state[k] for k in _TRAIN_KEYS}
        # scan-dispatch span (module singleton, resolved per call): the jit
        # returns as soon as the round is ENQUEUED, so the duration is the
        # host-side dispatch share (plus compile on the first call) — the
        # blocked end-to-end round time is the caller's span
        # (integrated.upgrade) or the wall clock around block_until_ready
        tel = telemetry.get()
        with tel.span("hfsl.round_dispatch", steps=steps, clean=clean):
            if clean:
                out, metrics = cores[False](train, state["backbone"], bank,
                                            offset)
            else:
                if True not in cores:
                    cores[True] = build_core(True)
                n = jax.tree.leaves(train["adapters_c"])[0].shape[0]
                mask = (jnp.ones((n,), jnp.float32) if mask is None
                        else jnp.asarray(mask, jnp.float32))
                corrupt = (jnp.zeros((n,), bool) if corrupt is None
                           else jnp.asarray(corrupt, bool))
                out, metrics = cores[True](train, state["backbone"], bank,
                                           offset, mask, corrupt)
        tel.count("hfsl.rounds")
        tel.count("hfsl.steps", steps)
        if not clean:
            tel.count("hfsl.faulted_rounds")
        return {**out, "backbone": state["backbone"]}, metrics

    return round_fn


def consensus_params(state: dict) -> dict:
    """Aggregated model (edge view after FedAvg): cluster-mean adapters."""
    return {"backbone": state["backbone"],
            "adapters": jax.tree.map(
                lambda x: jnp.mean(x.astype(jnp.float32), 0).astype(x.dtype),
                state["adapters_c"])}


# ---------------------------------------------------------------------------
# Communication accounting (per §III-C.2)
# ---------------------------------------------------------------------------

def sync_bytes(adapters_c) -> int:
    """Bytes moved per FedAvg round: each cluster uploads + downloads its
    adapter replica (the parameter-efficient flow; compare a full-model
    FedAvg in benchmarks/fig2_comm.py)."""
    import numpy as np
    one = jax.tree.map(lambda x: x[0], adapters_c)
    per_replica = sum(int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
                      for x in jax.tree.leaves(one))
    n = jax.tree.leaves(adapters_c)[0].shape[0]
    return 2 * n * per_replica
