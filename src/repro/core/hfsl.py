"""Hybrid Federated Split Learning trainer (paper §III-C, Fig 4).

The paper's fine-tuning workflow maps onto the TPU mesh as (DESIGN.md §2):

- **FL inter-cluster parallelism**: every index along the (`pod`, `data`)
  mesh axes is one fine-tuning client cluster. The tunable adapters carry a
  leading ``cluster`` dim (sharded over those axes), so each cluster trains
  its *own* adapter replica on its *own* data shard — zero cross-cluster
  traffic during local steps. The frozen backbone is shared (FSDP-sharded).
- **FedAvg sync**: every ``sync_every`` steps the adapter replicas are
  averaged over the cluster dim (one all-reduce of adapter-sized bytes —
  the paper's "uploading and aggregation of end model"). Optimizer state
  stays cluster-local, as in standard FedAvg.
- **SL intra-cluster seriality** becomes tensor parallelism over `model`
  inside each cluster for production (see core/sl_pipeline.py for the
  faithful serial form).

With ``sync_every=1`` this degenerates to synchronous data-parallel PEFT;
with one cluster it degenerates to SL, matching §III-C.1's remark.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.optim.optimizers import Optimizer, apply_updates, clip_by_global_norm
from repro.sharding.rules import ParamSpec, shard


# ---------------------------------------------------------------------------
# Specs / init
# ---------------------------------------------------------------------------

def _cluster_stack(tree, n: int):
    """Leading `cluster` dim on every adapter ParamSpec.

    Inner `fsdp` axes are dropped: `cluster` already consumes the
    (pod, data) mesh axes, and a spec may not map a mesh axis twice.
    """
    def f(s: ParamSpec) -> ParamSpec:
        inner = tuple(None if a == "fsdp" else a for a in s.axes) if s.axes \
            else tuple([None] * len(s.shape))
        return ParamSpec((n, *s.shape), s.dtype, ("cluster", *inner),
                         init=s.init, scale=s.scale)
    return jax.tree.map(f, tree, is_leaf=lambda x: isinstance(x, ParamSpec))


def hfsl_state_spec(cfg, n_clusters: int, optimizer: Optimizer,
                    model_spec_fn: Callable) -> dict:
    """ParamSpec tree of the full HFSL train state (dry-run compatible).

    Optimizer state is declared by structural analogy: AdamW keeps two f32
    moments per adapter leaf (+ step), SGD keeps zero or one.
    """
    ms = model_spec_fn(cfg)
    adapters_c = _cluster_stack(ms["adapters"], n_clusters)

    def f32_like(s: ParamSpec) -> ParamSpec:
        return ParamSpec(s.shape, jnp.float32, s.axes, init="zeros")

    opt = {
        "step": ParamSpec((n_clusters,), jnp.int32, ("cluster",), init="zeros"),
        "m": jax.tree.map(f32_like, adapters_c,
                          is_leaf=lambda x: isinstance(x, ParamSpec)),
        "v": jax.tree.map(f32_like, adapters_c,
                          is_leaf=lambda x: isinstance(x, ParamSpec)),
    }
    return {
        "backbone": ms["backbone"],
        "adapters_c": adapters_c,
        "opt": opt,
        "step": ParamSpec((), jnp.int32, (), init="zeros"),
    }


def init_hfsl_state(key: jax.Array, cfg, n_clusters: int,
                    optimizer: Optimizer, model_init_fn: Callable) -> dict:
    params = model_init_fn(cfg, key)
    adapters_c = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n_clusters, *x.shape)),
        params["adapters"])
    # cluster replicas start identical (edge model delivery, Fig 4 step 1)
    return {
        "backbone": params["backbone"],
        "adapters_c": adapters_c,
        "opt": jax.vmap(optimizer.init)(adapters_c),
        "step": jnp.zeros((), jnp.int32),
    }


# ---------------------------------------------------------------------------
# Train step
# ---------------------------------------------------------------------------

def fedavg(adapters_c):
    """FedAvg over the cluster dim: mean, broadcast back to every cluster."""
    return jax.tree.map(
        lambda x: jnp.broadcast_to(
            jnp.mean(x.astype(jnp.float32), axis=0, keepdims=True),
            x.shape).astype(x.dtype),
        adapters_c)


def make_hfsl_step(cfg, optimizer: Optimizer, loss_fn: Callable, *,
                   sync_every: int = 1, clip_norm: float = 0.0,
                   always_sync: bool = False) -> Callable:
    """Build the jittable HFSL train step.

    loss_fn(params, batch, cfg) -> (loss, aux). Batch leaves carry a leading
    cluster dim (see data/pipeline.cluster_batches).
    """

    def one_cluster(backbone, adapters, opt_state, batch):
        def inner(a):
            loss, aux = loss_fn({"backbone": backbone, "adapters": a},
                                batch, cfg)
            return loss, aux

        (loss, aux), grads = jax.value_and_grad(inner, has_aux=True)(adapters)
        if clip_norm:
            grads, _ = clip_by_global_norm(grads, clip_norm)
        updates, opt_state = optimizer.update(grads, opt_state, adapters)
        adapters = apply_updates(adapters, updates)
        return adapters, opt_state, loss, aux

    def step(state: dict, batch: dict) -> tuple[dict, dict]:
        adapters_c, opt_c, loss_c, aux_c = jax.vmap(
            one_cluster, in_axes=(None, 0, 0, 0))(
            state["backbone"], state["adapters_c"], state["opt"], batch)
        new_step = state["step"] + 1
        if always_sync or sync_every == 1:
            adapters_c = fedavg(adapters_c)
        else:
            do_sync = (new_step % sync_every) == 0
            synced = fedavg(adapters_c)
            adapters_c = jax.tree.map(
                lambda s, a: jnp.where(do_sync, s, a), synced, adapters_c)
        metrics = {"loss": jnp.mean(loss_c), "loss_per_cluster": loss_c}
        for k in (aux_c or {}):
            metrics[k] = jnp.mean(aux_c[k])
        return {**state, "adapters_c": adapters_c, "opt": opt_c,
                "step": new_step}, metrics

    return step


def consensus_params(state: dict) -> dict:
    """Aggregated model (edge view after FedAvg): cluster-mean adapters."""
    return {"backbone": state["backbone"],
            "adapters": jax.tree.map(
                lambda x: jnp.mean(x.astype(jnp.float32), 0).astype(x.dtype),
                state["adapters_c"])}


# ---------------------------------------------------------------------------
# Communication accounting (per §III-C.2)
# ---------------------------------------------------------------------------

def sync_bytes(adapters_c) -> int:
    """Bytes moved per FedAvg round: each cluster uploads + downloads its
    adapter replica (the parameter-efficient flow; compare a full-model
    FedAvg in benchmarks/fig2_comm.py)."""
    import numpy as np
    one = jax.tree.map(lambda x: x[0], adapters_c)
    per_replica = sum(int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
                      for x in jax.tree.leaves(one))
    n = jax.tree.leaves(adapters_c)[0].shape[0]
    return 2 * n * per_replica
