"""Host-side paged KV-cache bookkeeping: block allocator + prefix cache.

The device side of the paged cache is a block pool ``(L, n_blocks,
block_size, Hkv, D)`` plus per-row block tables (see
``models/attention.py``); this module owns the HOST side — which pool
blocks are free, who holds references to each block, and which blocks
hold which prompt prefixes:

- :class:`BlockAllocator` — a free-list allocator with refcounted
  blocks. The free list is LRU-ordered and freed blocks RETAIN their
  content hash until the slot is actually reused, so a prefix freed by
  one drain can be revived by the next (``acquire`` on a cache hit
  resurrects a dead block at refcount 1 without re-prefilling it).
- **Prefix sharing** — prompt token blocks are hashed with a CHAINED
  per-block CRC32 (each block's hash folds in its predecessor's), so a
  hash identifies not just the 16 tokens in the block but the entire
  prefix up to and including it — exactly the attention state the
  block's K/V rows encode. ``match_prefix`` walks the chain to find the
  longest cached prefix; ``register`` publishes a freshly prefilled
  prompt's full blocks for future requests.

Sharing is copy-on-write by construction: only FULL blocks are ever
shared, and decode always appends into the row's private tail blocks,
so a shared block is never written after publication.

Invariants (property-tested in tests/test_ragged.py): refcounts never
go negative, double-free raises, and ``used + free == n_blocks`` after
any alloc/free/acquire sequence.
"""
from __future__ import annotations

import dataclasses
import zlib
from collections import OrderedDict
from typing import Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class PagedSpec:
    """Static paged-cache geometry (part of every fused-fn cache key).

    ``n_blocks`` sizes the device pool; ``block_size`` is the tokens per
    block (pow2 so pow2 cache caps divide evenly). ``share_prefix``
    opts a drain into cross-request prefix sharing (full-block prompt
    hashes; only meaningful on all-attention, full-window configs)."""
    n_blocks: int = 64
    block_size: int = 16
    share_prefix: bool = False

    def __post_init__(self):
        if self.block_size < 1 or self.block_size & (self.block_size - 1):
            raise ValueError(
                f"block_size must be a power of two, got {self.block_size}")
        if self.n_blocks < 1:
            raise ValueError(f"n_blocks must be >= 1, got {self.n_blocks}")


def block_hashes(tokens, block_size: int) -> list[int]:
    """Chained CRC32 per FULL block of a prompt.

    ``h[i] = crc32(bytes(h[i-1]) + tokens[i*bs:(i+1)*bs])`` — a block's
    hash commits to the whole prefix through it, which is what makes
    hash equality mean attention-state equality. Partial tail blocks
    are never hashed (they are private by definition)."""
    toks = np.ascontiguousarray(np.asarray(tokens, np.int32))
    out: list[int] = []
    h = 0
    for i in range(len(toks) // block_size):
        blk = toks[i * block_size:(i + 1) * block_size]
        h = zlib.crc32(blk.tobytes(), zlib.crc32(h.to_bytes(8, "little")))
        out.append(h)
    return out


class BlockAllocator:
    """Refcounted free-list allocator over ``n_blocks`` pool slots."""

    def __init__(self, n_blocks: int, block_size: int):
        self.n_blocks = int(n_blocks)
        self.block_size = int(block_size)
        self.refcount = [0] * self.n_blocks
        # LRU free list: insertion order = eviction order. Freed blocks
        # keep their hash entry until reused, so they remain prefix-
        # cache hits ("dead" blocks are revivable via acquire()).
        self._free: OrderedDict[int, None] = OrderedDict(
            (i, None) for i in range(self.n_blocks))
        self._hash_to_block: dict[int, int] = {}
        self._block_to_hash: dict[int, int] = {}
        # counters (surfaced through EngineStats / telemetry gauges)
        self.allocated = 0
        self.freed = 0
        self.shared_acquires = 0
        self.hash_hits = 0

    # -- core ------------------------------------------------------------
    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        return self.n_blocks - len(self._free)

    def _evict_hash(self, bid: int) -> None:
        h = self._block_to_hash.pop(bid, None)
        if h is not None and self._hash_to_block.get(h) == bid:
            del self._hash_to_block[h]

    def alloc(self, n: int) -> Optional[list[int]]:
        """Take ``n`` blocks off the free list (LRU first), or None if
        fewer than ``n`` are free. Reuse evicts the block's old hash."""
        if n > len(self._free):
            return None
        out = []
        for _ in range(n):
            bid, _ = self._free.popitem(last=False)
            self._evict_hash(bid)
            self.refcount[bid] = 1
            out.append(bid)
        self.allocated += n
        return out

    def acquire(self, bid: int) -> None:
        """Take one more reference on ``bid`` (prefix-share a block).
        Reviving a dead block (rc==0, still hashed) removes it from the
        free list without touching its contents."""
        if self.refcount[bid] == 0:
            if bid not in self._free:
                raise RuntimeError(f"block {bid} has rc=0 but is not free")
            del self._free[bid]
        self.refcount[bid] += 1
        self.shared_acquires += 1

    def free(self, block_ids) -> None:
        """Drop one reference per block; rc==0 blocks go to the LRU tail
        (hash kept — still a prefix-cache hit until reused)."""
        for bid in block_ids:
            if self.refcount[bid] <= 0:
                raise RuntimeError(f"double free of block {bid}")
            self.refcount[bid] -= 1
            if self.refcount[bid] == 0:
                self._free[bid] = None
                self.freed += 1

    # -- prefix cache ----------------------------------------------------
    def match_prefix(self, tokens) -> tuple[list[int], int]:
        """Longest cached full-block prefix of ``tokens``.

        Returns (block_ids, n_matched_blocks); walking stops at the
        first chained hash with no live mapping. The caller must
        ``acquire`` each returned block to pin it."""
        ids: list[int] = []
        for h in block_hashes(tokens, self.block_size):
            bid = self._hash_to_block.get(h)
            if bid is None:
                break
            ids.append(bid)
        if ids:
            self.hash_hits += 1
        return ids, len(ids)

    def register(self, tokens, block_ids) -> None:
        """Publish a freshly prefilled prompt's full blocks for sharing.
        ``block_ids[i]`` must hold tokens ``[i*bs, (i+1)*bs)``. First
        registration of a hash wins; later duplicates stay private."""
        for h, bid in zip(block_hashes(tokens, self.block_size), block_ids):
            if h in self._hash_to_block:
                continue
            self._evict_hash(bid)          # block may carry an older hash
            self._hash_to_block[h] = bid
            self._block_to_hash[bid] = h

    def check(self) -> None:
        """Assert the conservation invariant (used in property tests)."""
        used = sum(1 for rc in self.refcount if rc > 0)
        if used + len(self._free) != self.n_blocks:
            raise AssertionError(
                f"pool leak: used={used} free={len(self._free)} "
                f"n_blocks={self.n_blocks}")
        if any(rc < 0 for rc in self.refcount):
            raise AssertionError(f"negative refcount: {self.refcount}")
