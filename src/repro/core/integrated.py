"""Integrated fine-tuning AND inference runtime (the paper's thesis, §IV).

GaisNet's defining property is that ONE edge system alternates between
model fine-tuning rounds (upgrading an edge model) and task-inference
rounds (serving requests) under a profit policy. `core/scheduler.py` holds
the abstract policies; this module is the *runtime* that executes them
against real models:

- it owns a set of domain edge models (shared frozen backbone + per-domain
  adapters, paper Fig 3) kept device-resident in ONE multi-tenant
  AdapterBank (core/adapter_bank.py),
- consumes a request stream (a round may demand one domain or a mix of
  domains; §IV-C's "one GAI service per round" is the single-domain case),
- on `produce`: serves the round's requests — mixed-domain rounds
  included — through the batched decode engine (launch/engine.py) in ONE
  engine call against the bank: per-request `adapter_ids` select each
  row's domain adapters inside the batched multi-LoRA kernels, so the
  round's host work is independent of how many domains the demand mixes
  (no per-domain param assembly, no per-domain engine drain). Profit is
  booked proportional to measured accuracy,
- on `upgrade`: runs an HFSL fine-tuning round for the chosen domain
  (paying the cost) and hot-publishes the result into the bank
  (`AdapterBank.publish` — a jitted in-place slot update), so the very
  next produce round serves the upgraded adapters (the paper's
  bidirectional knowledge flow, fine-tune -> serve, with zero host-side
  re-assembly),
- keeps the §III metric ledger (latency / compute / comm / energy) via
  core/comm.py.

This closes the loop the paper only simulates with constants (Table V):
here, "device value" is the measured accuracy of a real fine-tuned model.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hfsl, telemetry
from repro.core.adapter_bank import AdapterBank
from repro.core.comm import CostModel, RoundCost
from repro.core.faults import FaultPlan
from repro.core.peft import tree_bytes
from repro.checkpoint import io as ckpt_io
from repro.core.scheduler import SchedulerEnv, mlcp_policy, run_policy
from repro.data.noniid import partition_by_classes
from repro.data.pipeline import BatchBank
from repro.launch.engine import DecodeEngine
from repro.models import model as M
from repro.optim.optimizers import adamw


@dataclasses.dataclass
class DomainState:
    name: str
    adapters_c: dict                   # per-cluster replicas (HFSL state)
    opt_state: dict
    # HFSL step counter, persisted ACROSS upgrade rounds so the
    # sync_every FedAvg phase continues instead of restarting each round
    step: Any = None                   # scalar int32 device array
    level: int = 0                     # number of fine-tuning rounds applied
    accuracy: float = 0.0


@dataclasses.dataclass
class RoundRecord:
    round: int
    action: str                        # 'produce' | 'upgrade'
    domain: str
    profit: float
    accuracy: float
    cumulative: float
    cost: RoundCost


class IntegratedRuntime:
    """Executes fine-tune-or-infer rounds against real edge models."""

    def __init__(self, cfg, tasks: dict, *, n_clusters: int = 2,
                 steps_per_upgrade: int = 20, batch: int = 16,
                 sync_every: int = 5, serve_batch: int = 64,
                 serve_gen: int = 4, serve_slots: int = 16, lr: float = 5e-3,
                 profit_scale: float = 100.0, upgrade_cost: float = 50.0,
                 cost_model: Optional[CostModel] = None, seed: int = 0,
                 mesh=None, faults: Optional[FaultPlan] = None,
                 deadline_s: Optional[float] = None,
                 spec_k: Optional[int] = None, spec_d_model: int = 64,
                 spec_layers: int = 2,
                 tel: Optional[telemetry.Telemetry] = None,
                 paged=None):
        self.cfg = cfg
        self.tasks = tasks                       # domain -> ClassificationTask
        self.n_clusters = n_clusters
        self.steps = steps_per_upgrade
        self.sync_every = sync_every
        self.profit_scale = profit_scale
        self.upgrade_cost = upgrade_cost
        self.cm = cost_model or CostModel()
        self.serve_batch = serve_batch
        self.serve_gen = serve_gen
        # chaos wiring: an active FaultPlan drives per-round participation
        # masks + gradient corruption through the fused HFSL round;
        # deadline_s bounds every served request's wall time (over-budget
        # rows retire mid-wave as timed_out completions)
        self.faults = faults
        self.deadline_s = deadline_s
        # telemetry hook: spans/counters for every upgrade/produce round go
        # to `tel` if given, else the module singleton resolved at call
        # time (telemetry.enable() before run() instruments everything);
        # the engine shares the same instance
        self.tel = tel
        self._fault_round = 0                    # upgrade-round schedule index
        self._record_base = 0                    # rounds from restored runs
        self.publish_rejects = 0                 # validated publishes refused
        # mesh-native runtime: with a (`data`, `model`) mesh BOTH sides of
        # the loop shard — upgrade rounds pin the HFSL state/bank cluster
        # dims onto `data` (hfsl.make_hfsl_round(mesh=...)), serving shards
        # engine waves over `data` and the AdapterBank slot dim over `data`
        # too. Placement happens ONCE here; every dispatch thereafter
        # consumes mesh-resident buffers.
        self.mesh = mesh
        key = jax.random.PRNGKey(seed)
        params = M.init(cfg, key)
        self.backbone = params["backbone"]       # shared frozen FM
        self.opt = adamw(lr)
        self.batch = batch
        round_rules = None
        state_spec = None
        state_sh = None
        if mesh is not None:
            from repro.sharding import rules as R
            round_rules = R.hfsl_round_rules(cfg.family)
            # ONE spec derivation: the same tree places the init-time
            # state (device_put below) and pins the round's jit in/out
            # shardings (state_spec= to make_hfsl_round), so the two
            # cannot desynchronize
            state_spec = hfsl.hfsl_state_spec(cfg, n_clusters, self.opt,
                                              M.model_spec)
            state_sh = R.named_shardings(state_spec, mesh, round_rules)
            self.backbone = jax.device_put(self.backbone,
                                           state_sh["backbone"])
        self._state_sh = state_sh                # restore() re-places here
        self.domains: dict[str, DomainState] = {}
        self._banks: dict[str, BatchBank] = {}
        for i, name in enumerate(tasks):
            state = hfsl.init_hfsl_state(jax.random.PRNGKey(seed + i), cfg,
                                         n_clusters, self.opt,
                                         lambda c, k: params)
            if state_sh is not None:             # cluster replicas on `data`
                state = {**state, **jax.device_put(
                    {k: state[k] for k in ("adapters_c", "opt", "step")},
                    {k: state_sh[k] for k in ("adapters_c", "opt", "step")})}
            data = tasks[name].dataset(200 * n_clusters, seed=seed + 11 + i)
            parts = partition_by_classes(data["label"], n_clusters,
                                         cfg.peft.head_dim_out,
                                         seed=seed + i)
            # one epoch of per-cluster batches lives on device for the whole
            # runtime; every upgrade round gathers from it inside the scan
            # (with a mesh: each cluster's rows on that cluster's slice)
            self._banks[name] = BatchBank.pack(data, parts, batch,
                                               seed=seed + i, mesh=mesh,
                                               rules=round_rules)
            self.domains[name] = DomainState(
                name, state["adapters_c"], state["opt"], state["step"])
        # ONE jitted dispatch per fine-tuning round (the decode engine's
        # twin): steps_per_upgrade scanned HFSL steps, in-scan FedAvg.
        # Input state buffers are donated: upgrade() replaces the domain's
        # state wholesale, so the round reuses them for its outputs.
        self._round = hfsl.make_hfsl_round(
            cfg, self.opt, M.classify_loss, steps=self.steps,
            sync_every=self.sync_every, donate=True, mesh=mesh,
            rules=round_rules, state_spec=state_spec)
        # ONE multi-tenant bank for every domain's serving adapters: waves
        # and classify calls address it with per-row adapter slot ids, so
        # serving never assembles per-domain param trees on the host.
        self.bank = AdapterBank.create(
            {n: self._consensus_adapters(n) for n in self.domains},
            mesh=mesh)
        # speculative serving: spec_k drafts per verify pass from a tiny
        # recurrent drafter — the paper's "small edge model assists the
        # large one" made concrete for inference rounds. The drafter is a
        # replicated edge model (sharding/rules.py::drafter_rules);
        # produce() books drafted vs verified tokens in the RoundCost
        # ledger so the profit policy can see the measured draft quality.
        self.spec = None
        if spec_k is not None:
            from repro.core.spec_decode import SpecDecoder
            self.spec = SpecDecoder.init(
                cfg, jax.random.PRNGKey(seed + 997), k=spec_k,
                d_model=spec_d_model, n_layers=spec_layers)
        # paged serving: a core.paged.PagedSpec swaps the engine's dense
        # per-slot cache slabs for the block-pool layout (cross-drain
        # prefix revival included); mutually exclusive with spec_k —
        # DecodeEngine validates the combination.
        self.engine = DecodeEngine(cfg, slots=min(serve_slots, serve_batch),
                                   seed=seed, bank=self.bank, mesh=mesh,
                                   spec=self.spec, tel=tel, paged=paged)

        def _classify_impl(p, b, ids):
            from repro.sharding import rules as R
            with R.use_rules(mesh, R.serving_rules() if mesh else None):
                return M.classify(p, b, cfg, adapter_ids=ids)

        self._classify = jax.jit(_classify_impl)
        self.records: list[RoundRecord] = []
        self._eval_cache: dict[str, dict] = {
            n: tasks[n].dataset(150, seed=seed + 91 + i)
            for i, n in enumerate(tasks)}
        for n in self.domains:
            self.domains[n].accuracy = self._measure(n)

    # -- internals ---------------------------------------------------------
    def _telemetry(self) -> telemetry.Telemetry:
        return self.tel if self.tel is not None else telemetry.get()

    def _consensus_adapters(self, domain: str) -> dict:
        """Edge view after FedAvg: cluster-mean adapters (what serves)."""
        return hfsl.consensus_params({
            "backbone": self.backbone,
            "adapters_c": self.domains[domain].adapters_c})["adapters"]

    def _measure(self, domain: str) -> float:
        """Eval accuracy through the bank's multi-tenant classify path
        (all rows address one slot — same kernels as mixed waves)."""
        data = self._eval_cache[domain]
        ids = jnp.full((data["label"].shape[0],), self.bank.slot(domain),
                       jnp.int32)
        logits = self._classify(self.bank.serving_params(self.backbone),
                                {k: jnp.asarray(v) for k, v in data.items()},
                                ids)
        return float(jnp.mean(jnp.argmax(logits, -1) == data["label"]))

    # -- the two GAI services ----------------------------------------------
    def upgrade(self, domain: str) -> tuple[float, RoundCost]:
        """One HFSL fine-tuning round for `domain` (paper: 'upgrade').

        The round's steps_per_upgrade HFSL steps run in ONE jitted scan
        dispatch (hfsl.make_hfsl_round) over the domain's device-resident
        batch bank. The domain's HFSL step counter persists across rounds,
        so the sync_every FedAvg phase continues where the last upgrade
        left off; comm is booked per FedAvg actually fired. The RoundCost
        ledger records examples consumed and measured ex_per_s — the
        fine-tuning twin of produce()'s tokens / tok_per_s.

        The round's consensus adapters are hot-published into the serving
        AdapterBank (jitted in-place slot update — no host transfer), so
        the next produce round serves the upgraded model immediately.
        """
        tel = self._telemetry()
        d = self.domains[domain]
        bank = self._banks[domain]
        state = {"backbone": self.backbone, "adapters_c": d.adapters_c,
                 "opt": d.opt_state, "step": d.step}
        step0 = int(state["step"])
        fr, self._fault_round = self._fault_round, self._fault_round + 1
        chaos = self.faults is not None and self.faults.active
        part_n, dropped_n = self.n_clusters, 0
        with tel.span("integrated.upgrade", domain=domain,
                      steps=self.steps) as usp:
            t0 = time.perf_counter()
            if chaos:
                # seeded per-round schedules: which clusters participate and
                # which get their updates NaN-poisoned (the in-scan guard
                # where-skips those; dropped clusters carry state untouched)
                mask_np, _, _ = self.faults.participation(fr, self.n_clusters)
                corrupt_np = self.faults.corrupt_mask(fr, self.n_clusters)
                part_n = int(mask_np.sum())
                dropped_n = self.n_clusters - part_n
                state, ms = self._round(state, bank.arrays,
                                        bank.advance(self.steps),
                                        mask=jnp.asarray(mask_np,
                                                         jnp.float32),
                                        corrupt=jnp.asarray(corrupt_np))
            else:
                state, ms = self._round(state, bank.arrays,
                                        bank.advance(self.steps))
            jax.block_until_ready(state["adapters_c"])
            dt = time.perf_counter() - t0
            skipped_n = int(np.asarray(ms["skipped"]).sum()) \
                if "skipped" in ms else 0
            d.adapters_c, d.opt_state, d.step = \
                state["adapters_c"], state["opt"], state["step"]
            d.level += 1
            try:
                self.bank.publish(domain, self._consensus_adapters(domain))
            except ValueError:
                # a poisoned consensus never reaches live traffic: the bank
                # keeps serving the current (validated) version
                self.publish_rejects += 1
            d.accuracy = self._measure(domain)
            examples = self.steps * part_n * self.batch
            seq = bank.arrays["tokens"].shape[-1]
            flops = 6.0 * self.cfg.active_param_count() * examples * seq
            n_syncs = (step0 + self.steps) // self.sync_every \
                - step0 // self.sync_every
            comm = hfsl.sync_bytes(d.adapters_c) * n_syncs
            if chaos:                  # only survivors exchange sync bytes
                comm = int(comm * part_n / self.n_clusters)
            cost = RoundCost(dt, flops, self.cm.cs.energy(comm), comm, 0,
                             examples=examples, dropped_clusters=dropped_n,
                             skipped_updates=skipped_n)
            # tag the round span with the ledger it booked (RoundCost
            # fields), so a trace row answers "what did this round cost"
            usp.set(latency_s=cost.latency_s, examples=cost.examples,
                    comm_bytes=cost.comm_bytes, ex_per_s=cost.ex_per_s,
                    dropped_clusters=dropped_n, skipped_updates=skipped_n,
                    accuracy=d.accuracy)
        tel.count("integrated.upgrades")
        tel.count("integrated.examples", examples)
        tel.observe("integrated.upgrade_s", dt)
        return -self.upgrade_cost, cost

    def produce(self, domain) -> tuple[float, RoundCost]:
        """Serve one round of inference requests.

        ``domain`` is one domain name or a sequence of names (mixed-domain
        demand): the round's ``serve_batch`` requests are split across the
        demanded domains and drained through the decode engine in ONE
        engine call against the AdapterBank — waves freely mix rows from
        different domains (per-row adapter_ids inside the batched
        multi-LoRA kernels), so per-round host work does not grow with the
        number of domains. Profit is booked from each row's own domain
        head via the same multi-tenant classify path. The RoundCost ledger
        records the engine's measured serving latency and token count, so
        ``cost.tok_per_s`` is the round's decode throughput; compute FLOPs
        are booked on EXECUTED decode steps (served + padded slot-steps),
        and ``cost.utilization`` exposes how much of that execution served
        real tokens under the engine's ragged continuous batching.
        """
        tel = self._telemetry()
        domains = [domain] if isinstance(domain, str) else list(domain)
        base, rem = divmod(self.serve_batch, len(domains))
        rows: list[tuple[str, np.ndarray, int]] = []   # (domain, tokens, label)
        for i, d in enumerate(domains):
            cnt = base + (1 if i < rem else 0)
            if cnt == 0:
                continue
            data = self.tasks[d].dataset(
                cnt, seed=self._record_base + len(self.records) + 123 + i)
            rows += [(d, np.asarray(data["tokens"][j]),
                      int(data["label"][j])) for j in range(cnt)]
        params = self.bank.serving_params(self.backbone)
        with tel.span("integrated.produce", domains=",".join(domains),
                      requests=len(rows)) as psp:
            t0 = time.perf_counter()
            for d, toks, _ in rows:                    # ONE drain, mixed waves
                self.engine.submit(toks, self.serve_gen, domain=d,
                                   deadline_s=self.deadline_s)
            _, stats = self.engine.run(params)
            # accuracy through the bank: rows grouped by prompt length only
            # (one classify call in the common equal-length case), each row
            # scored by its own domain's stacked head
            correct = 0
            by_len: dict[int, list[int]] = {}
            for j, (_, toks, _) in enumerate(rows):
                by_len.setdefault(len(toks), []).append(j)
            for idxs in by_len.values():
                batch = {"tokens": jnp.asarray(
                    np.stack([rows[j][1] for j in idxs]))}
                ids = self.bank.adapter_ids([rows[j][0] for j in idxs])
                logits = self._classify(params, batch, ids)
                pred = np.asarray(jnp.argmax(logits, -1))
                correct += int(np.sum(pred == np.asarray(
                    [rows[j][2] for j in idxs])))
            acc = correct / max(len(rows), 1)
            # latency covers the whole round (engine waves + the accuracy
            # forward); stats.wall_s is the pure decode-serving share
            nbytes = self.serve_batch * (self.cfg.peft.head_dim_out * 4
                                         + self.serve_gen * 4)
            executed = stats.tokens + stats.padded_tokens
            flops = 2.0 * self.cfg.active_param_count() * executed
            cost = RoundCost(time.perf_counter() - t0, flops,
                             self.cm.d2d.energy(nbytes),
                             nbytes, 0, tokens=stats.tokens,
                             padded_tokens=stats.padded_tokens,
                             timed_out=stats.timed_out,
                             drafted_tokens=stats.drafted,
                             accepted_tokens=stats.accepted)
            psp.set(latency_s=cost.latency_s, tokens=cost.tokens,
                    padded_tokens=cost.padded_tokens,
                    tok_per_s=cost.tok_per_s, utilization=cost.utilization,
                    timed_out=cost.timed_out, accuracy=acc)
        tel.count("integrated.produces")
        tel.observe("integrated.produce_s", cost.latency_s)
        return self.profit_scale * acc, cost

    # -- scheduling ----------------------------------------------------------
    def run(self, demand: Sequence[str],
            policy: Optional[Callable[[int, tuple], int]] = None
            ) -> list[RoundRecord]:
        """Execute a demand sequence under a policy (default: MLCP DP on the
        measured-accuracy value model)."""
        names = list(self.domains)
        didx = {n: i for i, n in enumerate(names)}
        if policy is None:
            # value model for the DP: expected profit at level l
            base = {n: self.domains[n].accuracy for n in names}
            lift = 0.25                       # measured typical per-round gain
            values = tuple(
                int(self.profit_scale * min(1.0, np.mean(list(base.values()))
                                            + lift * l)) for l in range(3))
            env = SchedulerEnv(demand=tuple(didx[d] for d in demand),
                               values=values,
                               upgrade_cost=int(self.upgrade_cost),
                               n_devices=len(names))
            policy = mlcp_policy(env)

        cum = 0.0
        levels = tuple(0 for _ in names)
        for r, dom in enumerate(demand):
            a = policy(r, levels)
            if a == len(names):
                profit, cost = self.produce(dom)
                action, target = "produce", dom
            else:
                target = names[a]
                profit, cost = self.upgrade(target)
                levels = tuple(min(l + 1, 2) if i == a else l
                               for i, l in enumerate(levels))
                action = "upgrade"
            cum += profit
            self.records.append(RoundRecord(
                r + 1, action, target, profit,
                self.domains[target].accuracy, cum, cost))
        return self.records

    # -- crash-safe persistence ---------------------------------------------
    def _ckpt_tree(self) -> dict:
        """The runtime's resumable state as one pytree: per-domain HFSL
        state (cluster adapters + opt + step counter), batch-bank cursors,
        bank versions, and round counters. The backbone and engine are
        re-derived from config+seed at construction, so they are NOT
        stored — restore() requires a same-config runtime."""
        doms = {}
        for n, d in self.domains.items():
            doms[n] = {
                "adapters_c": d.adapters_c,
                "opt": d.opt_state,
                "step": d.step,
                "level": jnp.asarray(d.level, jnp.int32),
                "accuracy": jnp.asarray(d.accuracy, jnp.float32),
                "bank_offset": jnp.asarray(self._banks[n].offset, jnp.int32),
                "bank_version": jnp.asarray(self.versions_of(n), jnp.int32),
            }
        return {"domains": doms,
                "rounds": jnp.asarray(
                    self._record_base + len(self.records), jnp.int32),
                "fault_round": jnp.asarray(self._fault_round, jnp.int32)}

    def versions_of(self, domain: str) -> int:
        return self.bank.versions[domain]

    def save(self, path: str) -> int:
        """Atomically checkpoint the runtime (checkpoint.io.save: temp file
        + os.replace — a crash mid-save keeps the previous file intact).
        Returns bytes written."""
        return ckpt_io.save(path, self._ckpt_tree())

    def restore(self, path: str) -> None:
        """Resume from a :meth:`save` checkpoint, step-for-step identically:
        HFSL step counters, batch-bank cursors, bank versions, and round
        counters all continue where the saved run left off. The runtime
        must be constructed with the same config/seed (the frozen backbone
        is re-derived, not stored)."""
        tree = ckpt_io.load(path, like=self._ckpt_tree())
        for n, saved in tree["domains"].items():
            d = self.domains[n]
            ac, opt, step = (saved["adapters_c"], saved["opt"], saved["step"])
            if self._state_sh is not None:       # back onto the round's mesh
                sh = self._state_sh
                ac = jax.device_put(ac, sh["adapters_c"])
                opt = jax.device_put(opt, sh["opt"])
                step = jax.device_put(step, sh["step"])
            d.adapters_c, d.opt_state, d.step = ac, opt, step
            d.level = int(saved["level"])
            d.accuracy = float(saved["accuracy"])
            self._banks[n].offset = int(saved["bank_offset"])
            # serve the restored consensus immediately; the version counter
            # is overwritten to the saved value (publish bumped it by one)
            self.bank.publish(n, self._consensus_adapters(n))
            self.bank.versions[n] = int(saved["bank_version"])
        self._record_base = int(tree["rounds"])
        self._fault_round = int(tree["fault_round"])
        self.records = []

    def total_profit(self) -> float:
        return self.records[-1].cumulative if self.records else 0.0


# The paper names the system GaisNet; the runtime IS the system, so export
# the name (notably for `GaisNet(mesh=...)`, the mesh-native entry point).
GaisNet = IntegratedRuntime
