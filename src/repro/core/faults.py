"""Seeded fault injection for the virtuous cycle (the chaos layer).

GaisNet's premise is fragmented edge compute over wireless links — a world
defined by dropout, stragglers, and lossy backhaul, not by the all-clusters
-always-survive assumption the happy path makes. This module is the single
source of truth for *when* things fail; every layer consumes it:

- **HFSL rounds** (core/hfsl.py): a per-round per-cluster participation
  mask (dropout + stragglers) threads through ``make_hfsl_round``'s scan —
  masked FedAvg aggregates only surviving clusters — and a per-cluster
  gradient-corruption mask drives the in-scan non-finite guard.
- **Knowledge relay** (core/relay.py): per-attempt link drops and in-flight
  payload corruption; the relay retries with capped exponential backoff and
  a CRC32 payload checksum rejects corrupted adapter deliveries.
- **Serving** (core/adapter_bank.py, launch/engine.py): publish validation
  + last-known-good rollback, per-request deadlines.

Every schedule is a pure function of ``(seed, coordinates)`` via
``np.random.SeedSequence``, so a plan replays the SAME faults regardless of
call order or how many other draws happened in between — chaos tests and
benchmarks are exactly reproducible. A default-constructed plan is all-off
(``active`` is False) and injects nothing.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Tuple

import jax
import numpy as np

# schedule namespaces (SeedSequence entropy words) — one per fault kind so
# e.g. the dropout draw for round r never aliases the straggler draw
_DROP, _STRAGGLE, _CORRUPT, _LINK, _PAYLOAD, _FLIP, _JITTER = range(7)

_RATES = ("dropout", "straggler", "grad_nan", "link_loss", "payload_corrupt")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Deterministic, replayable fault schedule.

    Rates are per-event probabilities: ``dropout``/``straggler``/``grad_nan``
    per (round, cluster); ``link_loss``/``payload_corrupt`` per (transfer,
    attempt). All must be in ``[0, 1)`` — a rate of 1.0 would make lossy
    transfers unterminating.
    """
    seed: int = 0
    dropout: float = 0.0          # P(cluster absent for a whole round)
    straggler: float = 0.0        # P(cluster misses the round's sync deadline)
    grad_nan: float = 0.0         # P(cluster's round updates go non-finite)
    link_loss: float = 0.0        # P(one relay transfer attempt is dropped)
    payload_corrupt: float = 0.0  # P(a delivered payload is bit-corrupted)

    def __post_init__(self):
        for f in _RATES:
            v = getattr(self, f)
            if not 0.0 <= v < 1.0:
                raise ValueError(f"FaultPlan.{f}={v} must be in [0, 1)")

    @property
    def active(self) -> bool:
        """False for the all-off plan — consumers take the exact happy path
        (bitwise-identical to running with no plan at all)."""
        return any(getattr(self, f) > 0.0 for f in _RATES)

    def _rng(self, *coords: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence((self.seed, *map(int, coords))))

    # -- HFSL round schedules ------------------------------------------------
    def participation(self, round_idx: int, n_clusters: int
                      ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Per-cluster presence for one round.

        Returns ``(mask, dropped, stragglers)`` — bool ``(n_clusters,)``
        arrays; ``mask`` True means the cluster trains and syncs this round.
        Stragglers are clusters that *would* have trained but miss the sync
        deadline — for a synchronous round both are excluded the same way
        (their state carries forward untouched), but they are reported
        separately so staleness-weighting policies can treat them
        differently later.
        """
        dropped = self._rng(_DROP, round_idx).random(n_clusters) < self.dropout
        stragglers = (self._rng(_STRAGGLE, round_idx).random(n_clusters)
                      < self.straggler) & ~dropped
        return ~(dropped | stragglers), dropped, stragglers

    def corrupt_mask(self, round_idx: int, n_clusters: int) -> np.ndarray:
        """Which clusters' updates get NaN-poisoned this round (bool (n,));
        drives hfsl's in-scan non-finite guard end-to-end."""
        return (self._rng(_CORRUPT, round_idx).random(n_clusters)
                < self.grad_nan)

    # -- relay link schedules ------------------------------------------------
    def link_drops(self, transfer_id: int, attempt: int) -> bool:
        """True if this (transfer, attempt) is lost on the wire."""
        return (self.link_loss > 0.0
                and self._rng(_LINK, transfer_id, attempt).random()
                < self.link_loss)

    def payload_corrupted(self, transfer_id: int, attempt: int) -> bool:
        """True if this attempt arrives but bit-corrupted (checksum bait)."""
        return (self.payload_corrupt > 0.0
                and self._rng(_PAYLOAD, transfer_id, attempt).random()
                < self.payload_corrupt)

    def retry_jitter(self, transfer_id: int, attempt: int) -> float:
        """Deterministic backoff jitter draw in [0, 1) for this (transfer,
        attempt). The relay scales its exponential backoff by ``1 + u``
        (multiplicative, so jittered backoff never undercuts the base
        delay) — de-synchronizing retry storms across transfers while
        keeping every replay of the same plan bitwise identical."""
        return float(self._rng(_JITTER, transfer_id, attempt).random())

    def corrupt_payload(self, tree, transfer_id: int, attempt: int):
        """The wire copy of ``tree`` with one byte of one leaf flipped —
        what a corrupted delivery actually hands the receiver. The XOR is
        guaranteed to change the byte, so :func:`payload_checksum` always
        catches it (the point is exercising the real checksum compare, not
        simulating its verdict)."""
        leaves, treedef = jax.tree.flatten(tree)
        r = self._rng(_FLIP, transfer_id, attempt)
        i = int(r.integers(len(leaves)))
        # np.array COPIES: device_get returns a read-only view of the jax
        # buffer, and the wire copy must be writable (and must not alias
        # the sender's live adapters)
        wire = np.array(jax.device_get(leaves[i]))
        buf = wire.view(np.uint8).reshape(-1)
        buf[int(r.integers(buf.size))] ^= 0xFF
        leaves = list(leaves)
        leaves[i] = wire
        return jax.tree.unflatten(treedef, leaves)


def payload_checksum(tree) -> int:
    """CRC32 over a pytree's structure, dtypes, shapes, and raw bytes —
    the relay's end-to-end wire check for adapter deliveries."""
    leaves, treedef = jax.tree.flatten(tree)
    c = zlib.crc32(repr(treedef).encode())
    for x in leaves:
        a = np.ascontiguousarray(np.asarray(jax.device_get(x)))
        c = zlib.crc32(str(a.dtype).encode(), c)
        c = zlib.crc32(np.asarray(a.shape, np.int64).tobytes(), c)
        c = zlib.crc32(a.tobytes(), c)
    return c


def leaf_checksums(tree) -> list:
    """Per-leaf CRC32 (dtype + shape + raw bytes), in flatten order.

    The relay's partial-retransmit unit: a corrupted delivery is rejected
    per LEAF, so only the leaves whose checksums mismatch are re-sent —
    one flipped byte in a 1 KB leaf no longer re-ships a 100 MB tree
    (``Ledger.retransmit_bytes`` books just the resent leaves)."""
    out = []
    for x in jax.tree.leaves(tree):
        a = np.ascontiguousarray(np.asarray(jax.device_get(x)))
        c = zlib.crc32(str(a.dtype).encode())
        c = zlib.crc32(np.asarray(a.shape, np.int64).tobytes(), c)
        c = zlib.crc32(a.tobytes(), c)
        out.append(c)
    return out


# The canonical all-off plan: schedules exist, nothing ever fires.
NO_FAULTS = FaultPlan()
