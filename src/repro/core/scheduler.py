"""Integrated fine-tuning-or-inference scheduling (paper §IV-C, §V-F).

The paper's toy economy: M edge models ("devices" a, b, c) serve M inference
services (A, B, C). Each GAI round serves exactly one request from a known
demand sequence; the scheduler either *produces* (run the requested
inference; profit = device's current value) or *upgrades* a device
(fine-tune; immediate profit = -cost, raises that device's future value).

Policies:
- **MLCP** (proposed): maximize long-term cumulative profit — exact DP over
  the remaining horizon (demand known, as in the paper's Table V), or value
  iteration for the stochastic-demand generalization.
- **MSIP**: greedy maximum short-term immediate profit.
- **RS**: uniform random action.

`paper_env()` + the three policies reproduce Table V / Fig 8 exactly
(benchmarks/table5_scheduler.py).
"""
from __future__ import annotations

import dataclasses
import functools
import itertools
from typing import Callable, Optional, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class SchedulerEnv:
    demand: tuple[int, ...]            # device index demanded per round
    values: tuple[int, ...] = (50, 75, 100)   # production value per level
    upgrade_cost: int = 50
    n_devices: int = 3

    @property
    def horizon(self) -> int:
        return len(self.demand)

    @property
    def max_level(self) -> int:
        return len(self.values) - 1


def paper_env() -> SchedulerEnv:
    """Table V: demand 1×A, 1×A, 1×B, 7×C."""
    return SchedulerEnv(demand=(0, 0, 1, 2, 2, 2, 2, 2, 2, 2))


@dataclasses.dataclass
class Record:
    round: int
    action: str                        # 'produce' | 'upgrade'
    device: int
    profit: int
    cumulative: int


# ---------------------------------------------------------------------------
# Policies: state = (round r, levels tuple); action int: 0..M-1 upgrade m,
# M = produce.
# ---------------------------------------------------------------------------

def mlcp_policy(env: SchedulerEnv) -> Callable[[int, tuple], int]:
    """Exact horizon DP (the proposed maximum-long-term-cumulative-profit)."""
    @functools.lru_cache(maxsize=None)
    def value(r: int, levels: tuple) -> tuple[int, int]:
        """-> (best total profit from round r, best action)."""
        if r == env.horizon:
            return 0, -1
        best, best_a = -10 ** 9, -1
        # produce
        dev = env.demand[r]
        p = env.values[levels[dev]]
        v = p + value(r + 1, levels)[0]
        if v > best:
            best, best_a = v, env.n_devices
        # upgrades
        for m in range(env.n_devices):
            if levels[m] >= env.max_level:
                continue
            nl = tuple(l + 1 if i == m else l for i, l in enumerate(levels))
            v = -env.upgrade_cost + value(r + 1, nl)[0]
            if v > best:
                best, best_a = v, m
        return best, best_a

    return lambda r, levels: value(r, levels)[1]


def msip_policy(env: SchedulerEnv) -> Callable[[int, tuple], int]:
    """Greedy: produce always beats paying an upgrade cost."""
    return lambda r, levels: env.n_devices


def rs_policy(env: SchedulerEnv, seed: int = 0) -> Callable[[int, tuple], int]:
    rng = np.random.default_rng(seed)
    return lambda r, levels: int(rng.integers(0, env.n_devices + 1))


def run_policy(env: SchedulerEnv, policy: Callable[[int, tuple], int]
               ) -> list[Record]:
    levels = tuple([0] * env.n_devices)
    cum = 0
    out = []
    for r in range(env.horizon):
        a = policy(r, levels)
        if a == env.n_devices:                       # produce
            dev = env.demand[r]
            profit = env.values[levels[dev]]
            action = "produce"
        else:
            dev = a
            profit = -env.upgrade_cost
            # an upgrade past max level burns the cost without effect
            # (random policies can pick it; found by hypothesis)
            levels = tuple(min(l + 1, env.max_level) if i == dev else l
                           for i, l in enumerate(levels))
            action = "upgrade"
        cum += profit
        out.append(Record(r + 1, action, dev, profit, cum))
    return out


def total_profit(records: Sequence[Record]) -> int:
    return records[-1].cumulative if records else 0


# ---------------------------------------------------------------------------
# Beyond-paper: stochastic demand via value iteration
# ---------------------------------------------------------------------------

def mlcp_value_iteration(env: SchedulerEnv, demand_probs: Sequence[float],
                         gamma: float = 0.95, iters: int = 200
                         ) -> Callable[[int, tuple], int]:
    """Stationary policy for unknown future demand (demand ~ Cat(p)).

    The paper assumes the demand sequence is known; real edge serving does
    not. Value iteration over (levels) with expected immediate reward."""
    p = np.asarray(demand_probs, float)
    p = p / p.sum()
    states = list(itertools.product(range(env.max_level + 1),
                                    repeat=env.n_devices))
    sidx = {s: i for i, s in enumerate(states)}
    V = np.zeros(len(states))
    for _ in range(iters):
        newV = np.empty_like(V)
        for s in states:
            i = sidx[s]
            prod = sum(p[d] * env.values[s[d]] for d in range(env.n_devices)) \
                + gamma * V[i]
            best = prod
            for m in range(env.n_devices):
                if s[m] >= env.max_level:
                    continue
                ns = tuple(l + 1 if j == m else l for j, l in enumerate(s))
                best = max(best, -env.upgrade_cost + gamma * V[sidx[ns]])
            newV[i] = best
        if np.max(np.abs(newV - V)) < 1e-9:
            V = newV
            break
        V = newV

    def policy(r: int, levels: tuple) -> int:
        i = sidx[levels]
        best_a, best_v = env.n_devices, \
            sum(p[d] * env.values[levels[d]] for d in range(env.n_devices)) \
            + gamma * V[i]
        for m in range(env.n_devices):
            if levels[m] >= env.max_level:
                continue
            ns = tuple(l + 1 if j == m else l for j, l in enumerate(levels))
            v = -env.upgrade_cost + gamma * V[sidx[ns]]
            if v > best_v:
                best_a, best_v = m, v
        return best_a

    return policy
