"""Parameter-efficient fine-tuning mechanics (paper §III-A.1).

The model API (models/model.py) already splits params into ``backbone`` /
``adapters``. This module provides the training-side mechanics around that
split:

- gradients and optimizer state exist *only* for the adapter subtree
  (``peft_value_and_grad``), the backbone being closed over as a constant —
  no backbone grads are ever materialized;
- full fine-tuning is the same entry point with ``trainable='all'`` (the
  paper's Fig 7 baseline);
- accounting helpers report trainable fraction and transport bytes (feeding
  the §III-A.2 parameter-efficient-inference ledger in core/comm.py).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Literal

import jax
import jax.numpy as jnp
import numpy as np

Trainable = Literal["adapters", "all", "backbone"]


def split(params: dict, trainable: Trainable = "adapters") -> tuple[dict, dict]:
    """-> (trainable_subtree, frozen_subtree)."""
    if trainable == "adapters":
        return {"adapters": params["adapters"]}, {"backbone": params["backbone"]}
    if trainable == "backbone":
        return {"backbone": params["backbone"]}, {"adapters": params["adapters"]}
    return params, {}


def merge(trainable: dict, frozen: dict) -> dict:
    return {**frozen, **trainable}


def peft_value_and_grad(loss_fn: Callable, trainable: Trainable = "adapters",
                        has_aux: bool = True) -> Callable:
    """value_and_grad over the trainable subtree only.

    loss_fn(params, *args) -> loss | (loss, aux).
    Returned fn(params, *args) -> ((loss, aux), grads_subtree).
    """
    def wrapped(params: dict, *args):
        t, f = split(params, trainable)

        def inner(t_, *a):
            return loss_fn(merge(t_, f), *a)

        return jax.value_and_grad(inner, has_aux=has_aux)(t, *args)

    return wrapped


def count_params(tree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def tree_bytes(tree) -> int:
    return sum(int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
               for x in jax.tree.leaves(tree))


def trainable_fraction(params: dict) -> float:
    """The paper's '<1% of model parameters' claim, measured."""
    a = count_params(params.get("adapters", {}))
    b = count_params(params.get("backbone", {}))
    return a / max(a + b, 1)


def merge_lora_into_backbone(params: dict, cfg) -> dict:
    """Bake LoRA deltas into frozen weights (deploy-time optimization).

    W' = W + scale * A @ B per target projection. Leaves prefix/state
    prompts untouched (they are runtime inputs, not weight deltas).
    Works on the stacked (L, ...) layout via einsum over the layer dim.
    """
    out = jax.tree.map(lambda x: x, params)      # shallow-ish copy
    scale = cfg.peft.lora_alpha / max(cfg.peft.lora_rank, 1)
    stack = out["adapters"].get("stack", {})
    name_map = {"q": "wq", "k": "wk", "v": "wv", "o": "wo"}
    for gname, group in stack.items():
        for sname, sub in group.items():
            lora = sub.get("lora")
            if not lora:
                continue
            tgt = out["backbone"]["layers"][gname][sname]
            blk = tgt.get("attn", tgt.get("mix"))
            for t, ab in lora.items():
                w = blk[name_map[t]]
                delta = scale * jnp.einsum("lkr,lrn->lkn",
                                           ab["a"].astype(jnp.float32),
                                           ab["b"].astype(jnp.float32))
                blk[name_map[t]] = (w.astype(jnp.float32) + delta).astype(w.dtype)
                ab["b"] = jnp.zeros_like(ab["b"])   # disarm runtime branch
    return out
