"""Unified host-side telemetry: counters, latency histograms, spans, traces.

The paper's whole argument is metric-driven (§III enumerates latency /
compute / energy / comm metrics for every workflow), but aggregate
per-round ledgers (core/comm.py::RoundCost, launch/engine.py::EngineStats)
can't answer the questions production serving is judged on: what is the
p99 time-to-first-token, how long did requests queue, what did the engine
actually execute and when. This module is the one instrument every tier
reports through:

- **Counters / gauges** — monotonically accumulated ints / last-written
  floats (``tel.count("relay.retries")``, ``tel.gauge("bank.slots", 8)``).
- **Log-bucketed latency histograms** — ``tel.observe("engine.ttft_s", dt)``
  records into geometric buckets (default 8 per decade), so p50/p95/p99
  come from bucket counts with bounded RELATIVE error (~±15% per bucket
  step) without ever storing samples: O(1) record, O(buckets) memory, no
  reservoir bias at the tail — the standard HDR-histogram trade.
- **Spans** — ``with tel.span("decode_segment", wave=3, rows=8):`` records
  a named interval on the monotonic clock (`time.perf_counter`), with
  nesting depth tracked per thread. ``span(...) as sp`` allows late
  attributes (``sp.set(tokens=n)``) for values only known at exit.
- **Export** — :meth:`Telemetry.export_trace` writes Chrome trace-event
  JSON (open in Perfetto / chrome://tracing: one timeline row per thread,
  spans nested by enclosure), :meth:`Telemetry.snapshot` returns a plain
  dict (counters + gauges + histogram summaries), :meth:`Telemetry.report`
  a human-readable text block.

**Overhead discipline**: the module-level singleton defaults OFF, and every
disabled call is a guard-and-return — ``span()`` hands back one shared
no-op context manager (zero allocations on the hot path), ``observe`` /
``count`` return before touching any dict. Enabling is explicit
(:func:`enable`), per-component ``tel=`` arguments override the singleton.
``benchmarks/telemetry_bench.py`` asserts the disabled path is
indistinguishable from no instrumentation at all.

Host-side only by design: spans bracket *dispatches* (what the host asked
the device to do and when the result synced), not on-device kernel time —
that is what roofline/profile tooling is for. Not thread-safe for
concurrent writers beyond CPython atomicity; the engines are host-serial.
"""
from __future__ import annotations

import dataclasses
import json
import math
import threading
import time
from typing import Any, Dict, List, Optional

# geometric bucket growth: 8 buckets per decade resolves percentiles to
# ~±15% relative error, plenty for latency SLOs (p99 = 12ms vs 13ms is
# noise; 12ms vs 120ms is the signal) at ~100 buckets across ns..minutes
_GROWTH = 10.0 ** (1.0 / 8.0)
_MIN_VALUE = 1e-9                      # 1ns floor: below it, bucket 0


class Histogram:
    """Log-bucketed scalar histogram: O(1) record, percentile from counts.

    Bucket ``i`` covers ``[min_value * growth**i, min_value * growth**(i+1))``;
    a recorded value increments its bucket count, so quantiles are read off
    the cumulative bucket counts and reported as the bucket's geometric
    midpoint — bounded relative error, no stored samples, no tail bias.
    """
    __slots__ = ("counts", "n", "total", "vmin", "vmax", "_log_g")

    def __init__(self) -> None:
        self.counts: Dict[int, int] = {}
        self.n = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf
        self._log_g = math.log(_GROWTH)

    def record(self, value: float, n: int = 1) -> None:
        """Record ``value`` with multiplicity ``n`` (e.g. one per-token
        latency observed ``tokens`` times in one decode segment)."""
        v = float(value)
        idx = 0 if v <= _MIN_VALUE else int(
            math.log(v / _MIN_VALUE) / self._log_g) + 1
        self.counts[idx] = self.counts.get(idx, 0) + n
        self.n += n
        self.total += v * n
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v

    def _bucket_value(self, idx: int) -> float:
        if idx == 0:
            return _MIN_VALUE
        # geometric midpoint of [g**(i-1), g**i) * min_value
        return _MIN_VALUE * _GROWTH ** (idx - 0.5)

    def percentile(self, q: float) -> float:
        """Nearest-rank percentile (q in [0, 100]) from bucket counts,
        clamped into the observed [min, max] so tiny histograms don't
        report a bucket edge outside what was ever recorded."""
        if self.n == 0:
            return 0.0
        rank = max(1, math.ceil(q / 100.0 * self.n))
        seen = 0
        for idx in sorted(self.counts):
            seen += self.counts[idx]
            if seen >= rank:
                return min(max(self._bucket_value(idx), self.vmin), self.vmax)
        return self.vmax

    @property
    def mean(self) -> float:
        return self.total / self.n if self.n else 0.0

    def summary(self) -> dict:
        """Plain-dict summary (snapshot / EngineStats embedding)."""
        return {"count": self.n, "sum": self.total, "mean": self.mean,
                "min": self.vmin if self.n else 0.0,
                "max": self.vmax if self.n else 0.0,
                "p50": self.percentile(50), "p95": self.percentile(95),
                "p99": self.percentile(99)}


@dataclasses.dataclass
class SpanRecord:
    """One completed span: monotonic start offset + duration (seconds,
    relative to the Telemetry epoch), thread id, nesting depth, attrs."""
    name: str
    t0: float
    dur: float
    tid: int
    depth: int
    args: dict


class _NoopSpan:
    """Shared do-nothing context manager: the disabled-mode hot path."""
    __slots__ = ()

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc) -> None:
        return None

    def set(self, **args) -> None:
        return None


_NOOP_SPAN = _NoopSpan()


class _Span:
    """Live span handle; records itself into the owning Telemetry on exit."""
    __slots__ = ("_tel", "name", "args", "_t0", "_depth")

    def __init__(self, tel: "Telemetry", name: str, args: dict) -> None:
        self._tel = tel
        self.name = name
        self.args = args

    def set(self, **args) -> None:
        """Attach attributes discovered mid-span (e.g. tokens served)."""
        self.args.update(args)

    def __enter__(self) -> "_Span":
        local = self._tel._local
        self._depth = getattr(local, "depth", 0)
        local.depth = self._depth + 1
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        t1 = time.perf_counter()
        tel = self._tel
        tel._local.depth = self._depth
        tel.spans.append(SpanRecord(
            self.name, self._t0 - tel._epoch, t1 - self._t0,
            threading.get_ident(), self._depth, self.args))


class Telemetry:
    """Registry of counters / gauges / histograms + span recorder.

    One instance per observed subsystem is fine (the runtime threads one
    through engine/bank/relay), but the common path is the module-level
    singleton: components resolve :func:`get` at call time, so
    ``telemetry.enable()`` before a run instruments everything with no
    construction-order coupling. Disabled (the default for the singleton)
    every method is a guard-and-return no-op.
    """

    def __init__(self, enabled: bool = True) -> None:
        self.enabled = enabled
        self.counters: Dict[str, float] = {}
        self.gauges: Dict[str, float] = {}
        self.hists: Dict[str, Histogram] = {}
        self.spans: List[SpanRecord] = []
        self._local = threading.local()
        self._epoch = time.perf_counter()
        # Perfetto needs a wall-clock epoch; never used for durations.
        self._epoch_wall = time.time()    # tracelint: ignore[R3] trace epoch

    # -- recording ----------------------------------------------------------
    def count(self, name: str, n: float = 1) -> None:
        if not self.enabled:
            return
        self.counters[name] = self.counters.get(name, 0) + n

    def gauge(self, name: str, value: float) -> None:
        if not self.enabled:
            return
        self.gauges[name] = float(value)

    def observe(self, name: str, value: float, n: int = 1) -> None:
        if not self.enabled:
            return
        h = self.hists.get(name)
        if h is None:
            h = self.hists[name] = Histogram()
        h.record(value, n)

    def span(self, name: str, **args):
        if not self.enabled:
            return _NOOP_SPAN
        return _Span(self, name, args)

    def record_span(self, name: str, t0: float, t1: float, **args) -> None:
        """Record an interval measured externally (``time.perf_counter``
        values) — e.g. a request lifecycle whose start predates the drain
        span. Depth 0: rendered as a top-level track row."""
        if not self.enabled:
            return
        self.spans.append(SpanRecord(name, t0 - self._epoch, t1 - t0,
                                     threading.get_ident(), 0, args))

    def reset(self) -> None:
        """Drop all recorded data (epoch restarts; enabled flag kept)."""
        self.counters.clear()
        self.gauges.clear()
        self.hists.clear()
        self.spans.clear()
        self._epoch = time.perf_counter()
        self._epoch_wall = time.time()    # tracelint: ignore[R3] trace epoch

    # -- reading ------------------------------------------------------------
    def hist_summary(self, name: str) -> Optional[dict]:
        h = self.hists.get(name)
        return h.summary() if h is not None else None

    def snapshot(self) -> dict:
        """Everything as one plain dict (JSON-serializable)."""
        return {
            "enabled": self.enabled,
            "epoch_unix_s": self._epoch_wall,
            "counters": dict(self.counters),
            "gauges": dict(self.gauges),
            "histograms": {k: h.summary() for k, h in self.hists.items()},
            "spans": len(self.spans),
        }

    def report(self) -> str:
        """Human-readable text block (the CLI --metrics-out companion)."""
        lines = [f"telemetry: {len(self.spans)} spans, "
                 f"{len(self.counters)} counters, {len(self.hists)} hists"]
        for k in sorted(self.counters):
            lines.append(f"  counter {k:<32} {self.counters[k]:g}")
        for k in sorted(self.gauges):
            lines.append(f"  gauge   {k:<32} {self.gauges[k]:g}")
        for k in sorted(self.hists):
            s = self.hists[k].summary()
            lines.append(
                f"  hist    {k:<32} n={s['count']} mean={s['mean']:.3e} "
                f"p50={s['p50']:.3e} p95={s['p95']:.3e} p99={s['p99']:.3e}")
        return "\n".join(lines)

    # -- trace export -------------------------------------------------------
    def trace_events(self, *, pid: int = 1) -> List[dict]:
        """Chrome trace-event list: one complete ("X") event per span
        (microsecond timestamps relative to the telemetry epoch), plus
        counter ("C") events at the trace end so totals show as tracks."""
        events: List[dict] = [{
            "ph": "M", "pid": pid, "tid": 0, "name": "process_name",
            "args": {"name": "repro-telemetry"}}]
        tids = {}
        t_end = 0.0
        for sp in self.spans:
            tid = tids.setdefault(sp.tid, len(tids) + 1)
            events.append({
                "name": sp.name, "cat": sp.name.split(".")[0], "ph": "X",
                "ts": sp.t0 * 1e6, "dur": sp.dur * 1e6,
                "pid": pid, "tid": tid,
                "args": {k: _jsonable(v) for k, v in sp.args.items()}})
            t_end = max(t_end, sp.t0 + sp.dur)
        for name, value in sorted(self.counters.items()):
            events.append({"name": name, "ph": "C", "ts": t_end * 1e6,
                           "pid": pid, "tid": 0, "args": {"value": value}})
        return events

    def export_trace(self, path: str) -> int:
        """Write the Perfetto/chrome://tracing JSON file; returns the
        number of span events exported."""
        events = self.trace_events()
        with open(path, "w") as f:
            json.dump({"traceEvents": events, "displayTimeUnit": "ms",
                       "metadata": {"epoch_unix_s": self._epoch_wall}}, f)
        return len(self.spans)

    def export_metrics(self, path: str) -> None:
        """Write :meth:`snapshot` as JSON."""
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=2, sort_keys=True)


def _jsonable(v: Any):
    """Span attrs may carry numpy scalars; coerce to plain JSON types."""
    if isinstance(v, (str, bool, int, float)) or v is None:
        return v
    try:
        return float(v)
    except (TypeError, ValueError):
        return str(v)


# ---------------------------------------------------------------------------
# Module-level singleton (defaults OFF: zero-overhead unless asked for)
# ---------------------------------------------------------------------------

_GLOBAL = Telemetry(enabled=False)


def get() -> Telemetry:
    """The process-wide telemetry instance (disabled until :func:`enable`).
    Instrumented components resolve this at CALL time, so enabling after
    construction still instruments them."""
    return _GLOBAL


def enable(fresh: bool = True) -> Telemetry:
    """Switch the global instance on (optionally resetting recorded data);
    returns it for chaining (``tel = telemetry.enable()``)."""
    if fresh:
        _GLOBAL.reset()
    _GLOBAL.enabled = True
    return _GLOBAL


def disable() -> Telemetry:
    """Switch the global instance off (recorded data kept for export)."""
    _GLOBAL.enabled = False
    return _GLOBAL
