"""Communication / energy / latency cost model (paper §III-C.2, §III-D.2).

The paper enumerates six metrics for its workflows but never prices them;
this module does, for both the wireless topology the paper assumes (D2D +
client-server links, 6G-ish defaults) and the TPU ICI topology the
production system runs on. All byte counts come from real pytrees or SL
traces — nothing hardcoded.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.sl_pipeline import SLTrace

# v5e constants (per spec)
TPU_PEAK_FLOPS = 197e12        # bf16 / chip
TPU_HBM_BW = 819e9             # B/s
TPU_ICI_BW = 50e9              # B/s per link


@dataclasses.dataclass(frozen=True)
class LinkModel:
    """One link class: rate (B/s) + energy per byte (J/B)."""
    rate: float
    energy_per_byte: float

    def latency(self, nbytes: float) -> float:
        return nbytes / self.rate

    def energy(self, nbytes: float) -> float:
        return nbytes * self.energy_per_byte


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Topology prices. Defaults: 6G-ish wireless edge (paper's world)."""
    d2d: LinkModel = LinkModel(rate=250e6 / 8, energy_per_byte=40e-9)
    cs: LinkModel = LinkModel(rate=100e6 / 8, energy_per_byte=80e-9)   # client<->server
    backhaul: LinkModel = LinkModel(rate=10e9 / 8, energy_per_byte=5e-9)
    client_flops: float = 10e12            # edge-device peak (RTX4060-ish)
    client_joules_per_flop: float = 2e-11

    @staticmethod
    def tpu() -> "CostModel":
        return CostModel(
            d2d=LinkModel(TPU_ICI_BW, 1e-10),
            cs=LinkModel(TPU_ICI_BW, 1e-10),
            backhaul=LinkModel(4 * TPU_ICI_BW, 1e-10),
            client_flops=TPU_PEAK_FLOPS,
            client_joules_per_flop=1e-12,
        )


@dataclasses.dataclass
class RoundCost:
    """The paper's metric set for one fine-tuning round / inference request.

    ``tokens`` counts decode tokens served during the round (0 for
    fine-tuning rounds); with ``latency_s`` it yields the measured serving
    throughput (:attr:`tok_per_s`). ``padded_tokens`` counts decode
    slot-steps the round EXECUTED but did not serve (retired or empty
    batch slots riding along in a wave) — :attr:`utilization` is then the
    real accelerator efficiency, which is what compute/energy should be
    priced on, not the served-token rate. ``examples`` mirrors ``tokens``
    for the fine-tuning service: training examples consumed during the
    round (0 for serving rounds), yielding the measured fine-tuning
    throughput (:attr:`ex_per_s`).

    The fault-tolerance counters ledger how much of the round degraded
    instead of failing (core/faults.py): ``dropped_clusters`` counts
    cluster-rounds lost to dropout/stragglers, ``skipped_updates`` counts
    in-scan non-finite cluster updates the masked round guarded out,
    ``retries``/``retransmit_bytes`` meter lossy-relay retransmissions
    (``comm_bytes`` includes every attempt's bytes on the wire;
    ``retransmit_bytes`` is the share beyond the first attempt), and
    ``timed_out`` counts requests the engine retired at their deadline.

    Speculative serving rounds (core/spec_decode.py) additionally book
    ``drafted_tokens`` (edge-drafter proposals) vs ``accepted_tokens``
    (proposals the target's verify pass committed):
    :attr:`acceptance_rate` is then the measured draft quality that the
    round's >1 tokens-per-verify-pass speedup rests on."""
    latency_s: float
    compute_flops: float
    energy_j: float
    comm_bytes: int
    memory_bytes: int
    tokens: int = 0
    examples: int = 0
    padded_tokens: int = 0
    dropped_clusters: int = 0
    skipped_updates: int = 0
    retries: int = 0
    retransmit_bytes: int = 0
    timed_out: int = 0
    drafted_tokens: int = 0
    accepted_tokens: int = 0

    @property
    def tok_per_s(self) -> float:
        return self.tokens / self.latency_s if self.latency_s > 0 else 0.0

    @property
    def ex_per_s(self) -> float:
        return self.examples / self.latency_s if self.latency_s > 0 else 0.0

    @property
    def utilization(self) -> float:
        """Served fraction of executed decode slot-steps (1.0 = no waste)."""
        total = self.tokens + self.padded_tokens
        return self.tokens / total if total else 1.0

    # every field is summed except the max-reduced ones below: peak memory
    # over a sequence of rounds is the max of the per-round peaks, not a sum
    _MAX_FIELDS = ("memory_bytes",)

    def __add__(self, o: "RoundCost") -> "RoundCost":
        # field-wise (never positional): a field appended to the dataclass
        # is automatically summed — a positional rebuild would silently
        # shift values into the wrong slots (tests/test_core.py pins this)
        kw = {}
        for f in dataclasses.fields(self):
            a, b = getattr(self, f.name), getattr(o, f.name)
            kw[f.name] = max(a, b) if f.name in self._MAX_FIELDS else a + b
        return RoundCost(**kw)

    @property
    def acceptance_rate(self) -> float:
        """Committed fraction of drafted tokens (speculative serving)."""
        return (self.accepted_tokens / self.drafted_tokens
                if self.drafted_tokens else 0.0)


def sl_round_cost(trace: SLTrace, cm: CostModel, *,
                  model_delivery_bytes: int = 0,
                  upload_bytes: int = 0) -> RoundCost:
    """Cost of one SL pass (fine-tuning if trace.gradient_bytes > 0).

    Serial chain: compute latencies add up (the paper's serial D2D relay);
    each hop pays D2D latency; delivery/upload pay CS latency.
    """
    compute_lat = sum(f / cm.client_flops for f in trace.per_client_flops)
    d2d_bytes = trace.smashed_bytes + trace.gradient_bytes + trace.feedback_bytes
    comm_lat = cm.d2d.latency(d2d_bytes) \
        + cm.cs.latency(model_delivery_bytes + upload_bytes)
    flops = float(sum(trace.per_client_flops))
    energy = flops * cm.client_joules_per_flop + cm.d2d.energy(d2d_bytes) \
        + cm.cs.energy(model_delivery_bytes + upload_bytes)
    return RoundCost(
        latency_s=compute_lat + comm_lat,
        compute_flops=flops,
        energy_j=energy,
        comm_bytes=d2d_bytes + model_delivery_bytes + upload_bytes,
        memory_bytes=trace.peak_activation_bytes,
    )


def transfer_cost(nbytes: int, link: LinkModel) -> RoundCost:
    return RoundCost(link.latency(nbytes), 0.0, link.energy(nbytes),
                     nbytes, 0)
