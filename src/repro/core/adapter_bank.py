"""Device-resident multi-tenant adapter bank (the serving twin of BatchBank).

GaisNet's layout is ONE shared frozen FM with many per-domain adapter sets
(paper §III-B, Fig 3). Single-tenant serving assembles a merged param tree
per domain on the host and drains the decode engine once per domain; the
bank instead keeps EVERY domain's adapters resident on device in one
stacked pytree so a single engine wave mixes rows from different domains
(S-LoRA/Punica-style multi-tenant serving):

- **Serving layout**: leaves under the ``stack`` subtree gain an
  ``n_slots`` dim *after* the scanned layer dim — ``(L, n_slots, ...)`` —
  so the model's layer scan hands each layer the whole slot stack and the
  batched multi-LoRA kernel (kernels/lora_bgmv.py) / per-row gathers select
  by ``adapter_ids``. All other leaves (e.g. the classification ``head``)
  are slot-leading ``(n_slots, ...)``.
- **publish(domain, adapters)**: one jitted ``dynamic_update_slice`` at the
  domain's slot — no host transfer, no recompile (the slot index is a
  traced scalar), visible to the very next wave. Each publish bumps the
  domain's version, mirroring KnowledgeRelay's edge versioning.
- **snapshot(domain)**: the training-side acquire — slices one domain's
  adapter tree back out (e.g. to seed an HFSL round or a parity check).

The bank never holds the backbone: :meth:`serving_params` pairs the shared
frozen backbone with the stacked adapters per wave.
"""
from __future__ import annotations

from typing import Dict, Iterable, Sequence

import jax
import jax.numpy as jnp


def _slot_axis(key: str) -> int:
    # 'stack' leaves keep their scanned layer dim leading; everything else
    # (head, future flat adapters) stacks slot-first.
    return 1 if key == "stack" else 0


def _publish(stacked: dict, new: dict, slot: jax.Array) -> dict:
    out = {}
    for key in stacked:
        axis = _slot_axis(key)
        out[key] = jax.tree.map(
            lambda cur, add: jax.lax.dynamic_update_slice_in_dim(
                cur, jnp.expand_dims(add.astype(cur.dtype), axis), slot,
                axis=axis),
            stacked[key], new[key])
    return out


def _snapshot(stacked: dict, slot: jax.Array) -> dict:
    out = {}
    for key in stacked:
        axis = _slot_axis(key)
        out[key] = jax.tree.map(
            lambda cur: jax.lax.dynamic_index_in_dim(cur, slot, axis=axis,
                                                     keepdims=False),
            stacked[key])
    return out


_publish_jit = jax.jit(_publish)
_snapshot_jit = jax.jit(_snapshot)


class AdapterBank:
    """Stacked per-domain adapter store with slot-indexed publish/serve."""

    def __init__(self, domains: Sequence[str], stacked: dict):
        self.domains = tuple(domains)
        self._slot = {d: i for i, d in enumerate(self.domains)}
        self.stacked = stacked
        self.versions: Dict[str, int] = {d: 0 for d in self.domains}

    @classmethod
    def create(cls, adapters_by_domain: Dict[str, dict]) -> "AdapterBank":
        """Stack one adapter tree per domain into the serving layout."""
        domains = list(adapters_by_domain)
        trees = [adapters_by_domain[d] for d in domains]
        stacked = {}
        for key in trees[0]:
            axis = _slot_axis(key)
            stacked[key] = jax.tree.map(
                lambda *leaves: jnp.stack(leaves, axis=axis),
                *(t[key] for t in trees))
        return cls(domains, stacked)

    # -- addressing ---------------------------------------------------------
    @property
    def n_slots(self) -> int:
        return len(self.domains)

    def slot(self, domain: str) -> int:
        if domain not in self._slot:
            raise KeyError(
                f"domain {domain!r} has no adapter slot "
                f"(known: {list(self.domains)})")
        return self._slot[domain]

    def adapter_ids(self, domains: Iterable[str]) -> jax.Array:
        """Per-row slot ids for a mixed-domain batch."""
        return jnp.asarray([self.slot(d) for d in domains], jnp.int32)

    def version(self, domain: str) -> int:
        return self.versions[domain]

    # -- publish / acquire --------------------------------------------------
    def publish(self, domain: str, adapters: dict) -> None:
        """Hot-swap one domain's adapters in place (jitted update at the
        slot; the next wave that reads :attr:`stacked` serves the new
        version — no stale reads across waves)."""
        slot = jnp.asarray(self.slot(domain), jnp.int32)
        self.stacked = _publish_jit(self.stacked, adapters, slot)
        self.versions[domain] += 1

    def snapshot(self, domain: str) -> dict:
        """Slice one domain's adapter tree out of the bank (training-side
        acquire; also the per-domain baseline for parity tests)."""
        slot = jnp.asarray(self.slot(domain), jnp.int32)
        return _snapshot_jit(self.stacked, slot)

    # -- serving ------------------------------------------------------------
    def serving_params(self, backbone: dict) -> dict:
        """Param tree for the multi-tenant serving/classify path."""
        return {"backbone": backbone, "adapters": self.stacked}
