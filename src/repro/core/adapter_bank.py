"""Device-resident multi-tenant adapter bank (the serving twin of BatchBank).

GaisNet's layout is ONE shared frozen FM with many per-domain adapter sets
(paper §III-B, Fig 3). Single-tenant serving assembles a merged param tree
per domain on the host and drains the decode engine once per domain; the
bank instead keeps EVERY domain's adapters resident on device in one
stacked pytree so a single engine wave mixes rows from different domains
(S-LoRA/Punica-style multi-tenant serving):

- **Serving layout**: leaves under the ``stack`` subtree gain an
  ``n_slots`` dim *after* the scanned layer dim — ``(L, n_slots, ...)`` —
  so the model's layer scan hands each layer the whole slot stack and the
  batched multi-LoRA kernel (kernels/lora_bgmv.py) / per-row gathers select
  by ``adapter_ids``. All other leaves (e.g. the classification ``head``)
  are slot-leading ``(n_slots, ...)``.
- **publish(domain, adapters)**: one jitted ``dynamic_update_slice`` at the
  domain's slot — no host transfer, no recompile (the slot index is a
  traced scalar), visible to the very next wave. Each publish bumps the
  domain's version, mirroring KnowledgeRelay's edge versioning.
- **snapshot(domain)**: the training-side acquire — slices one domain's
  adapter tree back out (e.g. to seed an HFSL round or a parity check).

The bank never holds the backbone: :meth:`serving_params` pairs the shared
frozen backbone with the stacked adapters per wave.

Constructed with a ``mesh``, the bank is **slot-sharded**: every stacked
leaf's ``n_slots`` dim is placed on the mesh's (`pod`, `data`) axes (the
``slots`` rule in sharding/rules.py) — slot-parallel multi-tenant serving,
where each data slice owns a subset of tenant slots and a publish's
``dynamic_update_slice`` only writes the owning shard. Publish pins its
out_shardings to the same placement, so the bank layout is stable across
hot-swaps (no creeping resharding round over round).
"""
from __future__ import annotations

import functools
from typing import Dict, Iterable, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import telemetry
from repro.sharding.rules import dim_sharding


def _slot_axis(key: str) -> int:
    # 'stack' leaves keep their scanned layer dim leading; everything else
    # (head, future flat adapters) stacks slot-first.
    return 1 if key == "stack" else 0


def _publish(stacked: dict, new: dict, slot: jax.Array) -> dict:
    out = {}
    for key in stacked:
        axis = _slot_axis(key)
        out[key] = jax.tree.map(
            lambda cur, add: jax.lax.dynamic_update_slice_in_dim(
                cur, jnp.expand_dims(add.astype(cur.dtype), axis), slot,
                axis=axis),
            stacked[key], new[key])
    return out


def _snapshot(stacked: dict, slot: jax.Array) -> dict:
    out = {}
    for key in stacked:
        axis = _slot_axis(key)
        out[key] = jax.tree.map(
            lambda cur: jax.lax.dynamic_index_in_dim(cur, slot, axis=axis,
                                                     keepdims=False),
            stacked[key])
    return out


# publish DONATES the stacked bank: the hot-swap is a dynamic_update_slice,
# so with donation XLA updates the resident buffers in place instead of
# copying the whole bank per publish (a non-donated publish doubles bank
# memory and defeats the "jitted in-place slot update" this class exists
# for). The old `stacked` reference is invalidated by each publish —
# readers must re-read the attribute, which the engine does per dispatch.
# _snapshot must NOT donate: it is a pure read that leaves the bank
# serving. Module-level so every mesh-less bank shares one compile cache;
# sharded banks build a per-instance publish that additionally pins
# out_shardings (the slot placement survives the swap).
_publish_jit = jax.jit(_publish, donate_argnums=(0,))
_snapshot_jit = jax.jit(_snapshot)


@jax.jit
def _all_finite(tree) -> jax.Array:
    """Scalar bool: every leaf of ``tree`` is finite (publish validation)."""
    return functools.reduce(
        jnp.logical_and,
        [jnp.all(jnp.isfinite(x.astype(jnp.float32)))
         for x in jax.tree.leaves(tree)])


class AdapterBank:
    """Stacked per-domain adapter store with slot-indexed publish/serve."""

    def __init__(self, domains: Sequence[str], stacked: dict, *,
                 mesh=None, rules: Optional[dict] = None):
        self.domains = tuple(domains)
        self._slot = {d: i for i, d in enumerate(self.domains)}
        self.mesh = mesh
        self._publish_jit = _publish_jit
        if mesh is not None:
            sh = self.shardings(stacked, mesh, rules)
            stacked = jax.device_put(stacked, sh)
            self._publish_jit = jax.jit(_publish, donate_argnums=(0,),
                                        out_shardings=sh)
        self.stacked = stacked
        self.versions: Dict[str, int] = {d: 0 for d in self.domains}
        # last-known-good serving copies: per-domain snapshot of the slot
        # as it was BEFORE the most recent validated publish, so a poisoned
        # round can be rolled back without ever re-validating old state
        self._lkg: Dict[str, dict] = {}
        self._lkg_version: Dict[str, int] = {}
        self.rollbacks: Dict[str, int] = {d: 0 for d in self.domains}

    @staticmethod
    def shardings(stacked: dict, mesh, rules: Optional[dict] = None):
        """NamedSharding tree: each leaf's slot dim on the `slots` axes."""
        def sub(key):
            axis = _slot_axis(key)
            n = jax.tree.leaves(stacked[key])[0].shape[axis]
            sh = dim_sharding(mesh, n, "slots", index=axis, rules=rules)
            return jax.tree.map(lambda _: sh, stacked[key])
        return {key: sub(key) for key in stacked}

    @classmethod
    def create(cls, adapters_by_domain: Dict[str, dict], *,
               mesh=None, rules: Optional[dict] = None) -> "AdapterBank":
        """Stack one adapter tree per domain into the serving layout (with
        a ``mesh``: slot-sharded over its `data` axis)."""
        domains = list(adapters_by_domain)
        trees = [adapters_by_domain[d] for d in domains]
        stacked = {}
        for key in trees[0]:
            axis = _slot_axis(key)
            stacked[key] = jax.tree.map(
                lambda *leaves: jnp.stack(leaves, axis=axis),
                *(t[key] for t in trees))
        return cls(domains, stacked, mesh=mesh, rules=rules)

    # -- addressing ---------------------------------------------------------
    @property
    def n_slots(self) -> int:
        return len(self.domains)

    def slot(self, domain: str) -> int:
        if domain not in self._slot:
            raise KeyError(
                f"domain {domain!r} has no adapter slot "
                f"(known: {list(self.domains)})")
        return self._slot[domain]

    def adapter_ids(self, domains: Iterable[str]) -> jax.Array:
        """Per-row slot ids for a mixed-domain batch."""
        return jnp.asarray([self.slot(d) for d in domains], jnp.int32)

    def version(self, domain: str) -> int:
        return self.versions[domain]

    # -- publish / acquire --------------------------------------------------
    def validate(self, domain: str, adapters: dict) -> None:
        """Reject a payload that must never reach live traffic: wrong tree
        structure, wrong per-leaf shape (vs the slot it would overwrite),
        or any non-finite value. Raises ``ValueError``; a passing payload
        returns silently. One device reduction for finiteness — no per-leaf
        host sync."""
        slot = self.slot(domain)           # KeyError on unknown domain
        del slot
        for key in self.stacked:
            if key not in adapters:
                raise ValueError(
                    f"publish({domain!r}): payload missing subtree {key!r}")
            axis = _slot_axis(key)
            cur_leaves = jax.tree.leaves(self.stacked[key])
            new_leaves = jax.tree.leaves(adapters[key])
            if len(cur_leaves) != len(new_leaves):
                raise ValueError(
                    f"publish({domain!r}): payload subtree {key!r} has "
                    f"{len(new_leaves)} leaves, slot has {len(cur_leaves)}")
            for cur, new in zip(cur_leaves, new_leaves):
                want = cur.shape[:axis] + cur.shape[axis + 1:]
                if tuple(new.shape) != tuple(want):
                    raise ValueError(
                        f"publish({domain!r}): leaf shape {tuple(new.shape)} "
                        f"!= slot shape {tuple(want)} in subtree {key!r}")
        if not bool(_all_finite(adapters)):
            raise ValueError(
                f"publish({domain!r}): payload contains non-finite values")

    def publish(self, domain: str, adapters: dict, *,
                validate: bool = True) -> None:
        """Hot-swap one domain's adapters in place (jitted, DONATED update
        at the slot — the resident bank buffers are reused, never copied;
        the next wave that reads :attr:`stacked` serves the new version —
        no stale reads across waves). Holding a pre-publish reference to
        ``stacked`` and using it after the publish is an error (the buffer
        is donated); re-read the attribute per dispatch.

        With ``validate`` (the default), the payload is checked first
        (:meth:`validate`) and the outgoing slot contents are kept as the
        domain's last-known-good — :meth:`rollback` restores them if the
        new version turns out bad downstream. A rejected publish raises
        ``ValueError`` and leaves the bank serving the current version."""
        tel = telemetry.get()
        with tel.span("bank.publish", domain=domain,
                      validate=validate) as sp:
            if validate:
                try:
                    self.validate(domain, adapters)
                except ValueError:
                    tel.count("bank.publish_rejects")
                    sp.set(rejected=True)
                    raise
                # snapshot BEFORE the donating publish: _snapshot_jit
                # returns fresh buffers, so the LKG copy survives donation
                self._lkg[domain] = self.snapshot(domain)
                self._lkg_version[domain] = self.versions[domain]
            slot = jnp.asarray(self.slot(domain), jnp.int32)
            self.stacked = self._publish_jit(self.stacked, adapters, slot)
            self.versions[domain] += 1
            sp.set(version=self.versions[domain])
        tel.count("bank.publishes")

    def rollback(self, domain: str) -> int:
        """Re-publish the domain's last-known-good adapters (the slot
        contents before its most recent validated publish). Returns the
        version the slot is rolled back TO; raises ``ValueError`` if the
        domain has never had a validated publish. Idempotent: the LKG copy
        survives the rollback, so repeated calls republish the same state."""
        if domain not in self._lkg:
            raise ValueError(
                f"rollback({domain!r}): no last-known-good recorded "
                "(no validated publish yet)")
        # LKG was already validated when it served; publish it unvalidated
        # so rollback can't itself be rejected
        tel = telemetry.get()
        with tel.span("bank.rollback", domain=domain,
                      to_version=self._lkg_version[domain]):
            self.publish(domain, self._lkg[domain], validate=False)
        self.rollbacks[domain] += 1
        tel.count("bank.rollbacks")
        return self._lkg_version[domain]

    def last_known_good_version(self, domain: str) -> Optional[int]:
        """Version number of the stored LKG copy (None before any
        validated publish)."""
        return self._lkg_version.get(domain)

    def snapshot(self, domain: str) -> dict:
        """Slice one domain's adapter tree out of the bank (training-side
        acquire; also the per-domain baseline for parity tests). Unlike
        :meth:`publish` this never donates — the bank keeps serving."""
        tel = telemetry.get()
        slot = jnp.asarray(self.slot(domain), jnp.int32)
        with tel.span("bank.snapshot", domain=domain):
            snap = _snapshot_jit(self.stacked, slot)
        tel.count("bank.snapshots")
        return snap

    # -- serving ------------------------------------------------------------
    def serving_params(self, backbone: dict) -> dict:
        """Param tree for the multi-tenant serving/classify path."""
        return {"backbone": backbone, "adapters": self.stacked}
