"""Edge-drafted speculative decoding: recurrent drafter + batched verify.

GaisNet pairs small edge models with the big cloud model (PAPER.md §III);
the edge-cloud synergy framework of arXiv:2401.01666 makes that pairing
concrete — small models propose, the big model validates. That is exactly
speculative decoding, and it is the decode-bound throughput lever: plain
decode reads the whole cache + weights once PER TOKEN, speculative decode
reads them once per (k+1)-token chunk.

One speculative **chunk** per row:

1. **Draft** — a tiny recurrent drafter (ssm by default: O(1) state, no
   draft KV cache) runs ``k+1`` greedy steps over ``[carry, d1..dk]``
   (model._scan_steps with ``with_state=True``), proposing k tokens and
   snapshotting its per-step state — one snapshot per possible rollback
   point.
2. **Verify** — ONE pass of the target model over all k+1 chunk positions
   against the live caches (model.verify_step): greedy targets are the
   argmax at every offset.
3. **Accept** — greedy exact-match: the longest draft prefix that agrees
   with the targets. ``commit = min(accepted + 1, remaining)`` tokens
   land (the "+1" is the verify pass's own next token — progress is
   guaranteed even at 0% acceptance). Residual sampling for non-greedy
   serving is a recorded follow-up hook.
4. **Rollback** — per-row: attention caches restore the slots rejected
   drafts overwrote (exact for full and sliding-window layouts), and
   recurrent caches gather the snapshot at the committed step. Inactive
   (retired) rows keep their caches bitwise frozen, so a ragged wave
   mixes speculative, plain (``spec_rows=False`` forces commit=1, i.e.
   plain decoding THROUGH the verify pass), and retired rows freely.

Greedy speculative output is token-for-token identical to plain
``generate_scan`` — acceptance only changes how fast tokens commit, never
which tokens commit.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import model as M
from repro.models.transformer import attn_window, groups_for
from repro.sharding.rules import drafter_rules, use_rules


def _pow2floor(n: int) -> int:
    return 1 << (max(int(n), 1).bit_length() - 1)


# ---------------------------------------------------------------------------
# Drafter
# ---------------------------------------------------------------------------


def drafter_config(cfg: ModelConfig, *, d_model: int = 64,
                   n_layers: int = 2) -> ModelConfig:
    """A tiny recurrent drafter config for ``cfg``: ssm family (O(1) state,
    no draft KV cache), shared vocab, no PEFT modules. Quality comes from
    distilling the target into these weights (out of scope here — the
    mechanism is exact for ANY drafter weights, acceptance just varies)."""
    return cfg.with_(
        name=f"{cfg.name}-drafter", family="ssm", n_layers=n_layers,
        d_model=d_model, n_heads=1, n_kv_heads=1, head_dim=0,
        d_ff=2 * d_model, attn_variant="full",
        peft=dataclasses.replace(cfg.peft, n_prefix=0, lora_rank=0,
                                 state_prompt=False, head_dim_out=0))


def _min_window(cfg: ModelConfig) -> int:
    """Smallest nonzero attention window in the stack (0 = unwindowed)."""
    ws = [attn_window(cfg, kind) for _, kinds, _ in groups_for(cfg)
          for kind in kinds if kind in ("attn", "moe")]
    ws = [w for w in ws if w and w > 0]
    return min(ws) if ws else 0


@dataclasses.dataclass
class SpecDecoder:
    """Drafter bundle the engine / spec_generate consume.

    ``cfg``/``params`` are the drafter model (any non-audio/vlm family;
    :func:`drafter_config` builds the recommended recurrent one), ``k`` is
    the number of tokens proposed per chunk."""
    cfg: ModelConfig
    params: dict
    k: int = 4

    def __post_init__(self):
        if self.k < 1:
            raise ValueError(f"SpecDecoder.k={self.k} must be >= 1")

    @classmethod
    def init(cls, target: ModelConfig, key: jax.Array, *, k: int = 4,
             d_model: int = 64, n_layers: int = 2) -> "SpecDecoder":
        dcfg = drafter_config(target, d_model=d_model, n_layers=n_layers)
        return cls(dcfg, M.init(dcfg, key), k=k)

    def validate_target(self, cfg: ModelConfig) -> None:
        """Static compatibility checks, raised at construction/submit time
        rather than as silent corruption mid-wave."""
        for c, role in ((cfg, "target"), (self.cfg, "drafter")):
            if c.family in ("audio", "vlm"):
                raise NotImplementedError(
                    f"speculative decoding: {role} family {c.family!r} "
                    "not supported")
            w = _min_window(c)
            if w and self.k + 1 > w:
                raise ValueError(
                    f"speculative chunk k+1={self.k + 1} exceeds the "
                    f"{role}'s sliding window {w}: a chunk would wrap the "
                    "rolling cache buffer and rollback could not restore "
                    "the overwritten slots")
        if self.cfg.vocab_size != cfg.vocab_size:
            raise ValueError(
                f"drafter vocab {self.cfg.vocab_size} != target vocab "
                f"{cfg.vocab_size}")

    def place(self, mesh) -> "SpecDecoder":
        """Replicate the drafter params on ``mesh`` (sharding/rules.py::
        drafter_rules — tiny weights everywhere beats a collective per
        draft step)."""
        if mesh is None:
            return self
        params = M.place_params(self.params, self.cfg, mesh,
                                rules=drafter_rules())
        return dataclasses.replace(self, params=params)


def draft_chunk(dparams: dict, dcfg: ModelConfig, k: int, tok, dcaches,
                pos, active):
    """k+1 greedy drafter steps over ``[tok, d1..dk]``.

    One step MORE than the k proposals: the per-step snapshots then cover
    every rollback point a chunk can commit to (state after chunk offset
    c-1 for any commit c in 1..k+1). Returns (drafts (B, k), final drafter
    caches — chunk-advanced, rollback-mandatory — and per-step recurrent
    snapshots (L, B, k+1, ...))."""
    remaining = jnp.where(active, jnp.int32(k + 2), jnp.int32(0))
    toks, (_, caches, _, _, _), snaps = M._scan_steps(
        dparams, dcfg, k + 1, True, tok, dcaches, pos, remaining,
        jax.random.PRNGKey(0), None, with_state=True)
    return toks[:, 1:], caches, snaps


# ---------------------------------------------------------------------------
# Rollback
# ---------------------------------------------------------------------------


def _restore_attn(old: dict, new: dict, *, qpos, commit, active, window):
    """Exact attention-cache rollback: re-copy ``old``'s values into every
    slot the chunk wrote at a REJECTED offset (>= commit). For the full
    cache those slots were empty (restores the +1e9 sentinel); for the
    sliding-window rolling buffer they held live older entries the chunk
    overwrote — which post-rollback queries can still see, so value
    restore (not just sentinel-masking) is required for correctness."""
    S = old["pos"].shape[2]
    B, T = qpos.shape
    stale = jnp.arange(T)[None, :] >= commit[:, None]       # (B, T)
    slot = attn_mod.chunk_slots(qpos, window, S)
    slot = jnp.where(stale & active[:, None], slot, S)      # keep-slots OOB
    rows = jnp.arange(B)[:, None]
    gidx = jnp.clip(slot, 0, S - 1)

    def fix(o, n):
        return n.at[:, rows, slot].set(o[:, rows, gidx], mode="drop")

    return {key: fix(old[key], new[key]) for key in old}


def _restore_rec(old: dict, snaps: dict, *, commit, active):
    """Recurrent-cache rollback: gather the per-step snapshot at the last
    committed chunk offset (commit-1); inactive rows keep ``old``."""
    idx = jnp.maximum(commit - 1, 0)

    def fix(o, s):                                   # s: (L, B, T, ...)
        g = jnp.take_along_axis(
            s, idx.reshape((1, -1, 1) + (1,) * (s.ndim - 3)), axis=2)
        g = g[:, :, 0]
        return jnp.where(active.reshape((1, -1) + (1,) * (g.ndim - 2)),
                         g, o)

    return jax.tree.map(fix, old, snaps)


def rollback_caches(cfg: ModelConfig, old: dict, new: dict, snaps: dict, *,
                    pos, commit, active, k: int) -> dict:
    """Per-row cache rollback after a chunk: row b keeps exactly the state
    of having decoded its first ``commit[b]`` chunk tokens plainly
    (inactive rows keep ``old`` bitwise). ``old``/``new`` are the pre-/
    post-chunk cache trees, ``snaps`` the per-step recurrent snapshots."""
    qpos = pos[:, None] + jnp.arange(k + 1, dtype=jnp.int32)[None, :]
    out: dict = {}
    for name, kinds, _ in groups_for(cfg):
        grp: dict = {}
        for i, kind in enumerate(kinds):
            key = f"s{i}"
            if kind in ("attn", "moe"):
                grp[key] = _restore_attn(
                    old[name][key], new[name][key], qpos=qpos,
                    commit=commit, active=active,
                    window=attn_window(cfg, kind))
            else:
                grp[key] = _restore_rec(old[name][key], snaps[name][key],
                                        commit=commit, active=active)
        out[name] = grp
    return out


# ---------------------------------------------------------------------------
# Chunk + segment
# ---------------------------------------------------------------------------


def spec_chunk(params, dparams, cfg: ModelConfig, dcfg: ModelConfig, k: int,
               tok, caches, dcaches, pos, remaining, spec_rows, adapter_ids,
               mesh=None):
    """One draft -> verify -> accept -> rollback chunk for a ragged wave.

    Carry semantics: ``tok`` (B, 1) is the committed-but-unemitted next
    token at position ``pos`` (exactly _scan_steps's carry). The chunk
    emits ``commit`` tokens ``[tok, t1..t_{commit-1}]`` and carries the
    verify target at the last committed offset. ``spec_rows`` (B,) bool
    rows decode plainly through the verify pass when False (commit is
    forced to 1 and their drafts are never counted)."""
    active = remaining > 0
    with use_rules(mesh, drafter_rules() if mesh is not None else None):
        drafts, dnew, dsnaps = draft_chunk(dparams, dcfg, k, tok, dcaches,
                                           pos, active)
    tks = jnp.concatenate([tok, drafts], axis=1)            # (B, k+1)
    logits, vnew, vsnaps = M.verify_step(params, tks, caches, pos, cfg,
                                         adapter_ids=adapter_ids,
                                         active=active)
    tgt = jnp.argmax(logits, axis=-1).astype(jnp.int32)     # (B, k+1)
    match = (drafts == tgt[:, :k]) & spec_rows[:, None]
    acc = jnp.cumprod(match.astype(jnp.int32), axis=1).sum(axis=1)
    commit = jnp.where(active, jnp.minimum(acc + 1, remaining),
                       jnp.int32(0))
    vals = jnp.concatenate([tok, tgt[:, :k]], axis=1)       # emitted stream
    carry = jnp.take_along_axis(tgt, jnp.maximum(commit - 1, 0)[:, None],
                                axis=1)
    tok = jnp.where(active[:, None], carry, tok).astype(jnp.int32)
    caches = rollback_caches(cfg, caches, vnew, vsnaps, pos=pos,
                             commit=commit, active=active, k=k)
    dcaches = rollback_caches(dcfg, dcaches, dnew, dsnaps, pos=pos,
                              commit=commit, active=active, k=k)
    pos = pos + commit
    remaining = remaining - commit
    drafted = jnp.where(active & spec_rows, jnp.int32(k), jnp.int32(0))
    accepted = jnp.maximum(commit - 1, 0)                   # accepted drafts
    return (tok, caches, dcaches, pos, remaining, vals, commit, drafted,
            accepted)


def spec_segment(params, dparams, cfg: ModelConfig, dcfg: ModelConfig,
                 chunks: int, k: int, tok, caches, dcaches, pos, remaining,
                 spec_rows, adapter_ids, mesh=None):
    """``chunks`` scanned speculative chunks in one dispatch (the engine's
    speculative counterpart of model._scan_steps).

    Emitted tokens scatter into a (B, chunks*(k+1)) buffer at per-row
    write offsets (rows commit at different rates); ``counts`` (B,) says
    how many of each row's buffer entries are real. Returns (buffer,
    counts, drafted, accepted, tok, caches, dcaches, pos, remaining)."""
    B = tok.shape[0]
    T = k + 1
    out0 = jnp.zeros((B, chunks * T), jnp.int32)
    off0 = jnp.zeros((B,), jnp.int32)
    rows = jnp.arange(B)[:, None]

    def body(carry, _):
        tok, caches, dcaches, pos, remaining, out, off = carry
        (tok, caches, dcaches, pos, remaining, vals, commit, drafted,
         accepted) = spec_chunk(params, dparams, cfg, dcfg, k, tok, caches,
                                dcaches, pos, remaining, spec_rows,
                                adapter_ids, mesh=mesh)
        idx = off[:, None] + jnp.arange(T, dtype=jnp.int32)[None, :]
        idx = jnp.where(jnp.arange(T)[None, :] < commit[:, None], idx,
                        out.shape[1])                       # pad -> dropped
        out = out.at[rows, idx].set(vals, mode="drop")
        off = off + commit
        return (tok, caches, dcaches, pos, remaining, out, off), \
            (jnp.sum(drafted), jnp.sum(accepted))

    carry, (drafted, accepted) = jax.lax.scan(
        body, (tok, caches, dcaches, pos, remaining, out0, off0), None,
        length=chunks)
    tok, caches, dcaches, pos, remaining, out, off = carry
    return (out, off, jnp.sum(drafted), jnp.sum(accepted), tok, caches,
            dcaches, pos, remaining)


# ---------------------------------------------------------------------------
# One-call generation (generate_scan's speculative twin)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SpecStats:
    drafted: int = 0
    accepted: int = 0

    @property
    def acceptance_rate(self) -> float:
        return self.accepted / self.drafted if self.drafted else 0.0


def spec_generate(params: dict, cfg: ModelConfig, spec: SpecDecoder,
                  prompts, *, gen: int, prompt_lens=None,
                  adapter_ids=None, spec_rows=None, mesh=None):
    """Greedy speculative generation — token-for-token identical to
    ``generate_scan(..., greedy=True)``, just fewer target cache reads.

    prompts: (B, S) int32. Returns ((B, gen) tokens, SpecStats). The
    drafter prefills alongside the target (its prefill argmax is
    discarded — the carry token is the TARGET's), then pow2-bucketed
    speculative segments drain the per-row budgets."""
    spec.validate_target(cfg)
    prompts = jnp.asarray(prompts, jnp.int32)
    B, S = prompts.shape
    lens = None if prompt_lens is None else \
        jnp.asarray(prompt_lens, jnp.int32)
    ids = None if adapter_ids is None else \
        jnp.asarray(adapter_ids, jnp.int32)
    cap = S + gen
    batch = {"tokens": prompts}
    tok, caches, pos = M._wave_prefill_fn(cfg, cap, mesh)(
        params, batch, lens, ids)
    _, dcaches, _ = M._wave_prefill_fn(spec.cfg, cap, mesh)(
        spec.params, batch, lens, None)
    remaining = jnp.full((B,), gen, jnp.int32)
    rows = jnp.ones((B,), bool) if spec_rows is None else \
        jnp.asarray(spec_rows, bool)
    T = spec.k + 1
    out_np = np.zeros((B, gen), np.int32)
    write = np.zeros((B,), np.int64)
    rem_np = np.full((B,), gen, np.int64)
    stats = SpecStats()
    while rem_np.max() > 0:
        chunks = max(1, _pow2floor(max(1, int(rem_np.max()) // T)))
        (buf, counts, dr, ac, tok, caches, dcaches, pos, remaining) = \
            M._spec_segment_fn(cfg, spec.cfg, chunks, spec.k, mesh)(
                params, spec.params, tok, caches, dcaches, pos, remaining,
                rows, ids)
        counts_np = np.asarray(counts)
        buf_np = np.asarray(buf)
        for b in range(B):
            c = int(counts_np[b])
            out_np[b, write[b]:write[b] + c] = buf_np[b, :c]
            write[b] += c
        rem_np -= counts_np
        stats.drafted += int(dr)
        stats.accepted += int(ac)
    return jnp.asarray(out_np), stats
