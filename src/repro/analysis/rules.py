"""AST rule passes R1-R4 + R6 (R5 lives in kernel_contract.py).

All passes are lexical: a function is "traced" when the file itself
jits or scans it (decorated with ``jax.jit`` / ``functools.partial(
jax.jit, ...)``, passed to ``jax.jit(f)`` or ``jax.lax.scan(f, ...)``,
or lexically nested inside such a function). Call graphs are NOT
followed — a helper called from a traced body must earn its own
annotation if it needs checking. That keeps the pass O(file) and the
findings explainable, at the cost of depth; the runtime
``compile_guard`` covers what static lexical analysis cannot.
"""
from __future__ import annotations

import ast
import builtins

from repro.analysis.base import SourceFile

_BUILTINS = frozenset(dir(builtins))

# host-sync calls flagged inside traced bodies (R2)
_SYNC_ATTRS = ("item", "tolist", "block_until_ready")
_NP_SYNC_FNS = ("asarray", "array", "ascontiguousarray")
_CASTS = ("float", "int", "bool")

_FN_NODES = (ast.FunctionDef, ast.AsyncFunctionDef)


# ---------------------------------------------------------------------------
# name-binding helpers
# ---------------------------------------------------------------------------

def _targets(node: ast.AST) -> set:
    return {n.id for n in ast.walk(node)
            if isinstance(n, ast.Name) and isinstance(n.ctx, (ast.Store,
                                                              ast.Del))}


def _params(fn) -> list:
    a = fn.args
    names = [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return names


def _walk_pruned(node, *, into_defs: bool = False):
    """Yield descendants of ``node`` without entering nested function or
    lambda bodies (unless ``into_defs``); ``node`` itself is not yielded."""
    stack = list(ast.iter_child_nodes(node))
    while stack:
        n = stack.pop()
        yield n
        if not into_defs and isinstance(n, _FN_NODES + (ast.Lambda,)):
            continue
        stack.extend(ast.iter_child_nodes(n))


def _bound_in_scope(fn) -> set:
    """Names bound in ``fn``'s own scope: params, assignments, imports,
    nested def/class names, loop/with/except targets, and (leniently —
    they are really their own scopes) comprehension/walrus targets.
    Flow-insensitive; does not descend into nested function bodies."""
    if isinstance(fn, ast.Lambda):
        return set(_params(fn))
    bound = set(_params(fn))
    for n in _walk_pruned(fn):
        if isinstance(n, _FN_NODES + (ast.ClassDef,)):
            bound.add(n.name)
        elif isinstance(n, (ast.Import, ast.ImportFrom)):
            for al in n.names:
                bound.add((al.asname or al.name).split(".")[0])
        elif isinstance(n, ast.Assign):
            for t in n.targets:
                bound.update(_targets(t))
        elif isinstance(n, (ast.AnnAssign, ast.AugAssign)):
            bound.update(_targets(n.target))
        elif isinstance(n, (ast.For, ast.AsyncFor)):
            bound.update(_targets(n.target))
        elif isinstance(n, (ast.With, ast.AsyncWith)):
            for it in n.items:
                if it.optional_vars is not None:
                    bound.update(_targets(it.optional_vars))
        elif isinstance(n, ast.ExceptHandler) and n.name:
            bound.add(n.name)
        elif isinstance(n, ast.comprehension):
            bound.update(_targets(n.target))
        elif isinstance(n, ast.NamedExpr):
            bound.update(_targets(n.target))
    return bound


def module_bindings(tree: ast.Module) -> set:
    fake = ast.FunctionDef(
        name="<module>", body=tree.body, decorator_list=[],
        args=ast.arguments(posonlyargs=[], args=[], kwonlyargs=[],
                           kw_defaults=[], defaults=[]))
    return _bound_in_scope(fake) | {"__name__", "__file__", "__doc__",
                                    "__package__", "__spec__"}


# ---------------------------------------------------------------------------
# import-alias resolution (numpy / jax spelled however the file spells them)
# ---------------------------------------------------------------------------

class Aliases:
    def __init__(self, tree: ast.Module):
        self.numpy: set = set()            # names bound to the numpy module
        self.jax: set = set()
        self.time_mod: set = set()
        self.datetime_mod: set = set()
        self.datetime_cls: set = set()
        self.from_time: set = set()        # `from time import time [as t]`
        self.device_get: set = set()       # `from jax import device_get`
        for n in ast.walk(tree):
            if isinstance(n, ast.Import):
                for al in n.names:
                    name, bind = al.name, al.asname or al.name.split(".")[0]
                    if name == "numpy":
                        self.numpy.add(bind)
                    elif name == "jax":
                        self.jax.add(bind)
                    elif name == "time":
                        self.time_mod.add(bind)
                    elif name == "datetime":
                        self.datetime_mod.add(bind)
            elif isinstance(n, ast.ImportFrom):
                for al in n.names:
                    bind = al.asname or al.name
                    if n.module == "time" and al.name == "time":
                        self.from_time.add(bind)
                    if n.module == "datetime" and al.name == "datetime":
                        self.datetime_cls.add(bind)
                    if n.module == "jax" and al.name == "device_get":
                        self.device_get.add(bind)


def _dotted(node) -> str:
    """Dotted name of a Name/Attribute chain ('' otherwise)."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _call_name(node: ast.Call) -> str:
    return _dotted(node.func)


def _is_jax_jit(name: str, al: Aliases) -> bool:
    if not name:
        return False
    head, _, tail = name.partition(".")
    return (head in al.jax and tail == "jit") or name == "jit"


# ---------------------------------------------------------------------------
# traced / hot scope discovery
# ---------------------------------------------------------------------------

def _is_lru_decorated(fn) -> bool:
    for d in fn.decorator_list:
        name = _dotted(d.func if isinstance(d, ast.Call) else d)
        if name.split(".")[-1] in ("lru_cache", "cache"):
            return True
    return False


def _is_jit_decorated(fn, al: Aliases) -> bool:
    for d in fn.decorator_list:
        if isinstance(d, ast.Call):
            if _is_jax_jit(_call_name(d), al):
                return True
            if _call_name(d).split(".")[-1] == "partial" and d.args \
                    and _is_jax_jit(_dotted(d.args[0]), al):
                return True
        elif _is_jax_jit(_dotted(d), al):
            return True
    return False


def _collect_traced_roots(tree: ast.Module, al: Aliases) -> list:
    """FunctionDef nodes the file jits or scans (lexically), in source
    order. Each root is checked with ITS OWN params (a scan body nested
    in a jitted impl appears twice: once via the impl subtree, once as
    its own root with the carry params); duplicate findings are deduped
    at the end of check_file."""
    roots: list = []

    def scan_scope(body, defs_in_scope):
        local = dict(defs_in_scope)
        for st in body:
            if isinstance(st, _FN_NODES):
                local[st.name] = st
                if _is_jit_decorated(st, al):
                    roots.append(st)
                scan_scope(st.body, local)
                continue
            if isinstance(st, ast.ClassDef):
                scan_scope(st.body, local)
                continue
            for n in _walk_pruned(st, into_defs=True):
                if not isinstance(n, ast.Call):
                    continue
                name = _call_name(n)
                is_scan = name.endswith("lax.scan") or name == "scan"
                if (_is_jax_jit(name, al) or is_scan) and n.args:
                    first = n.args[0]
                    if isinstance(first, ast.Name) and first.id in local:
                        roots.append(local[first.id])
            # defs nested in compound statements (if/try/with/for)
            for attr in ("body", "orelse", "finalbody"):
                blk = getattr(st, attr, None)
                if isinstance(blk, list):
                    scan_scope(blk, local)
            if isinstance(st, ast.Try):
                for h in st.handlers:
                    scan_scope(h.body, local)

    scan_scope(tree.body, {})
    return roots


def _hot_roots(sf: SourceFile) -> list:
    return [n for n in ast.walk(sf.tree)
            if isinstance(n, _FN_NODES)
            and sf.annotation_for(n, "hot") is not None]


# ---------------------------------------------------------------------------
# R2/R3 body checks
# ---------------------------------------------------------------------------

def _literalish(node) -> bool:
    return isinstance(node, (ast.Constant, ast.UnaryOp)) or (
        isinstance(node, ast.Call)
        and _call_name(node) in ("len", "min", "max", "round"))


def _branch_names(test: ast.AST) -> set:
    """Name loads in a branch test, minus static-structure idioms:
    ``x is None`` guards and isinstance/hasattr/len checks dispatch on
    Python structure, not traced values."""
    skip = set()
    for n in ast.walk(test):
        if isinstance(n, ast.Compare) and any(
                isinstance(op, (ast.Is, ast.IsNot)) for op in n.ops):
            for sub in [n.left] + n.comparators:
                if isinstance(sub, ast.Name):
                    skip.add(sub.id)
        if isinstance(n, ast.Call) and _call_name(n) in (
                "isinstance", "hasattr", "len", "getattr"):
            for sub in ast.walk(n):
                if isinstance(sub, ast.Name):
                    skip.add(sub.id)
    names = {n.id for n in ast.walk(test)
             if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load)}
    return names - skip


def _check_body(sf: SourceFile, fn, al: Aliases, *, traced: bool,
                out: list) -> None:
    """R2 (host syncs) + R3 (traced branching) inside one traced/hot fn.

    ``traced=False`` is an annotated host hot path (the drain loop):
    only unambiguous syncs are flagged there — ``np.asarray`` on a
    device array is a sync, so it is flagged and the loop's deliberate
    once-per-segment sync carries an inline ignore, while float()/int()
    on host bookkeeping stays legal.
    """
    where = "jitted/scanned body" if traced else "hot path"
    params = frozenset(_params(fn))

    def emit(line, code, msg):
        f = sf.finding(line, code, msg)
        if f:
            out.append(f)

    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            if isinstance(node.func, ast.Attribute) \
                    and node.func.attr in _SYNC_ATTRS and not node.args:
                emit(node.lineno, "R2",
                     f".{node.func.attr}() forces a host sync inside a "
                     f"{where}")
                continue
            name = _call_name(node)
            if not name:
                continue
            head, _, tail = name.partition(".")
            if (head in al.jax and tail == "device_get") \
                    or name in al.device_get:
                emit(node.lineno, "R2",
                     f"{name}() inside a {where} round-trips the device")
            elif head in al.numpy and tail in _NP_SYNC_FNS:
                emit(node.lineno, "R2",
                     f"{name}() inside a {where} materializes on host "
                     "(device sync)")
            elif traced and name in _CASTS and node.args \
                    and not _literalish(node.args[0]):
                emit(node.lineno, "R2",
                     f"{name}() on a possibly-traced value inside a "
                     "jitted/scanned body forces a host sync")
        elif traced and isinstance(node, (ast.If, ast.While, ast.IfExp)):
            hit = sorted(_branch_names(node.test) & params)
            if hit:
                emit(node.lineno, "R3",
                     f"Python branch on traced value(s) {', '.join(hit)} "
                     "inside a jitted/scanned body — use lax.cond/"
                     "jnp.where")


# ---------------------------------------------------------------------------
# R1 — fused-fn cache-key completeness
# ---------------------------------------------------------------------------

def _check_factory(sf: SourceFile, fn, mod_bound: set, al: Aliases,
                   out: list) -> None:
    params = _params(fn)
    jits = any(isinstance(n, ast.Call)
               and _is_jax_jit(_call_name(n), al)
               for n in ast.walk(fn))

    def emit(line, msg):
        f = sf.finding(line, "R1", msg)
        if f:
            out.append(f)

    if jits:
        ann = sf.annotation_for(fn, "keys")
        if ann is None:
            emit(fn.lineno,
                 f"lru_cache fused-fn factory {fn.name} missing its "
                 "`tracelint: keys=` cache-key declaration")
        else:
            declared, actual = set(ann.fields["keys"]), set(params)
            for k in sorted(declared - actual):
                emit(fn.lineno,
                     f"{fn.name}: declared cache key '{k}' is missing "
                     "from the factory signature — the jit cache would "
                     "serve one specialization for another")
            for k in sorted(actual - declared):
                emit(fn.lineno,
                     f"{fn.name}: factory arg '{k}' is not in the "
                     "declared `tracelint: keys=` tuple — a spurious key "
                     "(forks identical jits) or an undeclared "
                     "trace-shaper")

    # closure-capture resolution: every name the traced body loads must
    # resolve to the cache key (factory params/locals), module scope, or
    # builtins — anything else shapes the trace without keying the cache.
    factory_bound = set(params) | _bound_in_scope(fn)

    def resolve(name_node, chain):
        nm = name_node.id
        if any(nm in scope for scope in chain):
            return
        if nm in factory_bound or nm in mod_bound or nm in _BUILTINS:
            return
        emit(name_node.lineno,
             f"{fn.name}: traced body uses '{nm}' which resolves to "
             "neither the factory cache key nor module scope — a "
             "closure-captured trace-shaper outside the key")

    def resolve_scope(node, chain):
        own = _bound_in_scope(node)
        inner = [own] + chain
        roots = [node.body] if isinstance(node, ast.Lambda) else node.body
        stack = list(roots) if isinstance(roots, list) else [roots]
        while stack:
            n = stack.pop()
            if isinstance(n, _FN_NODES + (ast.Lambda,)):
                resolve_scope(n, inner)
                continue
            if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load):
                resolve(n, inner)
            stack.extend(ast.iter_child_nodes(n))

    for n in _walk_pruned(fn):
        if isinstance(n, _FN_NODES + (ast.Lambda,)):
            resolve_scope(n, [])


# ---------------------------------------------------------------------------
# R6 — donation hazards
# ---------------------------------------------------------------------------

def _donating_jits(tree: ast.Module, al: Aliases) -> dict:
    """{name: donated positional indices} for literal
    ``f = jax.jit(..., donate_argnums=(i, ...))`` bindings. Donation
    through non-literal argnums (config-dependent) is out of static
    reach and left to tests."""
    donors: dict = {}
    for n in ast.walk(tree):
        if not (isinstance(n, ast.Assign) and len(n.targets) == 1
                and isinstance(n.targets[0], ast.Name)
                and isinstance(n.value, ast.Call)
                and _is_jax_jit(_call_name(n.value), al)):
            continue
        for kw in n.value.keywords:
            if kw.arg != "donate_argnums" \
                    or not isinstance(kw.value, (ast.Tuple, ast.Constant)):
                continue
            idxs = [e.value for e in ast.walk(kw.value)
                    if isinstance(e, ast.Constant)
                    and isinstance(e.value, int)]
            if idxs:
                donors[n.targets[0].id] = tuple(idxs)
    return donors


def _check_donation(sf: SourceFile, fn, donors: dict, out: list) -> None:
    for call in ast.walk(fn):
        if not (isinstance(call, ast.Call)
                and isinstance(call.func, ast.Name)
                and call.func.id in donors):
            continue
        donated = [call.args[i].id for i in donors[call.func.id]
                   if i < len(call.args)
                   and isinstance(call.args[i], ast.Name)]
        if not donated:
            continue
        end = getattr(call, "end_lineno", call.lineno)
        rebound_at_call = set()
        for st in ast.walk(fn):
            if isinstance(st, ast.Assign) and st.value is call:
                for t in st.targets:
                    rebound_at_call.update(_targets(t))
        for nm in donated:
            if nm in rebound_at_call:
                continue
            events = sorted(
                (n.lineno, n.col_offset, isinstance(n.ctx, ast.Load))
                for n in ast.walk(fn)
                if isinstance(n, ast.Name) and n.id == nm
                and n.lineno > end)
            for line, _, is_load in events:
                if not is_load:
                    break                      # rebound before any use
                f = sf.finding(
                    line, "R6",
                    f"'{nm}' was donated to {call.func.id}() on line "
                    f"{call.lineno} and is read afterwards — donated "
                    "buffers are dead after the call")
                if f:
                    out.append(f)
                break


# ---------------------------------------------------------------------------
# entry
# ---------------------------------------------------------------------------

def check_file(sf: SourceFile, *, library: bool) -> list:
    """All single-file rules. ``library=True`` for src/repro files (R4
    bare-assert and R3 wall-clock apply only there; pytest asserts and
    test/benchmark timers are idiomatic)."""
    out: list = []
    al = Aliases(sf.tree)
    mod_bound = module_bindings(sf.tree)

    # R1: module-level lru_cache factories only — a nested lru_cache is
    # recreated per enclosing call (e.g. scheduler.mlcp_policy's DP
    # table), so closure capture there is scoped by construction.
    for st in sf.tree.body:
        if isinstance(st, _FN_NODES) and _is_lru_decorated(st):
            _check_factory(sf, st, mod_bound, al, out)

    # R2/R3 over every traced scope and annotated host hot path.
    for root in _collect_traced_roots(sf.tree, al):
        _check_body(sf, root, al, traced=True, out=out)
    for root in _hot_roots(sf):
        _check_body(sf, root, al, traced=False, out=out)

    # R3 wall-clock: library-wide (PR 8 standardized hot-path clocks on
    # time.perf_counter; wall clocks step/slew and poison latency math).
    if library:
        for n in ast.walk(sf.tree):
            if not isinstance(n, ast.Call):
                continue
            name = _call_name(n)
            if not name:
                continue
            head, _, tail = name.partition(".")
            bad = (head in al.time_mod and tail in ("time", "clock")) \
                or name in al.from_time \
                or (head in al.datetime_mod
                    and tail in ("datetime.now", "datetime.utcnow")) \
                or (head in al.datetime_cls and tail in ("now", "utcnow"))
            if bad:
                f = sf.finding(
                    n.lineno, "R3",
                    f"wall-clock {name}() — hot-path timing must use "
                    "time.perf_counter() (monotonic); annotate "
                    "`tracelint: ignore[R3]` where wall time is the "
                    "point")
                if f:
                    out.append(f)

    # R4: bare asserts in library code vanish under `python -O` and
    # abort without an actionable error type.
    if library:
        for n in ast.walk(sf.tree):
            if isinstance(n, ast.Assert):
                f = sf.finding(
                    n.lineno, "R4",
                    "bare assert in library code — raise ValueError/"
                    "RuntimeError (asserts vanish under -O)")
                if f:
                    out.append(f)

    # R6: donation hazards against same-file literal donating jits.
    donors = _donating_jits(sf.tree, al)
    if donors:
        for n in ast.walk(sf.tree):
            if isinstance(n, _FN_NODES):
                _check_donation(sf, n, donors, out)

    # unknown tracelint directive == a typo silently disabling a rule
    for ann in sf.annotations:
        if ann.kind == "unknown":
            f = sf.finding(ann.line, "R0",
                           "unrecognized tracelint directive "
                           f"{ann.fields['text']!r}")
            if f:
                out.append(f)

    # overlapping traced-root walks (impl + its nested scan body) can
    # produce byte-identical findings — dedupe, keep source order
    seen, deduped = set(), []
    for f in sorted(out, key=lambda f: (f.line, f.code, f.message)):
        if (f.line, f.code, f.message) not in seen:
            seen.add((f.line, f.code, f.message))
            deduped.append(f)
    return deduped
