"""R5 — the xla|pallas|interpret kernel triad contract.

Every Pallas kernel module (any file under ``kernels/`` containing a
``pallas_call``) must register its public contract with one or more

    # tracelint: kernel-op=<ops.py dispatch fn> oracle=<ref.py oracle fn>

annotations. R5 then verifies, cross-file:

- the named dispatch exists as a module-level def in ``ops.py``, takes a
  ``backend`` argument, and routes through the ``_pick`` backend
  resolver (the xla|pallas|interpret triad);
- the named oracle exists as a module-level def in ``ref.py``.

A kernel that loses its oracle loses its parity tests; a dispatch that
bypasses ``_pick`` silently drops the interpret path CI smokes rely on.
"""
from __future__ import annotations

import ast
from pathlib import Path

from repro.analysis.base import Finding, SourceFile


def _module_defs(tree: ast.Module) -> dict:
    return {n.name: n for n in tree.body
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}


def _has_backend_param(fn) -> bool:
    a = fn.args
    return any(p.arg == "backend"
               for p in a.posonlyargs + a.args + a.kwonlyargs)


def _routes_through_pick(fn) -> bool:
    for n in ast.walk(fn):
        if isinstance(n, ast.Call):
            f = n.func
            name = f.attr if isinstance(f, ast.Attribute) else (
                f.id if isinstance(f, ast.Name) else "")
            if name == "_pick":
                return True
    return False


def _first_pallas_call_line(tree: ast.Module) -> int:
    for n in ast.walk(tree):
        if isinstance(n, ast.Call):
            f = n.func
            name = f.attr if isinstance(f, ast.Attribute) else (
                f.id if isinstance(f, ast.Name) else "")
            if name == "pallas_call":
                return n.lineno
    return 1


def check_kernels(kernels_dir, *, rel_root=None) -> list:
    """Run R5 over one kernels directory. ``rel_root`` controls how
    finding paths are rendered (repo-relative by default)."""
    kernels_dir = Path(kernels_dir)
    rel_root = Path(rel_root) if rel_root is not None else kernels_dir
    out: list = []

    def rel(p: Path) -> str:
        try:
            return p.relative_to(rel_root).as_posix()
        except ValueError:
            return p.as_posix()

    ops_path = kernels_dir / "ops.py"
    ref_path = kernels_dir / "ref.py"
    ops_defs = _module_defs(ast.parse(ops_path.read_text())) \
        if ops_path.exists() else None
    ref_defs = _module_defs(ast.parse(ref_path.read_text())) \
        if ref_path.exists() else None

    for path in sorted(kernels_dir.glob("*.py")):
        if path.name in ("ops.py", "ref.py", "__init__.py"):
            continue
        text = path.read_text()
        if "pallas_call" not in text:
            continue
        sf = SourceFile(rel(path), text)
        anns = [a for a in sf.annotations if a.kind == "kernel-op"]
        if not anns:
            line = _first_pallas_call_line(sf.tree)
            if not sf.suppressed(line, "R5"):
                out.append(Finding(
                    sf.path, line, "R5",
                    "pallas_call kernel module has no `tracelint: "
                    "kernel-op=... oracle=...` registration (every "
                    "kernel needs its ref.py oracle and ops.py "
                    "xla|pallas|interpret dispatch)"))
            continue
        for ann in anns:
            op, oracle = ann.fields["op"], ann.fields["oracle"]
            if not oracle:
                out.append(Finding(
                    sf.path, ann.line, "R5",
                    f"kernel-op={op or '?'} registration is missing its "
                    "oracle= (ref.py parity target)"))
            if ops_defs is None:
                out.append(Finding(sf.path, ann.line, "R5",
                                   "kernels/ops.py not found — no "
                                   "dispatch layer to register against"))
            elif op not in ops_defs:
                out.append(Finding(
                    sf.path, ann.line, "R5",
                    f"registered dispatch ops.{op} does not exist"))
            else:
                fn = ops_defs[op]
                if not _has_backend_param(fn):
                    out.append(Finding(
                        sf.path, ann.line, "R5",
                        f"ops.{op} has no backend= parameter — the "
                        "xla|pallas|interpret triad is not selectable"))
                elif not _routes_through_pick(fn):
                    out.append(Finding(
                        sf.path, ann.line, "R5",
                        f"ops.{op} does not route through the _pick "
                        "backend resolver — interpret-mode CI smokes "
                        "cannot reach this kernel"))
            if oracle and ref_defs is None:
                out.append(Finding(sf.path, ann.line, "R5",
                                   "kernels/ref.py not found — no oracle "
                                   "layer to register against"))
            elif oracle and oracle not in ref_defs:
                out.append(Finding(
                    sf.path, ann.line, "R5",
                    f"registered oracle ref.{oracle} does not exist"))
    return out
