"""tracelint CLI: discovery, baseline, exit code.

    python -m repro.analysis [paths...] [--baseline FILE]
                             [--write-baseline] [--no-baseline]

Default paths are ``src/repro`` and ``tests`` under the repo root (the
nearest ancestor of cwd holding a ``pyproject.toml``). Findings print as
``file:line CODE message``; the process exits 1 iff any finding is not
covered by the checked-in baseline (``scripts/lint_baseline.txt``).
Baseline entries key on (path, code, message) so they survive line
drift; stale entries are reported (and pruned on ``--write-baseline``)
but never fail the run.

The pass is pure-AST — no jax import, no tracing — so the whole tree
lints in well under a second and CI can afford to gate on it always.
"""
from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path

from repro.analysis import kernel_contract, rules
from repro.analysis.base import Finding, SourceFile

BASELINE_DEFAULT = "scripts/lint_baseline.txt"


def repo_root(start=None) -> Path:
    cur = Path(start or Path.cwd()).resolve()
    for p in (cur, *cur.parents):
        if (p / "pyproject.toml").exists():
            return p
    return cur


def discover(paths) -> list:
    files: list = []
    for p in paths:
        p = Path(p)
        if p.is_dir():
            files.extend(sorted(f for f in p.rglob("*.py")
                                if "__pycache__" not in f.parts))
        elif p.suffix == ".py":
            files.append(p)
    return files


def lint_text(text: str, path: str, *, library: bool = True) -> list:
    """Lint one source string (the unit tests' entry point)."""
    return rules.check_file(SourceFile(path, text), library=library)


def lint_paths(root: Path, paths) -> tuple:
    """-> (findings, n_files). Kernel-contract (R5) runs once per
    ``kernels/`` directory seen among the files."""
    findings: list = []
    files = discover(paths)
    kernel_dirs = set()
    for f in files:
        rel = f.resolve()
        try:
            rel_s = rel.relative_to(root).as_posix()
        except ValueError:
            rel_s = rel.as_posix()
        library = rel_s.startswith("src/")
        try:
            sf = SourceFile(rel_s, f.read_text())
        except SyntaxError as e:
            findings.append(Finding(rel_s, e.lineno or 1, "R0",
                                    f"syntax error: {e.msg}"))
            continue
        findings.extend(rules.check_file(sf, library=library))
        if rel.parent.name == "kernels":
            kernel_dirs.add(rel.parent)
    for kd in sorted(kernel_dirs):
        findings.extend(kernel_contract.check_kernels(kd, rel_root=root))
    return findings, len(files)


def load_baseline(path: Path) -> set:
    if not path.exists():
        return set()
    keys = set()
    for line in path.read_text().splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        parts = line.split("\t", 2)
        if len(parts) == 3:
            keys.add((parts[0], parts[1], parts[2]))
    return keys


def write_baseline(path: Path, findings) -> None:
    lines = ["# tracelint suppression baseline — one `path<TAB>CODE<TAB>",
             "# message` per tolerated finding. Keep this empty: fix or",
             "# inline-`tracelint: ignore[...]` (with a reason) instead,",
             "# and reserve the baseline for staged burn-downs.",
             "# Regenerate: python -m repro.analysis --write-baseline"]
    for f in sorted(set(f.key for f in findings)):
        lines.append("\t".join(f))
    path.write_text("\n".join(lines) + "\n")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="tracelint: static analysis for the serving/training "
                    "hot paths (rules R1-R6; see README).")
    ap.add_argument("paths", nargs="*",
                    help="files/dirs to lint (default: src/repro tests)")
    ap.add_argument("--baseline", default=None,
                    help=f"baseline file (default: {BASELINE_DEFAULT})")
    ap.add_argument("--no-baseline", action="store_true",
                    help="report every finding, baselined or not")
    ap.add_argument("--write-baseline", action="store_true",
                    help="rewrite the baseline from current findings "
                         "and exit 0")
    args = ap.parse_args(argv)

    t0 = time.perf_counter()
    root = repo_root()
    paths = [Path(p) for p in args.paths] if args.paths else \
        [root / "src" / "repro", root / "tests"]
    findings, n_files = lint_paths(root, paths)

    baseline_path = Path(args.baseline) if args.baseline \
        else root / BASELINE_DEFAULT
    if args.write_baseline:
        write_baseline(baseline_path, findings)
        print(f"tracelint: wrote {len(set(f.key for f in findings))} "
              f"baseline entries to {baseline_path}")
        return 0

    baseline = set() if args.no_baseline else load_baseline(baseline_path)
    new = [f for f in findings if f.key not in baseline]
    known = len(findings) - len(new)
    stale = baseline - set(f.key for f in findings)

    for f in sorted(new):
        print(f.render())
    for key in sorted(stale):
        print(f"tracelint: stale baseline entry (fixed? prune it): "
              f"{key[0]} {key[1]} {key[2]}")
    dt = time.perf_counter() - t0
    print(f"tracelint: {len(new)} new finding(s), {known} baselined, "
          f"{len(stale)} stale baseline entr(ies) across {n_files} files "
          f"in {dt:.2f}s")
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
