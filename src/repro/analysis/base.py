"""Shared lint plumbing: findings, ``# tracelint:`` comments, source model.

Annotation grammar (one per comment, anywhere a ``#`` comment is legal):

- ``# tracelint: keys=cfg,cap,mesh`` — declares the trace-shaping key
  tuple of the ``functools.lru_cache`` fused-fn factory it annotates
  (the def/decorator it immediately precedes or shares a line with).
  R1 checks the declaration against the factory signature BOTH ways.
- ``# tracelint: hot`` — marks a host-side function (e.g. the engine
  drain loop) as a hot path: R2/R3 host-sync and wall-clock checks apply
  to its whole lexical body.
- ``# tracelint: kernel-op=<ops fn> oracle=<ref fn>`` — registers a
  Pallas kernel module's public contract; R5 resolves both names.
- ``# tracelint: ignore[R2,R3] <reason>`` — suppresses those codes on
  that line (``ignore`` with no bracket suppresses every code). Use for
  the deliberate exceptions: the drain loop's one-sync-per-segment
  ``np.asarray``, telemetry's wall-clock trace epoch.

Baselines key on ``(path, code, message)`` — line-number free, so a
baselined finding survives unrelated edits but a new instance of the
same defect elsewhere still fails the gate.
"""
from __future__ import annotations

import ast
import dataclasses
import io
import re
import tokenize
from typing import Optional

_ANN_RE = re.compile(r"tracelint:\s*(.+?)\s*$")
_IGNORE_RE = re.compile(r"ignore(?:\[([A-Z0-9,\s]+)\])?")

ALL_CODES = ("R1", "R2", "R3", "R4", "R5", "R6")


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    """One lint finding, printed as ``path:line CODE message``."""
    path: str                          # repo-relative, posix separators
    line: int
    code: str                          # "R1".."R6"
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line} {self.code} {self.message}"

    @property
    def key(self) -> tuple:
        """Baseline identity: line numbers drift, messages don't."""
        return (self.path, self.code, self.message)


@dataclasses.dataclass(frozen=True)
class Annotation:
    line: int
    kind: str                          # 'keys' | 'hot' | 'kernel-op' | 'ignore'
    fields: dict


def _parse_annotation(line: int, text: str) -> Optional[Annotation]:
    m = _ANN_RE.search(text)
    if not m:
        return None
    body = m.group(1)
    if body.startswith("ignore"):
        im = _IGNORE_RE.match(body)
        codes = frozenset(c.strip() for c in im.group(1).split(",")) \
            if im.group(1) else frozenset(ALL_CODES)
        return Annotation(line, "ignore", {"codes": codes})
    if body == "hot" or body.startswith("hot "):
        return Annotation(line, "hot", {})
    if body.startswith("keys="):
        raw = body[len("keys="):].split()[0] if body[len("keys="):] else ""
        keys = tuple(k.strip() for k in raw.split(",") if k.strip())
        return Annotation(line, "keys", {"keys": keys})
    if body.startswith("kernel-op="):
        fields = {}
        for part in body.split():
            if "=" in part:
                k, v = part.split("=", 1)
                fields[k] = v
        return Annotation(line, "kernel-op",
                          {"op": fields.get("kernel-op", ""),
                           "oracle": fields.get("oracle", "")})
    # unknown directive: surface it rather than silently ignoring a typo
    return Annotation(line, "unknown", {"text": body})


class SourceFile:
    """One parsed file: AST + tracelint comments, ready for rule passes."""

    def __init__(self, path: str, text: str):
        self.path = path
        self.text = text
        self.tree = ast.parse(text)
        self.annotations: list[Annotation] = []
        self.ignores: dict[int, frozenset] = {}
        for tok in tokenize.generate_tokens(io.StringIO(text).readline):
            if tok.type != tokenize.COMMENT:
                continue
            ann = _parse_annotation(tok.start[0], tok.string)
            if ann is None:
                continue
            if ann.kind == "ignore":
                self.ignores[ann.line] = ann.fields["codes"]
            else:
                self.annotations.append(ann)

    # -- annotation lookup --------------------------------------------------
    def annotation_for(self, node: ast.AST, kind: str) -> Optional[Annotation]:
        """The ``kind`` annotation attached to a def: on the def line, on a
        decorator line, or on its own line up to 2 lines above the first
        decorator (room for one explanatory comment line between)."""
        start = min([node.lineno]
                    + [d.lineno for d in getattr(node, "decorator_list", [])])
        lo, hi = start - 2, node.body[0].lineno if getattr(node, "body", None) \
            else node.lineno
        best = None
        for ann in self.annotations:
            if ann.kind == kind and lo <= ann.line <= hi:
                if best is None or ann.line > best.line:
                    best = ann
        return best

    def suppressed(self, line: int, code: str) -> bool:
        return code in self.ignores.get(line, frozenset())

    def finding(self, line: int, code: str, message: str
                ) -> Optional[Finding]:
        if self.suppressed(line, code):
            return None
        return Finding(self.path, line, code, message)
