"""Runtime compile-count sentinel: the dynamic half of tracelint.

The static pass (R1) proves the fused-fn cache KEYS are complete; this
module proves the caches actually stay BOUNDED at runtime. The engine's
pow2 bucketing (segment lengths, refill row counts, prompt widths, cache
caps) promises that a drain compiles O(log) distinct programs and that a
repeat drain over the same envelope compiles NOTHING — promises only a
counter can enforce.

:func:`compile_guard` wraps ``jax.log_compiles()``: every XLA
compilation inside the context is counted (and its name recorded) via
the ``Compiling <name> with global shapes`` log line, the total is
exported as a telemetry counter, and exceeding ``max_compiles`` raises
:class:`CompileBudgetExceeded` listing exactly what compiled — so a
recompile storm fails the test that budgeted against it instead of
showing up as a latency mystery in production traces.

    with compile_guard(max_compiles=0):        # warm path: no compiles
        engine.run(params)

    with compile_guard(max_compiles=12, match=r"impl") as log:
        first_drain()                          # fused fns only
    print(log.count, log.names)
"""
from __future__ import annotations

import contextlib
import logging
import re
from typing import Optional

import jax

from repro.core import telemetry

# jax logs one "Compiling <name> with global shapes and types [...]" line
# per actual XLA compilation (cache hits are silent) when log_compiles is
# on; tracing/lowering lines are deliberately NOT counted.
_COMPILE_RE = re.compile(r"^Compiling (.+?) with global shapes")


class CompileBudgetExceeded(RuntimeError):
    """More XLA compilations than the guarded region budgeted for."""


class CompileLog:
    """Mutable view yielded by :func:`compile_guard`."""

    def __init__(self) -> None:
        self.names: list[str] = []

    @property
    def count(self) -> int:
        return len(self.names)

    def __repr__(self) -> str:
        return f"CompileLog(count={self.count}, names={self.names!r})"


class _Capture(logging.Handler):
    def __init__(self, log: CompileLog, match: Optional[str]) -> None:
        super().__init__(level=logging.DEBUG)
        self._log = log
        self._match = re.compile(match) if match else None

    def emit(self, record: logging.LogRecord) -> None:
        m = _COMPILE_RE.match(record.getMessage())
        if not m:
            return
        name = m.group(1)
        if self._match is not None and not self._match.search(name):
            return
        self._log.names.append(name)


@contextlib.contextmanager
def compile_guard(max_compiles: Optional[int] = None, *,
                  match: Optional[str] = None,
                  counter: str = "analysis.compiles",
                  tel: Optional[telemetry.Telemetry] = None):
    """Count XLA compilations in the block; enforce a budget.

    - ``max_compiles=None`` only counts (and exports the counter);
      ``max_compiles=N`` raises :class:`CompileBudgetExceeded` when the
      block compiles more than N programs. ``max_compiles=0`` is the
      strongest form: the block must run entirely off warm jit caches.
    - ``match`` restricts counting to compiled-function names matching
      the regex (the repo's fused serving/training dispatches are all
      named ``impl``/``round_core``, so ``match=r"impl"`` isolates them
      from one-off convert/broadcast micro-compiles).
    - counts are exported to ``tel`` (default: the global telemetry
      registry) as counter ``analysis.compiles`` plus
      ``analysis.compile_guard_trips`` on budget violations.

    The guard composes with nested guards (each counts independently)
    and leaves ``jax_log_compiles`` exactly as it found it.
    """
    log = CompileLog()
    handler = _Capture(log, match)
    jax_logger = logging.getLogger("jax")
    prev_level = jax_logger.level
    jax_logger.addHandler(handler)
    # log_compiles emits at WARNING; make sure an app-configured stricter
    # level cannot starve the counter
    if prev_level > logging.WARNING:
        jax_logger.setLevel(logging.WARNING)
    # log_compiles also floods "Finished tracing/lowering" lines from the
    # dispatch logger; those are not compilations — keep them off stderr
    noisy = logging.getLogger("jax._src.dispatch")
    prev_noisy = noisy.level
    noisy.setLevel(logging.ERROR)
    try:
        with jax.log_compiles():
            yield log
    finally:
        jax_logger.removeHandler(handler)
        jax_logger.setLevel(prev_level)
        noisy.setLevel(prev_noisy)
        t = tel if tel is not None else telemetry.get()
        t.count(counter, log.count)
    if max_compiles is not None and log.count > max_compiles:
        t.count("analysis.compile_guard_trips")
        raise CompileBudgetExceeded(
            f"{log.count} XLA compilation(s) inside a "
            f"compile_guard(max_compiles={max_compiles}) region"
            + (f" (match={match!r})" if match else "")
            + f": {log.names}")
