"""tracelint: static analysis + runtime compile guards for the hot paths.

The serving/training hot paths (PRs 1-9) rest on invariants that are
invisible to the type checker and too easy to regress in review:

- every ``functools.lru_cache`` fused-fn factory's key tuple must contain
  EVERY value that shapes the traced graph (a missed key silently serves
  one specialization for another; a spurious key forks identical jits);
- the drain loop syncs the host exactly once per segment — a stray
  ``.item()`` / ``np.asarray`` / ``block_until_ready`` inside a jitted or
  scanned body (or the drain loop itself) turns a fused dispatch into a
  per-token round trip;
- hot-path clocks are ``time.perf_counter()`` (monotonic), never wall
  clocks;
- library code raises real exceptions, not bare ``assert``s;
- every Pallas kernel keeps its ``ref.py`` oracle and its
  xla|pallas|interpret ``ops.py`` dispatch;
- a donated buffer is dead after the donating call.

``python -m repro.analysis`` (or ``scripts/lint.sh``) machine-checks all
of the above over ``src/repro`` + ``tests`` as rules R1-R6 and exits
nonzero on any finding not in the checked-in baseline
(``scripts/lint_baseline.txt``). See README "lint rules" for the rule
table and the ``# tracelint:`` annotation/suppression syntax.

The runtime half, :mod:`repro.analysis.guards`, turns ``jax.log_compiles``
into :func:`compile_guard` — a context manager that counts XLA
compilations (exported as telemetry counters) and raises
:class:`CompileBudgetExceeded` past a budget, so tests can assert the
pow2 segment bucketing really does bound compilation per drain.
"""
from repro.analysis.base import Finding, SourceFile

__all__ = ["Finding", "SourceFile", "compile_guard", "CompileBudgetExceeded",
           "CompileLog"]


def __getattr__(name):
    # guards imports jax; keep the lint CLI import-light (sub-second) by
    # loading the runtime half only when asked for.
    if name in ("compile_guard", "CompileBudgetExceeded", "CompileLog"):
        from repro.analysis import guards
        return getattr(guards, name)
    raise AttributeError(name)
