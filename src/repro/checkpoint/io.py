"""Pytree checkpointing (npz) + parameter-efficient (adapter-only) checkpoints.

The adapter-only checkpoint is the storage/transport artifact of the paper's
*parameter-efficient inference* (§III-A.2, Fig 2): distributing a fine-tuned
model costs only the tunable modules' bytes, the frozen backbone being
presumed synchronized out-of-band. `core/relay.py` uses these to meter the
cloud-edge-end knowledge flows.
"""
from __future__ import annotations

import io
import json
import os
import tempfile
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "/"


def _atomic_savez(path: str, flat: dict) -> int:
    """Crash-safe npz write: savez to a temp file in the target directory,
    then ``os.replace`` into place — a crash mid-save leaves the previous
    checkpoint intact (readers only ever see a complete file). Returns
    bytes written."""
    d = os.path.dirname(path) or "."
    fd, tmp = tempfile.mkstemp(dir=d, prefix=os.path.basename(path) + ".",
                               suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as fh:
            np.savez(fh, **flat)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise
    return os.path.getsize(path)


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0][0:] or []:
        key = _SEP.join(_part(p) for p in path)
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype == jnp.bfloat16:
            flat[key + ".bf16"] = arr.view(np.uint16)
        else:
            flat[key] = arr
    return flat


def _part(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def save(path: str, tree) -> int:
    """Save a pytree of arrays (atomically). Returns bytes written."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    f = path if path.endswith(".npz") else path + ".npz"
    return _atomic_savez(f, flat)


def load(path: str, like: Optional[Any] = None):
    """Load into the structure of `like` (or a nested dict by key paths)."""
    f = path if path.endswith(".npz") else path + ".npz"
    raw = dict(np.load(f))
    arrays = {}
    for k, v in raw.items():
        if k.endswith(".bf16"):
            arrays[k[:-5]] = jnp.asarray(v.view(np.uint16)).view(jnp.bfloat16)
        else:
            arrays[k] = jnp.asarray(v)
    if like is None:
        out: dict = {}
        for k, v in arrays.items():
            node = out
            parts = k.split(_SEP)
            for p in parts[:-1]:
                node = node.setdefault(p, {})
            node[parts[-1]] = v
        return out
    leaves_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path, leaf in leaves_like:
        key = _SEP.join(_part(p) for p in path)
        if key not in arrays:
            raise KeyError(f"missing {key} in checkpoint")
        leaves.append(arrays[key].astype(leaf.dtype).reshape(leaf.shape))
    return jax.tree.unflatten(jax.tree.structure(like), leaves)


def save_adapters(path: str, params: dict) -> int:
    """Adapter-only checkpoint: the parameter-efficient transport unit."""
    return save(path, {"adapters": params["adapters"]})


def load_adapters(path: str, params: dict) -> dict:
    loaded = load(path, {"adapters": params["adapters"]})
    return {**params, "adapters": loaded["adapters"]}


def tree_bytes(tree) -> int:
    return sum(np.asarray(x).nbytes for x in jax.tree.leaves(tree))


# ---------------------------------------------------------------------------
# Quantized adapter transport (beyond-paper: D2D/CS links are the edge
# bottleneck, so squeeze the tunable modules further — int8 symmetric
# per-tensor-row quantization, ~2-4x over bf16/f32 adapters)
# ---------------------------------------------------------------------------

def _quantize(arr: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    a = np.asarray(arr, np.float32)
    flat = a.reshape(a.shape[0], -1) if a.ndim > 1 else a.reshape(1, -1)
    scale = np.abs(flat).max(axis=1, keepdims=True) / 127.0
    scale = np.maximum(scale, 1e-12)
    q = np.clip(np.round(flat / scale), -127, 127).astype(np.int8)
    return q.reshape(a.shape if a.ndim > 1 else a.shape), \
        scale.astype(np.float32)


def save_adapters_quantized(path: str, params: dict) -> int:
    """int8 adapter-only checkpoint. Returns bytes written."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = {}
    for p, leaf in jax.tree_util.tree_flatten_with_path(
            {"adapters": params["adapters"]})[0]:
        key = _SEP.join(_part(x) for x in p)
        arr = np.asarray(jax.device_get(leaf), np.float32)
        q, scale = _quantize(arr)
        flat[key + ".q8"] = q
        flat[key + ".scale"] = scale
        flat[key + ".dtype"] = np.frombuffer(
            str(jnp.dtype(leaf.dtype)).encode().ljust(16), np.uint8).copy()
    f = path if path.endswith(".npz") else path + ".npz"
    return _atomic_savez(f, flat)


def load_adapters_quantized(path: str, params: dict) -> dict:
    f = path if path.endswith(".npz") else path + ".npz"
    raw = dict(np.load(f))
    leaves_like, _ = jax.tree_util.tree_flatten_with_path(
        {"adapters": params["adapters"]})
    out = []
    for p, leaf in leaves_like:
        key = _SEP.join(_part(x) for x in p)
        q = raw[key + ".q8"].astype(np.float32)
        scale = raw[key + ".scale"]
        flat = q.reshape(q.shape[0], -1) if q.ndim > 1 else q.reshape(1, -1)
        deq = (flat * scale).reshape(leaf.shape)
        out.append(jnp.asarray(deq).astype(leaf.dtype))
    tree = jax.tree.unflatten(
        jax.tree.structure({"adapters": params["adapters"]}), out)
    return {**params, "adapters": tree["adapters"]}
