"""Pure-JAX optimizers (no optax in this environment).

Optax-like (init, update) pairs over arbitrary pytrees. Under the paper's
PEFT regime the optimizer only ever sees the ``adapters`` subtree, so state
is adapter-sized (the point of parameter-efficient fine-tuning: optimizer
memory ~ tunable params, not backbone).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[..., tuple[Any, Any]]   # (grads, state, params) -> (updates, state)


def _tmap(f, *trees):
    return jax.tree.map(f, *trees)


def sgd(lr: float | Callable[[jax.Array], jax.Array],
        momentum: float = 0.0) -> Optimizer:
    def init(params):
        mu = _tmap(jnp.zeros_like, params) if momentum else None
        return {"step": jnp.zeros((), jnp.int32), "mu": mu}

    def update(grads, state, params=None):
        step = state["step"] + 1
        lr_t = lr(step) if callable(lr) else lr
        if momentum:
            mu = _tmap(lambda m, g: momentum * m + g.astype(m.dtype),
                       state["mu"], grads)
            upd = _tmap(lambda m: (-lr_t * m), mu)
            return upd, {"step": step, "mu": mu}
        return _tmap(lambda g: -lr_t * g, grads), {"step": step, "mu": None}

    return Optimizer(init, update)


def adamw(lr: float | Callable[[jax.Array], jax.Array], b1: float = 0.9,
          b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": _tmap(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "v": _tmap(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = lr(step) if callable(lr) else lr
        m = _tmap(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                  state["m"], grads)
        v = _tmap(lambda v_, g: b2 * v_ + (1 - b2)
                  * jnp.square(g.astype(jnp.float32)), state["v"], grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(m_, v_, p):
            u = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (-lr_t * u).astype(p.dtype)

        return _tmap(upd, m, v, params), {"step": step, "m": m, "v": v}

    return Optimizer(init, update)


def apply_updates(params, updates):
    return _tmap(lambda p, u: (p + u.astype(p.dtype)), params, updates)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves)) if leaves else jnp.zeros(())


def clip_by_global_norm(tree, max_norm: float):
    n = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-9))
    return _tmap(lambda x: x * scale.astype(x.dtype), tree), n
