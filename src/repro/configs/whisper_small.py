"""whisper-small — encoder-decoder, conv frontend stubbed [arXiv:2212.04356]."""
from repro.configs.base import AudioConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-small", family="audio", citation="arXiv:2212.04356",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, head_dim=64,
    d_ff=3072, vocab_size=51865,
    audio=AudioConfig(n_enc_layers=12, n_audio_frames=1500),
))
