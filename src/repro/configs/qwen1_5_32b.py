"""qwen1.5-32b — dense MHA (kv=40) with QKV bias [hf:Qwen/Qwen1.5-0.5B]."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen1.5-32b", family="dense", citation="hf:Qwen/Qwen1.5-0.5B",
    n_layers=64, d_model=5120, n_heads=40, n_kv_heads=40, head_dim=128,
    d_ff=27392, vocab_size=152064, qkv_bias=True, rope_theta=1e6,
))
