"""Model / run configuration system.

Every assigned architecture is a `ModelConfig` registered under its public id
(``--arch <id>``). Configs are plain frozen dataclasses so they can be hashed
into jit static args and round-tripped through launch scripts.

The four assigned input shapes live in `INPUT_SHAPES`; each carries the step
kind it lowers (train / prefill / decode) per the spec.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Architecture config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    n_shared_experts: int = 0          # kimi-k2 style always-on shared expert
    router_aux_loss: float = 0.01      # load-balance loss weight
    router_jitter: float = 0.0
    capacity_factor: float = 1.25      # expert buffer slack (tokens dropped beyond)


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2                    # d_inner = expand * d_model
    dt_rank: int = 0                   # 0 -> ceil(d_model / 16)


@dataclass(frozen=True)
class HybridConfig:
    """RecurrentGemma-style block pattern."""
    pattern: Tuple[str, ...] = ("rglru", "rglru", "attn")   # 1:2 attn:recurrent
    tail: Tuple[str, ...] = ()          # unrolled remainder layers
    lru_width: int = 0                  # 0 -> d_model
    conv_width: int = 4
    window: int = 2048                  # local-attention window


@dataclass(frozen=True)
class VLMConfig:
    n_vis_tokens: int = 576             # patch embeddings supplied by the (stubbed) tower
    vis_embed_dim: int = 0              # 0 -> d_model (projector output dim)


@dataclass(frozen=True)
class AudioConfig:
    n_enc_layers: int = 12
    n_audio_frames: int = 1500          # post-conv frame count (stub supplies embeddings)


@dataclass(frozen=True)
class PEFTConfig:
    """Paper §III-A: prompt modules + head are the tunable part; backbone frozen."""
    n_prefix: int = 16                  # prefix-KV tokens per attention layer
    lora_rank: int = 8
    lora_alpha: float = 16.0
    lora_targets: Tuple[str, ...] = ("q", "v")
    head_dim_out: int = 0               # classification head width; 0 -> LM head reuse
    state_prompt: bool = True           # learned initial state for SSM / RG-LRU layers


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                         # dense | moe | ssm | hybrid | vlm | audio
    citation: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                   # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    rms_norm_eps: float = 1e-6
    tie_embeddings: bool = False
    attn_variant: str = "full"          # full | sliding
    sliding_window: int = 4096
    dtype: str = "bfloat16"
    moe: MoEConfig = field(default_factory=MoEConfig)
    ssm: SSMConfig = field(default_factory=SSMConfig)
    hybrid: HybridConfig = field(default_factory=HybridConfig)
    vlm: VLMConfig = field(default_factory=VLMConfig)
    audio: AudioConfig = field(default_factory=AudioConfig)
    peft: PEFTConfig = field(default_factory=PEFTConfig)

    # -- derived ------------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def d_inner(self) -> int:
        return self.ssm.expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return self.ssm.dt_rank or math.ceil(self.d_model / 16)

    @property
    def lru_width(self) -> int:
        return self.hybrid.lru_width or self.d_model

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # -- analytic parameter counts (for rooflines / MODEL_FLOPS) ------------
    def param_count(self) -> int:
        """Total backbone parameters (analytic, matches init to within ties)."""
        d, hd = self.d_model, self.head_dim_
        emb = self.vocab_size * d
        lm_head = 0 if self.tie_embeddings else self.vocab_size * d
        bias = d if self.qkv_bias else 0

        def attn_p(n_h, n_kv):
            q = d * n_h * hd + (bias and n_h * hd)
            kv = 2 * (d * n_kv * hd + (bias and n_kv * hd))
            o = n_h * hd * d
            return q + kv + o

        def mlp_p(ff):
            return 3 * d * ff            # gated (SwiGLU-style)

        def moe_p():
            m = self.moe
            per = 3 * d * m.d_ff_expert
            return (m.n_experts + m.n_shared_experts) * per + d * m.n_experts

        def ssm_p():
            di, ds, dr = self.d_inner, self.ssm.d_state, self.dt_rank
            return (d * 2 * di            # in_proj (x, z)
                    + di * self.ssm.d_conv
                    + di * (dr + 2 * ds)  # x_proj
                    + dr * di + di        # dt_proj
                    + di * ds + di        # A_log, D
                    + di * d)             # out_proj

        def rglru_p():
            w = self.lru_width
            return (d * 2 * w + w * self.hybrid.conv_width * 2  # in proj + conv
                    + 2 * w               # a_param, input gate params (diagonal)
                    + 2 * w * w           # gates (rg, input) dense
                    + w * d)              # out proj

        norms = 2 * d
        if self.family == "ssm":
            layer = ssm_p() + d
        elif self.family == "moe":
            layer = attn_p(self.n_heads, self.n_kv_heads) + moe_p() + norms
        elif self.family == "hybrid":
            pat = list(self.hybrid.pattern)
            n_block = (self.n_layers - len(self.hybrid.tail)) // len(pat)
            tot = 0
            for kind in pat * n_block + list(self.hybrid.tail):
                tot += (attn_p(self.n_heads, self.n_kv_heads) if kind == "attn"
                        else rglru_p()) + mlp_p(self.d_ff) + norms
            return emb + lm_head + tot + d
        elif self.family == "audio":
            enc = self.audio.n_enc_layers * (attn_p(self.n_heads, self.n_kv_heads)
                                             + mlp_p(self.d_ff) + norms)
            dec = self.n_layers * (2 * attn_p(self.n_heads, self.n_kv_heads)
                                   + mlp_p(self.d_ff) + 3 * d)
            return emb + lm_head + enc + dec + d
        else:                              # dense / vlm
            layer = attn_p(self.n_heads, self.n_kv_heads) + mlp_p(self.d_ff) + norms
        return emb + lm_head + self.n_layers * layer + d

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k + shared experts only)."""
        if self.family != "moe":
            return self.param_count()
        m = self.moe
        per = 3 * self.d_model * m.d_ff_expert
        inactive = (m.n_experts - m.top_k) * per
        return self.param_count() - self.n_layers * inactive

    # -- reduced variant for CPU smoke tests --------------------------------
    def reduced(self) -> "ModelConfig":
        """Same family, 2 layers, d_model<=256, <=4 experts (smoke tests)."""
        d = min(self.d_model, 256)
        n_h = min(self.n_heads, 4)
        n_kv = max(1, min(self.n_kv_heads, n_h))
        kw = dict(
            n_layers=2, d_model=d, n_heads=n_h, n_kv_heads=n_kv,
            head_dim=d // n_h, d_ff=min(self.d_ff, 4 * d) or 0,
            vocab_size=min(self.vocab_size, 512), sliding_window=64,
            peft=dataclasses.replace(self.peft, n_prefix=4, lora_rank=4),
        )
        if self.family == "moe":
            kw["moe"] = dataclasses.replace(
                self.moe, n_experts=4, top_k=2, d_ff_expert=min(self.moe.d_ff_expert, d),
                n_shared_experts=min(self.moe.n_shared_experts, 1))
        if self.family == "hybrid":
            kw["n_layers"] = 3
            kw["hybrid"] = dataclasses.replace(
                self.hybrid, tail=(), lru_width=d, window=32)
        if self.family == "vlm":
            kw["vlm"] = dataclasses.replace(self.vlm, n_vis_tokens=16)
        if self.family == "audio":
            kw["n_layers"] = 2
            kw["audio"] = dataclasses.replace(self.audio, n_enc_layers=2, n_audio_frames=32)
        return self.with_(**kw)


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                            # train | prefill | decode


INPUT_SHAPES = {
    "train_4k":    InputShape("train_4k",    4_096,   256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  InputShape("decode_32k",  32_768,  128, "decode"),
    "long_500k":   InputShape("long_500k",   524_288, 1,   "decode"),
}


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        _load_all()
    return _REGISTRY[name]


def list_configs() -> list[str]:
    _load_all()
    return sorted(_REGISTRY)


def _load_all() -> None:
    from repro.configs import (  # noqa: F401
        falcon_mamba_7b, kimi_k2_1t_a32b, recurrentgemma_2b, qwen2_7b,
        llava_next_mistral_7b, qwen1_5_32b, qwen2_5_32b, qwen2_5_14b,
        granite_moe_1b_a400m, whisper_small, vit_edge)
