"""kimi-k2-1t-a32b — trillion-param MoE, 384 experts top-8 [arXiv:2501.kimi2].

Homogenized: the first dense layer is folded into the uniform 61-layer MoE
stack so layers scan (DESIGN.md §6).
"""
from repro.configs.base import ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    name="kimi-k2-1t-a32b", family="moe", citation="arXiv:2501.kimi2",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=2048, vocab_size=163840,
    moe=MoEConfig(n_experts=384, top_k=8, d_ff_expert=2048, n_shared_experts=1),
))
