"""llava-next-mistral-7b — VLM, anyres tiling (vision tower stubbed)
[hf:llava-hf/llava-v1.6-mistral-7b-hf]. Mistral backbone uses SWA-4096."""
from repro.configs.base import ModelConfig, VLMConfig, register

CONFIG = register(ModelConfig(
    name="llava-next-mistral-7b", family="vlm",
    citation="hf:llava-hf/llava-v1.6-mistral-7b-hf",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=32000, attn_variant="sliding", sliding_window=4096,
    vlm=VLMConfig(n_vis_tokens=576),
))
