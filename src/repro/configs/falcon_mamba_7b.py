"""falcon-mamba-7b — attention-free Mamba-1 SSM [arXiv:2410.05355]."""
from repro.configs.base import ModelConfig, SSMConfig, register

CONFIG = register(ModelConfig(
    name="falcon-mamba-7b", family="ssm", citation="arXiv:2410.05355",
    n_layers=64, d_model=4096, n_heads=1, n_kv_heads=1, d_ff=0,
    vocab_size=65024, head_dim=64,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
))
