"""recurrentgemma-2b — RG-LRU + local attention, 1:2 [arXiv:2402.19427].

26 layers = 8 scanned (rglru, rglru, attn) blocks + unrolled (rglru, rglru) tail.
"""
from repro.configs.base import ModelConfig, HybridConfig, register

CONFIG = register(ModelConfig(
    name="recurrentgemma-2b", family="hybrid", citation="arXiv:2402.19427",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, head_dim=256,
    d_ff=7680, vocab_size=256000, tie_embeddings=True,
    hybrid=HybridConfig(pattern=("rglru", "rglru", "attn"),
                        tail=("rglru", "rglru"), lru_width=2560, window=2048),
))
