"""vit-edge — the paper's own case study backbone (ViT-B/16-like encoder used
for the flower-classification GaisNet experiments, §V) at edge scale."""
from repro.configs.base import ModelConfig, PEFTConfig, register

CONFIG = register(ModelConfig(
    name="vit-edge", family="dense", citation="paper §V (ViT-B/16 case study)",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, head_dim=64,
    d_ff=3072, vocab_size=1000,
    peft=PEFTConfig(n_prefix=16, lora_rank=8, head_dim_out=5),
))
