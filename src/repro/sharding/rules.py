"""Logical-axis sharding rules.

Single source of truth for parameter/activation layout:

- every parameter is declared once as a :class:`ParamSpec` (shape, dtype,
  logical axis names). From the spec tree we derive (a) initialized arrays,
  (b) `jax.ShapeDtypeStruct` stand-ins for the no-allocation dry-run, and
  (c) `PartitionSpec` trees for `jax.jit` in/out shardings.
- activations are constrained in model code via :func:`shard` using the same
  logical names, resolved against the active rule set.

Rules map a logical axis name -> mesh axis (str), tuple of mesh axes, or
``None`` (replicated). Rule sets are plain dicts so perf experiments can swap
them per run (see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# Rule sets
# ---------------------------------------------------------------------------

# Production rules for the ('pod', 'data', 'model') mesh. On the single-pod
# ('data', 'model') mesh, the 'pod' axis name is simply absent and is dropped
# when resolving (see _resolve).
DEFAULT_RULES: dict[str, Any] = {
    # activations
    "batch": ("pod", "data"),
    "cluster": ("pod", "data"),       # HFSL client-cluster axis (core/hfsl.py)
    "seq": None,
    "attn_seq": None,                 # seq dim *inside* mixers/MLPs: always
                                      # replicated so SP reshards at entry
    "kv_seq": "model",                # KV caches shard their seq dim (heads
                                      # rarely divide 16); long_500k decode
                                      # overrides to ('pod','data')
    "kv_blocks": ("pod", "data"),     # paged KV block pool: blocks over the
                                      # batch axes (any row's table may name
                                      # any block, so the pool cannot follow
                                      # `batch`; block count scales with
                                      # aggregate wave size like batch does)
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "d_model": None,
    "act_ff": "model",
    "act_experts": "model",
    # weights
    "fsdp": ("pod", "data"),          # second weight dim, ZeRO-3 style
    "moe_fsdp": ("pod", "data"),      # expert-weight d_model dim
    "d_ff": "model",
    "experts": "model",
    "vocab": "model",
    "d_inner": "model",
    "state": None,
    "conv": None,
    "lru": "model",
    "lora_rank": None,
    "prefix": None,
    "stage": "model",                 # SL pipeline stage axis (tests use a tiny mesh)
    "frames": None,
    "slots": ("pod", "data"),         # AdapterBank tenant-slot axis
}


def long_decode_rules() -> dict[str, Any]:
    """batch=1 decode: shard the KV-cache sequence dim instead of batch."""
    r = dict(DEFAULT_RULES)
    r["batch"] = None
    r["cluster"] = None
    r["kv_seq"] = ("pod", "data")
    return r


def moe_serving_rules() -> dict[str, Any]:
    """Inference-mode MoE sharding (EXPERIMENTS.md §Perf, kimi hillclimb).

    Training FSDP-shards expert weights over (pod, data) — correct when the
    all-gather amortizes over a big fwd+bwd, catastrophic for inference
    (every prefill re-gathers ~2 TB of experts). Serving flips to static
    expert parallelism: experts over `data` (384/16=24 per group), the
    expert d_model dim over `model`; tokens all-to-all to the expert shards
    (activation-sized traffic instead of weight-sized).
    """
    r = dict(DEFAULT_RULES)
    r["experts"] = "data"
    r["moe_fsdp"] = "model"
    r["act_experts"] = "data"
    return r


def serving_rules() -> dict[str, Any]:
    """Engine-wave serving rules (launch/engine.py mesh-native drains).

    The ragged continuous-batching wave shards its batch (slot) dim over
    (`pod`, `data`) and head/FF dims over `model`. Unlike DEFAULT_RULES the
    KV-cache seq dim stays replicated: the wave's per-row cache-slot
    scatter (`.at[rows, slot].set`) and the in-wave refill row-scatter
    address single positions along seq — sharding it would turn every
    decode-step write into a cross-device update. AdapterBank slot dims
    ride `data` (slot-parallel multi-tenant serving).
    """
    r = dict(DEFAULT_RULES)
    r["kv_seq"] = None
    return r


def drafter_rules() -> dict[str, Any]:
    """Speculative-decoding drafter rules: weights fully REPLICATED.

    The drafter is tiny — sharding its weights over `model` would trade a
    collective per draft step for negligible memory, and every device
    needs the whole drafter to propose for its local batch shard anyway.
    Activation batch dims keep the wave sharding over (`pod`, `data`)
    (the target's verify pass rides serving_rules unchanged); every other
    logical axis resolves to replicated.
    """
    keep = {"batch", "cluster", "slots"}
    return {k: (DEFAULT_RULES[k] if k in keep else None)
            for k in DEFAULT_RULES}


def train_rules(family: str) -> dict[str, Any]:
    """Per-family training rules (DESIGN.md §4 / EXPERIMENTS.md §Dry-run).

    - attention families: Megatron-style sequence parallelism — the residual
      stream shards its seq dim over `model`, bounding the remat carry
      (seq/16 per chip) at the cost of gather/scatter at layer boundaries.
    - recurrent families (ssm / hybrid): the time scan cannot shard seq, so
      the *per-cluster batch* shards over `model` instead.
    The inner `batch` rule is None in both cases when training under HFSL —
    the leading `cluster` dim carries the (pod, data) sharding.
    """
    r = dict(DEFAULT_RULES)
    r["batch"] = None
    if family in ("ssm", "hybrid"):
        r["batch"] = "model"
    else:
        r["seq"] = "model"
    return r


def hfsl_round_rules(family: str) -> dict[str, Any]:
    """Rules for the EXECUTED fused HFSL round (hfsl.make_hfsl_round).

    Same as :func:`train_rules` minus sequence parallelism: the SP
    gather/scatter inside the cluster-vmapped value_and_grad miscomputes
    VALUES (not just layout) under XLA:CPU SPMD on forced-host-device test
    meshes, and the round's parallelism story is the cluster dim on
    (`pod`, `data`) — pinned by the round's jit in/out shardings — with
    tensor parallelism over `model` inside each cluster. Re-enabling SP
    for real-TPU rounds is a ROADMAP follow-up; the dry-run still lowers
    the full train_rules SP path.
    """
    r = train_rules(family)
    r["seq"] = None
    return r


# ---------------------------------------------------------------------------
# Active context
# ---------------------------------------------------------------------------

_ctx = threading.local()


def _get() -> tuple[Optional[Mesh], Optional[dict]]:
    return getattr(_ctx, "mesh", None), getattr(_ctx, "rules", None)


@contextlib.contextmanager
def use_rules(mesh: Optional[Mesh], rules: Optional[dict] = None):
    """Activate (mesh, rules) for `shard()` constraints inside model code."""
    prev = _get()
    _ctx.mesh, _ctx.rules = mesh, (rules or DEFAULT_RULES)
    try:
        yield
    finally:
        _ctx.mesh, _ctx.rules = prev


def _resolve(axes: Sequence[Optional[str]], rules: dict, mesh: Mesh) -> P:
    """Logical axis names -> PartitionSpec.

    Mesh axes absent from the mesh are dropped; a mesh axis may appear only
    once per spec (earlier logical axes win — e.g. with sequence parallelism
    `seq` takes `model` and `heads` degrades to replicated)."""
    out = []
    used: set = set()
    for name in axes:
        tgt = rules.get(name) if name is not None else None
        if tgt is None:
            out.append(None)
            continue
        tgt_t = (tgt,) if isinstance(tgt, str) else tuple(tgt)
        tgt_t = tuple(a for a in tgt_t
                      if a in mesh.axis_names and a not in used)
        used.update(tgt_t)
        out.append(tgt_t if len(tgt_t) > 1 else (tgt_t[0] if tgt_t else None))
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def spec_for(axes: Sequence[Optional[str]], mesh: Mesh,
             rules: Optional[dict] = None) -> P:
    return _resolve(axes, rules or DEFAULT_RULES, mesh)


def shard(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """Apply a with_sharding_constraint by logical names (no-op w/o context)."""
    mesh, rules = _get()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, _resolve(axes, rules, mesh)))


# ---------------------------------------------------------------------------
# ParamSpec
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Declaration of one parameter: shape + dtype + logical layout + init."""
    shape: tuple[int, ...]
    dtype: Any = jnp.bfloat16
    axes: tuple[Optional[str], ...] = ()
    init: str = "normal"              # normal | zeros | ones | scaled
    scale: float = 0.02

    def __post_init__(self):
        # a real error, not an assert: layout declarations are config-file
        # territory and must fail loudly even under `python -O`
        if len(self.axes) not in (0, len(self.shape)):
            raise ValueError(
                f"ParamSpec axes {self.axes} must be empty or name one "
                f"logical axis per dim of shape {self.shape}")


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def init_from_spec(key: jax.Array, tree) -> Any:
    """Materialize a ParamSpec tree into initialized arrays."""
    leaves, treedef = jax.tree.flatten(tree, is_leaf=_is_spec)
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, s in zip(keys, leaves):
        if s.init == "zeros":
            out.append(jnp.zeros(s.shape, s.dtype))
        elif s.init == "ones":
            out.append(jnp.ones(s.shape, s.dtype))
        elif s.init == "scaled":  # fan-in scaled normal
            fan_in = s.shape[-2] if len(s.shape) >= 2 else max(s.shape[-1], 1)
            w = jax.random.normal(k, s.shape, jnp.float32) / np.sqrt(fan_in)
            out.append(w.astype(s.dtype))
        else:
            w = jax.random.normal(k, s.shape, jnp.float32) * s.scale
            out.append(w.astype(s.dtype))
    return jax.tree.unflatten(treedef, out)


def shape_structs(tree) -> Any:
    """ParamSpec tree -> ShapeDtypeStruct tree (dry-run: no allocation)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), tree, is_leaf=_is_spec)


def fit_spec(p: P, shape: tuple[int, ...], mesh: Mesh) -> P:
    """Drop mesh axes whose product does not divide the dim size.

    jit in/out shardings (unlike with_sharding_constraint) require exact
    divisibility; e.g. 8 kv heads cannot shard over a 16-way `model` axis.
    Tuples degrade gracefully: ('pod','data') -> ('pod',) -> None.
    """
    out = []
    used: set = set()
    for i, entry in enumerate(p):
        if entry is None or i >= len(shape):
            out.append(None if i >= len(shape) else entry)
            continue
        axes = [entry] if isinstance(entry, str) else list(entry)
        axes = [a for a in axes if a not in used]   # an axis maps once

        def prod(a):
            n = 1
            for x in a:
                n *= mesh.shape[x]
            return n
        while axes and shape[i] % prod(axes) != 0:
            axes.pop()
        used.update(axes)
        out.append(tuple(axes) if len(axes) > 1 else (axes[0] if axes else None))
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def partition_specs(tree, mesh: Mesh, rules: Optional[dict] = None) -> Any:
    """ParamSpec tree -> PartitionSpec tree for jit in/out shardings
    (shape-aware: non-dividing axes are dropped per fit_spec)."""
    r = rules or DEFAULT_RULES
    return jax.tree.map(
        lambda s: fit_spec(_resolve(s.axes, r, mesh), s.shape, mesh),
        tree, is_leaf=_is_spec)


def named_shardings(tree, mesh: Mesh, rules: Optional[dict] = None) -> Any:
    return jax.tree.map(lambda p: NamedSharding(mesh, p),
                        partition_specs(tree, mesh, rules),
                        is_leaf=lambda x: isinstance(x, P))


def dim_sharding(mesh: Mesh, size: int, logical: str, *, index: int = 0,
                 rules: Optional[dict] = None) -> NamedSharding:
    """NamedSharding placing ONE dim (at ``index``) on its logical axis.

    The workhorse for arrays that are not ParamSpec-declared (BatchBank
    rows, AdapterBank slot stacks): dim ``index`` of size ``size`` goes to
    the mesh axes ``rules[logical]`` resolves to, every other dim stays
    replicated. Non-dividing mesh axes are dropped per :func:`fit_spec`
    (device_put / jit shardings require exact divisibility), so e.g. 3
    tenant slots on a 2-way `data` axis degrade gracefully to replicated.
    """
    p = _resolve((None,) * index + (logical,), rules or DEFAULT_RULES, mesh)
    p = fit_spec(p, (1,) * index + (int(size),), mesh)
    return NamedSharding(mesh, p)


def param_bytes(tree) -> int:
    leaves = jax.tree.leaves(tree, is_leaf=_is_spec)
    return sum(int(np.prod(s.shape)) * jnp.dtype(s.dtype).itemsize for s in leaves)
