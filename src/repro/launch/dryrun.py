import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST precede every other import: jax pins the host
# device count at first initialization. (REPRO_DRYRUN_DEVICES overrides for
# the subprocess smoke tests only.)
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_DRYRUN_DEVICES"])

# Multi-pod dry-run: lower + compile every (arch x input-shape) on the
# production mesh, extract memory analysis, cost analysis, roofline terms.
#
# Usage:
#   PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k
#   PYTHONPATH=src python -m repro.launch.dryrun --all --json results/dryrun.json
# Flags: --multi-pod (2x16x16 mesh), --json <path>.
# (No module docstring: the XLA_FLAGS env assignment must be the first
# statements in the file, before any jax-importing module.)

import argparse
import dataclasses
import functools
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import INPUT_SHAPES, ModelConfig, get_config, list_configs
from repro.core import hfsl
from repro.launch.mesh import data_parallel_size, make_production_mesh
from repro.launch import roofline as rl
from repro.models import model as M
from repro.optim.optimizers import adamw
from repro.sharding import rules as R

# ---- perf knobs (EXPERIMENTS.md §Perf) ------------------------------------
if os.environ.get("REPRO_SSM_IMPL"):
    from repro.kernels import ops as _kops
    _kops.set_ssm_xla_impl(os.environ["REPRO_SSM_IMPL"])
if os.environ.get("REPRO_FLASH_BLOCKS"):
    from repro.kernels import ops as _kops2
    _bq, _bkv = map(int, os.environ["REPRO_FLASH_BLOCKS"].split(","))
    _kops2.set_flash_blocks(_bq, _bkv)

ASSIGNED = [
    "falcon-mamba-7b", "kimi-k2-1t-a32b", "recurrentgemma-2b", "qwen2-7b",
    "llava-next-mistral-7b", "qwen1.5-32b", "qwen2.5-32b", "qwen2.5-14b",
    "granite-moe-1b-a400m", "whisper-small",
]

# (arch, shape) pairs that are semantically inapplicable (DESIGN.md §6)
SKIPS = {
    ("whisper-small", "long_500k"):
        "enc-dec with full self+cross attention and a 448-position decoder; "
        "no sub-quadratic variant in its family",
}


def variant_for(cfg: ModelConfig, shape_name: str) -> ModelConfig:
    """long_500k on full-attention archs -> sliding-window variant."""
    if shape_name == "long_500k" and cfg.family in ("dense", "vlm", "moe") \
            and cfg.attn_variant != "sliding":
        return cfg.with_(attn_variant="sliding", sliding_window=4096)
    return cfg


def _input_sharding_tree(batch_structs, mesh, rules, *, cluster: bool):
    def leaf_spec(v):
        lead = "cluster" if cluster else "batch"
        axes = (lead,) + (None,) * (len(v.shape) - 1)
        p = R.fit_spec(R.spec_for(axes, mesh, rules), v.shape, mesh)
        return NamedSharding(mesh, p)
    return jax.tree.map(leaf_spec, batch_structs)


def _clusterize(batch_structs, n_clusters: int):
    def f(v):
        b = v.shape[0]
        if b % n_clusters != 0:
            raise ValueError(f"batch {b} does not split evenly over "
                             f"{n_clusters} clusters")
        return jax.ShapeDtypeStruct((n_clusters, b // n_clusters, *v.shape[1:]),
                                    v.dtype)
    return jax.tree.map(f, batch_structs)


def build_lowered(arch: str, shape_name: str, *, multi_pod: bool = False,
                  rules_override=None, remat: bool = True,
                  donate: bool = True, reduced: bool = False,
                  mesh=None):
    """Lower the appropriate step for (arch, shape) on the production mesh.

    Returns (lowered, meta) — meta carries cfg/shape/mesh info for reports.
    ``reduced=True`` shrinks config+shape for subprocess smoke tests.
    """
    from repro.configs.base import InputShape
    cfg = variant_for(get_config(arch), shape_name)
    shape = INPUT_SHAPES[shape_name]
    if reduced:
        cfg = variant_for(get_config(arch).reduced(), shape_name)
        cfg = cfg.with_(sliding_window=64)
        shape = InputShape(shape.name, 128, 16, shape.kind)
    if mesh is None:
        mesh = make_production_mesh(multi_pod=multi_pod)
    chips = 1
    for n in mesh.shape.values():
        chips *= n

    if shape.kind == "train":
        rules = rules_override or R.train_rules(cfg.family)
        C = data_parallel_size(mesh)
        opt = adamw(1e-4)
        state_spec = hfsl.hfsl_state_spec(cfg, C, opt, M.model_spec)
        state_structs = R.shape_structs(state_spec)
        state_sh = jax.tree.map(lambda p: NamedSharding(mesh, p),
                                R.partition_specs(state_spec, mesh, rules))
        batch_structs = _clusterize(M.input_specs(cfg, shape), C)
        batch_sh = _input_sharding_tree(batch_structs, mesh, rules,
                                        cluster=True)

        def loss_fn(params, batch, cfg_):
            return M.lm_loss(params, batch, cfg_, remat=remat)

        step = hfsl.make_hfsl_step(cfg, opt, loss_fn, always_sync=True)

        def train_step(state, batch):
            with R.use_rules(mesh, rules):
                return step(state, batch)

        jitted = jax.jit(train_step,
                         in_shardings=(state_sh, batch_sh),
                         out_shardings=(state_sh, None),
                         donate_argnums=(0,) if donate else ())
        lowered = jitted.lower(state_structs, batch_structs)

    elif shape.kind == "prefill":
        rules = rules_override or (
            R.moe_serving_rules()
            if (cfg.family == "moe"
                and os.environ.get("REPRO_MOE_SERVE", "0") == "1")
            else dict(R.DEFAULT_RULES))
        param_spec = M.model_spec(cfg)
        param_structs = R.shape_structs(param_spec)
        param_sh = jax.tree.map(lambda p: NamedSharding(mesh, p),
                                R.partition_specs(param_spec, mesh, rules))
        batch_structs = M.input_specs(cfg, shape)
        batch_sh = _input_sharding_tree(batch_structs, mesh, rules,
                                        cluster=False)

        def prefill_step(params, batch):
            with R.use_rules(mesh, rules):
                return M.prefill(params, batch, cfg)

        lowered = jax.jit(prefill_step,
                          in_shardings=(param_sh, batch_sh)).lower(
            param_structs, batch_structs)

    else:  # decode
        rules = rules_override or (
            R.long_decode_rules() if shape.global_batch == 1
            else dict(R.DEFAULT_RULES))
        param_spec = M.model_spec(cfg)
        param_structs = R.shape_structs(param_spec)
        param_sh = jax.tree.map(lambda p: NamedSharding(mesh, p),
                                R.partition_specs(param_spec, mesh, rules))
        window = cfg.sliding_window if cfg.attn_variant == "sliding" else 0
        cache_len = min(window, shape.seq_len) if window else shape.seq_len
        cache_spec = M.cache_spec(cfg, shape.global_batch, cache_len)
        cache_structs = R.shape_structs(cache_spec)
        cache_sh = jax.tree.map(lambda p: NamedSharding(mesh, p),
                                R.partition_specs(cache_spec, mesh, rules))
        batch_structs = M.input_specs(cfg, shape)
        batch_sh = _input_sharding_tree(batch_structs, mesh, rules,
                                        cluster=False)
        pos_struct = jax.ShapeDtypeStruct((), jnp.int32)

        def serve_step(params, token, caches, pos):
            with R.use_rules(mesh, rules):
                return M.decode_step(params, token, caches, pos, cfg)

        jitted = jax.jit(serve_step,
                         in_shardings=(param_sh, batch_sh["token"],
                                       cache_sh, None),
                         out_shardings=(None, cache_sh),
                         donate_argnums=(2,) if donate else ())
        lowered = jitted.lower(param_structs, batch_structs["token"],
                               cache_structs, pos_struct)

    meta = {"arch": arch, "shape": shape_name,
            "mesh": "x".join(str(mesh.shape[a]) for a in mesh.axis_names),
            "chips": chips, "kind": shape.kind,
            "family": cfg.family, "cfg": cfg, "shape_obj": shape}
    return lowered, meta


def _memory_analysis_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    if ma is None:
        return {}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes", "peak_memory_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            rules_override=None, verbose: bool = True) -> dict:
    if (arch, shape_name) in SKIPS:
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": SKIPS[(arch, shape_name)]}
    t0 = time.perf_counter()
    lowered, meta = build_lowered(arch, shape_name, multi_pod=multi_pod,
                                  rules_override=rules_override)
    t_lower = time.perf_counter() - t0
    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0

    mem = _memory_analysis_dict(compiled)
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0] if ca else {}
    xla_costs = {k: float(ca.get(k, 0.0)) for k in ("flops", "bytes accessed")}

    costs = rl.analyze_hlo_text(compiled.as_text())
    model_flops = rl.model_flops_for(meta["cfg"], meta["shape_obj"])
    roof = rl.Roofline.from_costs(
        costs, arch=arch, shape=shape_name, mesh=meta["mesh"],
        chips=meta["chips"], model_flops=model_flops, memory_analysis=mem)

    result = {
        "arch": arch, "shape": shape_name, "mesh": meta["mesh"],
        "chips": meta["chips"], "kind": meta["kind"], "status": "ok",
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory_analysis": mem, "xla_cost_analysis": xla_costs,
        "roofline": roof.asdict(),
    }
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} mesh={meta['mesh']} OK "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)")
        print(f"  memory_analysis: {mem}")
        print(f"  flops/dev={costs.flops:.3e} bytes/dev={costs.bytes_accessed:.3e} "
              f"coll/dev={costs.collective_bytes:.3e}")
        print(f"  terms: compute={roof.compute_s:.4f}s memory={roof.memory_s:.4f}s "
              f"collective={roof.collective_s:.4f}s -> {roof.bottleneck}-bound; "
              f"useful={roof.useful_ratio:.3f}")
    return result


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default="all")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--json", default=None)
    args = ap.parse_args(argv)

    archs = ASSIGNED if (args.all or args.arch is None) else [args.arch]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]
    results = []
    failures = 0
    for a in archs:
        for s in shapes:
            try:
                results.append(run_one(a, s, multi_pod=args.multi_pod))
            except Exception as e:
                failures += 1
                traceback.print_exc()
                results.append({"arch": a, "shape": s, "status": "error",
                                "error": f"{type(e).__name__}: {e}"})
    if args.json:
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        with open(args.json, "w") as f:
            json.dump(results, f, indent=1)
        print(f"[dryrun] wrote {args.json}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
