import os
if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# HLO cost profiler: per-opcode / per-op breakdown of the roofline terms.
# This is the tool behind every EXPERIMENTS.md §Perf iteration — it answers
# "which op class owns the dominant term?" for a compiled (arch x shape).
#
#   PYTHONPATH=src python -m repro.launch.profile --arch qwen2-7b \
#       --shape train_4k --top 20
# (Module doc as comment: XLA_FLAGS must precede jax imports.)

import argparse
from collections import defaultdict

from repro.launch import roofline as rl


def profile_hlo(text: str):
    """-> (per-opcode byte totals, top single ops, collective breakdown)."""
    comps, entry = rl.parse_hlo(text)
    by_op: dict = defaultdict(float)
    tops: list = []
    colls: dict = defaultdict(float)

    def walk(name, mult, count_bytes=True):
        comp = comps.get(name)
        if comp is None:
            return
        for op in comp.ops:
            oc = op.opcode
            if oc == "while":
                trip = rl._trip_count(op)
                for b in rl._called(op, "body") + rl._called(op, "condition"):
                    walk(b, mult * trip, count_bytes)
            elif oc == "fusion":
                if count_bytes:
                    nb = mult * rl._fusion_bytes(op, comp, comps)
                    by_op["fusion"] += nb
                    tops.append((nb, "fusion", op.name, op.type_str[:60]))
                for c in rl._called(op, "calls"):
                    walk(c, mult, False)
            elif oc in ("call",):
                for c in rl._called(op, "to_apply") + rl._called(op, "calls"):
                    walk(c, mult, count_bytes)
            else:
                if any(oc.startswith(c) for c in rl.COLLECTIVES):
                    nb = sum(rl._type_bytes(comp.by_name[o].type_str)
                             for o in rl._operand_names(op)
                             if o in comp.by_name) or rl._type_bytes(op.type_str)
                    colls[oc] += mult * nb
                if count_bytes and oc not in (
                        "parameter", "constant", "get-tuple-element",
                        "tuple", "bitcast"):
                    nb = mult * rl._op_bytes(op, comp)
                    by_op[oc] += nb
                    tops.append((nb, oc, op.name, op.type_str[:60]))

    walk(entry, 1.0)
    tops.sort(reverse=True)
    return dict(by_op), tops, dict(colls)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--top", type=int, default=15)
    ap.add_argument("--hlo-out", default=None)
    args = ap.parse_args(argv)

    from repro.launch.dryrun import build_lowered
    lowered, meta = build_lowered(args.arch, args.shape,
                                  multi_pod=args.multi_pod)
    txt = lowered.compile().as_text()
    if args.hlo_out:
        open(args.hlo_out, "w").write(txt)

    by_op, tops, colls = profile_hlo(txt)
    total = sum(by_op.values())
    print(f"== {args.arch} x {args.shape} mesh={meta['mesh']} — "
          f"bytes/device {total:.3e} ==")
    print("\nper-opcode bytes:")
    for k, v in sorted(by_op.items(), key=lambda x: -x[1])[:12]:
        print(f"  {k:22s} {v:11.3e}  ({v/total:6.1%})")
    if colls:
        print("\ncollective bytes:")
        for k, v in sorted(colls.items(), key=lambda x: -x[1]):
            print(f"  {k:22s} {v:11.3e}")
    print(f"\ntop {args.top} single ops (x trip count):")
    for nb, oc, name, t in tops[:args.top]:
        print(f"  {nb:10.3e} {oc:14s} {name[:40]:40s} {t}")


if __name__ == "__main__":
    main()
