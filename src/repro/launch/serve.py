"""Serving launcher: SL-based task inference with batched requests.

Prefill + decode against a fine-tuned (adapter-loaded) model; the
parameter-efficient deployment path (§III-A.2): backbone weights are
initialized locally (presumed synchronized), only adapters come from a
checkpoint.

Decode-engine architecture (fast path first):

- ``--impl scan`` (default): :func:`repro.models.model.generate_scan` — the
  whole request (prefill + ``gen`` decode steps) is ONE jitted dispatch; the
  decode loop is a ``jax.lax.scan`` with the KV caches in the carry, and
  each step's cache attention runs through the flash-decode kernel dispatch
  (``kernels/ops.py::flash_decode``).
- ``--impl engine``: the batched serving layer
  (:mod:`repro.launch.engine`) — a continuous-batching-style request queue
  packed into fixed batch slots, used by ``core/integrated.py::produce``.
- ``--impl loop``: the legacy per-token Python loop (one host dispatch per
  token), kept as the benchmark baseline (benchmarks/decode_bench.py).
- ``--impl spec``: speculative serving — the engine drains with a tiny
  recurrent edge drafter (``core/spec_decode.py``): ``--draft-k`` proposed
  tokens per chunk, verified by ONE batched target pass, exact-match
  accepted with per-row rollback. Greedy output is token-for-token
  identical to ``--impl scan``; the printed acceptance rate is the
  measured draft quality (a fresh random drafter accepts near 0% — train
  or distill one for real speedups; benchmarks/spec_bench.py shows the
  acceptance=1.0 upper bound).

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch vit-edge --reduced \
      --batch 4 --prompt-len 16 --gen 8 [--adapters ckpt.npz] [--impl scan]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import io as ckpt_io
from repro.configs.base import get_config
from repro.core import telemetry
from repro.models import model as M


def generate_loop(params, cfg, prompts: jax.Array, *, gen: int,
                  extra_batch: dict | None = None, greedy: bool = True,
                  key=None):
    """LEGACY batched generation: per-token Python loop, one jitted dispatch
    per decode step. Superseded by :func:`repro.models.model.generate_scan`
    (token-for-token identical output); kept as the decode benchmark
    baseline. prompts: (B, S)."""
    B, S = prompts.shape
    n_vis = cfg.vlm.n_vis_tokens if cfg.family == "vlm" else 0
    batch = {"tokens": prompts, **(extra_batch or {})}
    prefill_j = jax.jit(lambda p, b: M.prefill(p, b, cfg, max_len=S + n_vis + gen))
    decode_j = jax.jit(lambda p, t, c, pos: M.decode_step(p, t, c, pos, cfg))

    logits, caches = prefill_j(params, batch)
    out = []
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    for i in range(gen):
        out.append(tok)
        pos = jnp.asarray(S + n_vis + i, jnp.int32)
        logits, caches = decode_j(params, tok, caches, pos)
        if greedy or key is None:
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        else:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits[:, -1])[:, None].astype(jnp.int32)
    return jnp.concatenate(out, axis=1)


def generate(params, cfg, prompts: jax.Array, *, gen: int,
             extra_batch: dict | None = None, greedy: bool = True,
             key=None):
    """Batched greedy/sampled generation (single-dispatch scan path)."""
    return M.generate_scan(params, cfg, prompts, gen=gen,
                           extra_batch=extra_batch, greedy=greedy, key=key)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="vit-edge")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--adapters", default=None)
    ap.add_argument("--requests", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--impl", choices=("scan", "loop", "engine", "spec"),
                    default="scan")
    ap.add_argument("--draft-k", type=int, default=4,
                    help="--impl spec: drafted tokens per verify chunk")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="enable telemetry and write a Chrome trace-event "
                         "JSON here (open in Perfetto / chrome://tracing)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="enable telemetry and write the counter/histogram "
                         "snapshot as JSON here")
    args = ap.parse_args(argv)

    traced = args.trace_out or args.metrics_out
    if traced:
        telemetry.enable()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    key = jax.random.PRNGKey(args.seed)
    params = M.init(cfg, key)
    if args.adapters:
        params = ckpt_io.load_adapters(args.adapters, params)
        print(f"[serve] loaded adapters from {args.adapters} "
              f"(parameter-efficient deployment)")

    extra = None
    if cfg.family == "vlm":
        extra = {"vision_embeds": jnp.zeros(
            (args.batch, cfg.vlm.n_vis_tokens, cfg.d_model),
            jnp.dtype(cfg.dtype))}
    if cfg.family == "audio":
        extra = {"frames": jnp.zeros(
            (args.batch, cfg.audio.n_audio_frames, cfg.d_model),
            jnp.dtype(cfg.dtype))}

    def export_telemetry():
        if not traced:
            return
        tel = telemetry.get()
        if args.trace_out:
            n = tel.export_trace(args.trace_out)
            print(f"[serve] wrote {n} trace events to {args.trace_out}")
        if args.metrics_out:
            tel.export_metrics(args.metrics_out)
            print(f"[serve] wrote metrics snapshot to {args.metrics_out}")
        print(tel.report())

    if args.impl in ("engine", "spec"):
        from repro.launch.engine import DecodeEngine
        spec = None
        if args.impl == "spec":
            from repro.core.spec_decode import SpecDecoder
            # fold, don't split: the prompt stream must stay identical to
            # --impl engine/scan at the same seed (greedy spec serving is
            # token-for-token the plain output, so rows must match too)
            spec = SpecDecoder.init(cfg, jax.random.fold_in(key, 1337),
                                    k=args.draft_k)
        engine = DecodeEngine(cfg, slots=args.batch, spec=spec)
        for r in range(args.requests):
            key, sub = jax.random.split(key)
            prompts = jax.random.randint(sub, (args.batch, args.prompt_len),
                                         0, cfg.vocab_size, dtype=jnp.int32)
            toks, stats = engine.serve(params, np.asarray(prompts),
                                       gen=args.gen, extra_batch=extra)
            acc = (f", acceptance {stats.acceptance_rate:.2f} "
                   f"({stats.accepted}/{stats.drafted})"
                   if spec is not None else "")
            print(f"[serve] round {r}: {stats.requests} requests, "
                  f"{stats.tokens} tokens in {stats.wall_s:.2f}s "
                  f"({stats.tok_per_s:.1f} tok/s, {stats.waves} waves{acc}); "
                  f"first row: {toks[0][:8]}")
            if stats.ttft_hist:
                h = stats.ttft_hist
                print(f"[serve]   ttft p50={h['p50']:.3f}s "
                      f"p95={h['p95']:.3f}s p99={h['p99']:.3f}s")
        export_telemetry()
        return

    gen_fn = generate if args.impl == "scan" else generate_loop
    for r in range(args.requests):
        key, sub = jax.random.split(key)
        prompts = jax.random.randint(sub, (args.batch, args.prompt_len), 0,
                                     cfg.vocab_size, dtype=jnp.int32)
        t0 = time.perf_counter()
        with telemetry.get().span("serve.request", impl=args.impl,
                                  batch=args.batch, gen=args.gen):
            toks = gen_fn(params, cfg, prompts, gen=args.gen,
                          extra_batch=extra)
            toks = np.asarray(toks)
        dt = time.perf_counter() - t0
        tps = args.batch * args.gen / dt
        print(f"[serve] request {r}: generated {toks.shape} in {dt:.2f}s "
              f"({tps:.1f} tok/s); first row: {toks[0][:8]}")
    export_telemetry()


if __name__ == "__main__":
    main()
