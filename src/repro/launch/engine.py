"""Batched decode-serving engine (continuous-batching-style, wave-scheduled).

The integrated runtime's "task inference" rounds (paper §IV) are throughput
bound: a round's profit is booked per served request, so requests must be
packed onto the accelerator, not dispatched one by one. This engine is the
serving layer between a request queue and the fused single-dispatch
generator (:func:`repro.models.model.generate_scan`):

- **Request queue**: ``submit()`` enqueues prompts with per-request
  ``max_new_tokens``; ``run()`` drains the queue.
- **Fixed batch slots**: requests are packed into a fixed number of slots
  (``slots``) so every wave reuses the same compiled generate computation.
  Partial waves are padded by replicating a live row; padded rows are
  dropped on output.
- **Per-slot position/length tracking**: each :class:`Slot` records the
  request id, prompt length, and token budget; a wave groups
  requests of equal prompt length (length-bucketed packing) so all slots in
  a wave share cache positions and the whole wave is ONE jitted call —
  prefill + scanned decode, flash-decode attention per step.
- **Slot recycling**: when a slot's request completes its token budget the
  slot is freed and refilled from the queue for the next wave.

Throughput (tok/s), wave count, and wall latency are returned as
:class:`EngineStats`; ``core/integrated.py::produce`` feeds them into the
``RoundCost`` ledger.

Modality-conditioned requests (vision/audio extras) carry their extras row
with the request (``submit(..., extras={...})``): waves stack the rows in
slot order, so each request stays bound to its own conditioning even when
length-bucketing reorders the queue. Every request in one drain must agree
on the extras keys (or carry none).

**Multi-tenant serving**: constructed with an
:class:`~repro.core.adapter_bank.AdapterBank`, requests gain a ``domain``
field (``submit(..., domain=...)``) and one wave freely mixes requests
from different domains — each row's slot id is resolved against the bank
and threaded to the batched multi-LoRA kernels as per-row ``adapter_ids``.
Length-bucketing no longer implies domain-bucketing, and the bank's
stacked adapters are re-read at every wave, so an
``AdapterBank.publish`` between waves is served by the very next wave
(no stale reads). Mixed-domain waves are token-for-token identical to
draining each domain alone with its merged params.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M


@dataclasses.dataclass
class Request:
    uid: int
    tokens: np.ndarray                 # (S,) int32 prompt
    max_new_tokens: int
    extras: Optional[dict] = None      # per-request modality rows (no batch dim)
    domain: Optional[str] = None       # multi-tenant: AdapterBank slot owner


@dataclasses.dataclass
class Slot:
    """One fixed batch slot; live fields track the resident request."""
    uid: int = -1
    prompt_len: int = 0
    target: int = 0                    # requested new tokens
    active: bool = False

    def assign(self, req: Request) -> None:
        self.uid, self.prompt_len = req.uid, len(req.tokens)
        self.target = req.max_new_tokens
        self.active = True

    def recycle(self) -> None:
        self.uid, self.prompt_len, self.target = -1, 0, 0
        self.active = False


@dataclasses.dataclass
class Completion:
    uid: int
    tokens: np.ndarray                 # (max_new_tokens,) generated tokens
    latency_s: float                   # wall time of the serving wave
    wave: int


@dataclasses.dataclass
class EngineStats:
    requests: int = 0
    waves: int = 0
    tokens: int = 0                    # served (non-padding) tokens
    wall_s: float = 0.0

    @property
    def tok_per_s(self) -> float:
        return self.tokens / self.wall_s if self.wall_s > 0 else 0.0


class DecodeEngine:
    """Packs queued requests into fixed slots and serves them in waves."""

    def __init__(self, cfg, *, slots: int = 8, greedy: bool = True,
                 seed: int = 0, bank=None):
        self.cfg = cfg
        self.slots = slots
        self.greedy = greedy
        self.bank = bank                   # Optional[AdapterBank]: multi-tenant
        self.slot_table = [Slot() for _ in range(slots)]
        self._queue: deque[Request] = deque()
        self._uid = 0
        self._key = jax.random.PRNGKey(seed)

    # -- queue --------------------------------------------------------------
    def submit(self, tokens, max_new_tokens: int = 8,
               extras: Optional[dict] = None,
               domain: Optional[str] = None) -> int:
        """Enqueue one request; returns its uid. ``extras`` is one modality
        row per key (e.g. ``{"vision_embeds": (n_vis, d)}`` — no batch dim);
        it stays bound to this request across wave packing. ``domain`` names
        this request's adapter slot in the engine's AdapterBank (multi-tenant
        serving); it too stays bound across packing."""
        if domain is not None:
            if self.bank is None:
                raise ValueError("submit(domain=...) requires an engine "
                                 "constructed with an AdapterBank")
            self.bank.slot(domain)             # fail fast on unknown domains
        # enforce the all-or-none tenancy invariant at the door (rejecting
        # the offending request, not poisoning the queue): length bucketing
        # could otherwise separate tenant-addressed and merged-param
        # requests into different waves, where the mix would surface as a
        # shape error deep inside the projection kernels (stacked adapter
        # leaves served without adapter_ids).
        if self._queue and (domain is None) != (self._queue[0].domain is None):
            raise ValueError("all requests in a drain must carry a domain "
                             "or none (mixing tenant-addressed and "
                             "merged-param requests is ambiguous)")
        uid = self._uid
        self._uid += 1
        self._queue.append(Request(uid, np.asarray(tokens, np.int32),
                                   int(max_new_tokens), extras, domain))
        return uid

    def pending(self) -> int:
        return len(self._queue)

    # -- serving ------------------------------------------------------------
    def _pack_wave(self) -> list[Request]:
        """Fill free slots with queued requests of one prompt-length bucket
        (equal length => shared cache positions => one fused dispatch)."""
        S = len(self._queue[0].tokens)
        wave: list[Request] = []
        deferred: deque[Request] = deque()
        free = [s for s in self.slot_table if not s.active]
        while self._queue and len(wave) < len(free):
            req = self._queue.popleft()
            if len(req.tokens) == S:
                wave.append(req)
                free[len(wave) - 1].assign(req)
            else:
                deferred.append(req)               # next bucket, keep order
        self._queue.extendleft(reversed(deferred))
        return wave

    def _wave_extras(self, wave: list[Request]) -> Optional[dict]:
        """Stack per-request extras rows in slot order (padding replicates
        the last live row, mirroring the prompt padding)."""
        if all(r.extras is None for r in wave):
            return None
        keys = {k for r in wave if r.extras for k in r.extras}
        if any(r.extras is None or set(r.extras) != keys for r in wave):
            raise ValueError("all requests in a drain must carry the same "
                             f"extras keys ({sorted(keys)}) or none")
        pad = self.slots - len(wave)
        return {k: jnp.asarray(np.stack([np.asarray(r.extras[k])
                                         for r in wave]
                                        + [np.asarray(wave[-1].extras[k])] * pad))
                for k in keys}

    def _wave_adapter_ids(self, wave: list[Request]):
        """Per-slot bank slot ids (padding replicates the last live row's
        id, mirroring the prompt padding). None for single-tenant waves."""
        if all(r.domain is None for r in wave):
            return None
        doms = [r.domain for r in wave]
        doms += [doms[-1]] * (self.slots - len(wave))
        return self.bank.adapter_ids(doms)

    def run(self, params) -> tuple[list[Completion], EngineStats]:
        """Drain the queue: pack -> one generate_scan dispatch per wave ->
        recycle completed slots. Returns (completions, stats).

        Multi-tenant drains (domain-carrying requests against a bank)
        re-read ``bank.stacked`` per wave, so a publish() between waves is
        served immediately."""
        stats = EngineStats()
        out: list[Completion] = []
        t_all = time.time()
        while self._queue:
            wave = self._pack_wave()
            gen = max(r.max_new_tokens for r in wave)
            prompts = np.stack([r.tokens for r in wave])
            if len(wave) < self.slots:             # pad: replicate a live row
                fill = np.repeat(prompts[-1:], self.slots - len(wave), axis=0)
                prompts = np.concatenate([prompts, fill], axis=0)
            key = None
            if not self.greedy:
                self._key, key = jax.random.split(self._key)
            ids = self._wave_adapter_ids(wave)
            wave_params = params if ids is None else \
                {**params, "adapters": self.bank.stacked}
            t0 = time.time()
            toks = M.generate_scan(wave_params, self.cfg,
                                   jnp.asarray(prompts), gen=gen,
                                   extra_batch=self._wave_extras(wave),
                                   greedy=self.greedy, key=key,
                                   adapter_ids=ids)
            toks = np.asarray(toks)                # device sync = wave done
            dt = time.time() - t0
            for i, req in enumerate(wave):
                slot = next(s for s in self.slot_table if s.uid == req.uid)
                out.append(Completion(req.uid, toks[i, :req.max_new_tokens],
                                      dt, stats.waves))
                stats.tokens += req.max_new_tokens
                slot.recycle()
            stats.waves += 1
            stats.requests += len(wave)
        stats.wall_s = time.time() - t_all
        return out, stats

    def serve(self, params, prompts, *, gen: int,
              extra_batch: Optional[dict] = None,
              domains: Optional[list] = None
              ) -> tuple[np.ndarray, EngineStats]:
        """Serve an (N, S) prompt batch in slot-sized waves.

        One engine call per round: submits every row (with its
        ``extra_batch`` row, leading dim N, if given, and its ``domains[i]``
        adapter slot for multi-tenant rounds), drains the queue, and
        returns ((N, gen) tokens in submission order, stats)."""
        prompts = np.asarray(prompts)
        if domains is not None and len(domains) != len(prompts):
            raise ValueError(f"domains ({len(domains)}) must name one "
                             f"adapter slot per prompt ({len(prompts)})")
        uids = [self.submit(p, gen,
                            extras=None if extra_batch is None else
                            {k: np.asarray(v[i]) for k, v in extra_batch.items()},
                            domain=None if domains is None else domains[i])
                for i, p in enumerate(prompts)]
        comps, stats = self.run(params)
        by_uid = {c.uid: c.tokens for c in comps}
        return np.stack([by_uid[u] for u in uids]), stats
