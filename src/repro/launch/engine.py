"""Ragged continuous-batching decode engine.

The integrated runtime's "task inference" rounds (paper §IV) are throughput
bound: a round's profit is booked per served request, so requests must keep
the accelerator full under realistic edge traffic — heterogeneous prompt
lengths and token budgets from many tenants — not just equal-shaped waves.
This engine is the serving layer between a request queue and the fused
ragged-wave primitives in :mod:`repro.models.model`.

**Ragged wave lifecycle** (one ``run()`` drain):

1. **Pack** — free slots are filled from the queue FIFO, with NO length
   bucketing: one wave freely mixes prompt lengths, token budgets, and
   (against an AdapterBank) tenant domains. Prompts are right-padded to
   the pack's max length (bucketed to the next power of two so the jit
   cache stays O(log max_len)).
2. **Prefill** — one jitted dispatch builds every packed row's decode
   state with per-row cache positions (``model._wave_prefill_fn``). The
   cache capacity is sized once per drain to the largest
   ``prompt + budget`` in the queue.
3. **Decode segments** — generation runs as a sequence of jitted
   ``lax.scan`` segments (``model._segment_fn``). Each segment's length is
   the power-of-two floor of the smallest remaining budget among live
   rows, so segments are never longer than the next retirement and the
   set of compiled segment shapes is {1, 2, 4, ...} — the jit cache stops
   growing no matter how budgets mix.
4. **Retire + refill IN-WAVE** — a row that exhausts its budget retires
   inside the scan (per-row active mask: cache writes dropped, position
   frozen). At the next segment boundary the freed slot is re-prefilled
   from the queue (``model._refill_fn`` merges fresh cache rows into the
   live wave state) — true continuous batching: the wave never drains to
   a boundary just to admit new work.
5. **Account** — ``EngineStats.tokens`` counts served (budget) tokens;
   ``EngineStats.padded_tokens`` counts wasted slot-steps (retired or
   empty slots riding along in a segment), so ``utilization`` is the real
   accelerator efficiency, not just the served-token rate.

Every drain is token-for-token identical to serving each request alone:
per-row cache positions + sentinel masking keep rows independent in
attention, and the recurrent families freeze padded state
identity-exactly (see ``stack_seq(lengths=...)``).

Modality-conditioned requests (vision/audio extras) carry their extras row
with the request (``submit(..., extras={...})``); refills rebuild the wave
extras so each slot stays bound to its own conditioning. Every request in
one drain must agree on the extras keys (or carry none).

**Multi-tenant serving**: constructed with an
:class:`~repro.core.adapter_bank.AdapterBank`, requests gain a ``domain``
field and one wave freely mixes domains — each row's bank slot id rides
the wave as per-row ``adapter_ids`` into the batched multi-LoRA kernels.
``bank.stacked`` is re-read at every prefill/refill/segment dispatch, so
an ``AdapterBank.publish`` between drains (or between segments) is served
by the very next dispatch.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import telemetry
from repro.core.paged import BlockAllocator, PagedSpec
from repro.core.telemetry import Histogram, Telemetry
from repro.models import model as M
from repro.models.transformer import groups_for, paged_subs
from repro.sharding.rules import init_from_spec


def _pow2ceil(n: int) -> int:
    return 1 << max(int(n) - 1, 0).bit_length()


def _pow2floor(n: int) -> int:
    return 1 << (int(n).bit_length() - 1)


@dataclasses.dataclass
class Request:
    uid: int
    tokens: np.ndarray                 # (S,) int32 prompt
    max_new_tokens: int
    extras: Optional[dict] = None      # per-request modality rows (no batch dim)
    domain: Optional[str] = None       # multi-tenant: AdapterBank slot owner
    deadline_s: Optional[float] = None  # monotonic budget from submit time
    # deadline / latency anchor: time.perf_counter() at submit. MONOTONIC
    # by contract — a wall-clock step (NTP slew, manual set) must never
    # spuriously retire a request as timed_out or corrupt its latency
    t_submit: float = 0.0
    speculative: bool = True           # opt this row out of spec drafting
                                       # (it then decodes plainly THROUGH
                                       # the verify pass — mixed waves)
    t_submit_wall: float = 0.0         # informational ONLY (never compared)
    sla: Optional[str] = None          # service class label: per-class
                                       # TTFT/queue histograms + deadline-
                                       # miss counters (EngineStats.sla_stats)


@dataclasses.dataclass
class Slot:
    """One fixed batch slot; live fields track the resident request."""
    uid: int = -1
    prompt_len: int = 0
    target: int = 0                    # requested new tokens
    active: bool = False

    def assign(self, req: Request) -> None:
        self.uid, self.prompt_len = req.uid, len(req.tokens)
        self.target = req.max_new_tokens
        self.active = True

    def recycle(self) -> None:
        self.uid, self.prompt_len, self.target = -1, 0, 0
        self.active = False


@dataclasses.dataclass
class Completion:
    uid: int
    tokens: np.ndarray                 # (max_new_tokens,) generated tokens
    latency_s: float                   # submit -> retirement (monotonic)
    wave: int                          # prefill wave that admitted the row
    timed_out: bool = False            # retired at its deadline (partial tokens)
    queue_s: float = 0.0               # submit -> wave admission (queue wait)
    ttft_s: Optional[float] = None     # submit -> first token host-visible
                                       # (None: retired before any token)
    tok_s: float = 0.0                 # tokens / (admission -> retirement)


@dataclasses.dataclass
class EngineStats:
    requests: int = 0
    waves: int = 0                     # prefill/refill dispatches
    segments: int = 0                  # jitted decode-scan dispatches
    tokens: int = 0                    # served (budgeted) tokens
    padded_tokens: int = 0             # wasted slot-steps (retired/empty rows)
    timed_out: int = 0                 # requests retired at their deadline
    wall_s: float = 0.0
    drafted: int = 0                   # drafter-proposed tokens (spec serving)
    accepted: int = 0                  # proposals the verify pass committed
    # per-request latency distributions, summarized from log-bucketed
    # histograms (core/telemetry.py::Histogram.summary: count/mean/p50/
    # p95/p99) — always recorded (a handful of perf_counter reads per
    # dispatch), independent of whether global telemetry is enabled
    ttft_hist: Optional[dict] = None       # time-to-first-token (s)
    queue_hist: Optional[dict] = None      # queue wait (s)
    tok_latency_hist: Optional[dict] = None  # per-token decode latency (s)
    # SLA classes (submit(sla=...)): per-class latency distributions +
    # deadline misses — {cls: {ttft_hist, queue_hist, deadline_miss,
    # requests}}. None when no request carried a class label.
    sla_stats: Optional[dict] = None
    # paged serving (DecodeEngine(paged=PagedSpec(...))):
    pool_block_size: int = 0           # tokens per pool block (0 = dense)
    pool_peak_blocks: int = 0          # max simultaneously-referenced blocks
    pool_blocks_alloc: int = 0         # private blocks allocated this drain
    cache_tokens: int = 0              # prompt+budget tokens placed in NEW
                                       # blocks (shared prefixes counted once)
    prefix_hits: int = 0               # admissions that matched a cached prefix
    prefix_hit_tokens: int = 0         # prompt tokens served from shared blocks

    @property
    def pool_occupancy(self) -> float:
        """Paged: useful tokens per allocated pool-block token. Blocks are
        sized per request (ceil over block_size), so this dominates the
        dense-slab utilization sum(len+gen)/(N*cap) — the slab pads every
        row to the drain-wide pow2 cap."""
        denom = self.pool_blocks_alloc * self.pool_block_size
        return self.cache_tokens / denom if denom else 0.0

    @property
    def tok_per_s(self) -> float:
        return self.tokens / self.wall_s if self.wall_s > 0 else 0.0

    @property
    def acceptance_rate(self) -> float:
        """Committed fraction of drafted tokens (speculative serving)."""
        return self.accepted / self.drafted if self.drafted else 0.0

    @property
    def utilization(self) -> float:
        """Served fraction of executed decode slot-steps (1.0 = no waste;
        same convention as RoundCost.utilization)."""
        total = self.tokens + self.padded_tokens
        return self.tokens / total if total else 1.0


class DecodeEngine:
    """Packs queued requests into fixed slots and serves them ragged."""

    def __init__(self, cfg, *, slots: int = 8, greedy: bool = True,
                 seed: int = 0, bank=None, mesh=None, spec=None,
                 tel: Optional[Telemetry] = None,
                 paged: Optional[PagedSpec] = None):
        self.cfg = cfg
        self.slots = slots
        self.greedy = greedy
        self.bank = bank                   # Optional[AdapterBank]: multi-tenant
        # telemetry: spans/counters go to `tel` if given, else to the
        # module singleton resolved at CALL time (so telemetry.enable()
        # after construction still instruments this engine). Per-request
        # latency histograms in EngineStats are recorded regardless.
        self.tel = tel
        # speculative serving: with a core.spec_decode.SpecDecoder, decode
        # segments run draft->verify chunks (k proposals + ONE batched
        # verify pass) instead of plain per-token scans. Greedy-only:
        # acceptance is exact-match against the target argmax, which is
        # what makes spec drains token-identical to plain ones. Rows
        # submitted with speculative=False decode plainly THROUGH the
        # verify pass (commit=1/chunk), so one wave freely mixes both.
        self.spec = spec
        if spec is not None:
            if not greedy:
                raise ValueError(
                    "speculative serving is greedy-only (sampled residual "
                    "acceptance is a recorded follow-up)")
            spec.validate_target(cfg)
            if mesh is not None:
                self.spec = spec.place(mesh)
        # mesh-native waves: every fused dispatch (wave prefill / in-wave
        # refill / decode segment) traces under rules.serving_rules(), so
        # the wave batch shards over `data` and head/FF dims over `model`.
        # Params must already live on the mesh (model.place_params /
        # AdapterBank(mesh=...)); drains stay token-identical to unsharded
        # serving (see tests/test_mesh_sharding.py).
        self.mesh = mesh
        # paged serving: the per-slot dense cache slab is replaced by a
        # device block pool + per-row block tables (models/attention.py)
        # and this HOST-side refcounted allocator (core/paged.py). The
        # pool and allocator persist ACROSS drains — freed blocks keep
        # their prefix hash on the LRU free list, so a later drain's
        # matching prompt revives them without re-prefilling.
        self.paged = paged
        self._pool: Optional[dict] = None
        self._alloc: Optional[BlockAllocator] = None
        self._psubs: list[tuple[str, str]] = []
        self._slot_blocks: list[Optional[dict]] = [None] * slots
        self._arrivals: deque = deque()    # serve_trace timed admissions
        self._trace_t0 = 0.0
        if paged is not None:
            if spec is not None:
                raise ValueError(
                    "paged serving composes with plain decode only "
                    "(speculative verify reads the dense slot layout; "
                    "paged verify is a recorded follow-up)")
            if cfg.family in ("audio", "vlm"):
                raise ValueError(
                    f"paged serving does not support the {cfg.family} "
                    "family (modality prefixes address the dense slab)")
            self._psubs = paged_subs(cfg)
            if paged.share_prefix:
                n_subs = sum(len(kinds) for _, kinds, _ in groups_for(cfg))
                if len(self._psubs) != n_subs or not self._psubs:
                    raise ValueError(
                        "share_prefix requires a fully paged stack (every "
                        "sub-layer full-window attention/moe): suffix-only "
                        "prefill has no partial-stack path")
            self._alloc = BlockAllocator(paged.n_blocks, paged.block_size)
        self.slot_table = [Slot() for _ in range(slots)]
        self._queue: deque[Request] = deque()
        self._uid = 0
        self._key = jax.random.PRNGKey(seed)

    # -- queue --------------------------------------------------------------
    def submit(self, tokens, max_new_tokens: int = 8,
               extras: Optional[dict] = None,
               domain: Optional[str] = None,
               deadline_s: Optional[float] = None,
               speculative: bool = True,
               sla: Optional[str] = None) -> int:
        """Enqueue one request; returns its uid. ``extras`` is one modality
        row per key (e.g. ``{"vision_embeds": (n_vis, d)}`` — no batch dim);
        it stays bound to this request across wave packing. ``domain`` names
        this request's adapter slot in the engine's AdapterBank (multi-tenant
        serving); it too stays bound across packing. ``deadline_s`` is a
        wall-clock budget from NOW: a row still live past it is retired
        mid-wave as a ``timed_out`` completion with its partial tokens.
        ``speculative=False`` opts this row out of drafting on a spec
        engine (it decodes plainly through the verify pass; ignored on
        plain engines). ``sla`` labels this request's service class:
        TTFT/queue-wait land in per-class histograms and a deadline
        retirement books a per-class miss (``EngineStats.sla_stats``,
        ``engine.deadline_miss.<cls>`` counters).

        Malformed requests fail HERE with ``ValueError`` — an empty or
        non-1-D prompt, a non-positive token budget, or an unknown domain
        would otherwise surface as a shape error (or a silent stall) deep
        inside a traced wave."""
        tokens = np.asarray(tokens, np.int32)
        if tokens.ndim != 1 or tokens.size == 0:
            raise ValueError(
                f"submit: prompt must be a non-empty 1-D token row, got "
                f"shape {tokens.shape}")
        if int(max_new_tokens) < 1:
            raise ValueError(
                f"submit: max_new_tokens must be >= 1, got {max_new_tokens}")
        if deadline_s is not None and deadline_s < 0:
            raise ValueError(
                f"submit: deadline_s must be >= 0, got {deadline_s}")
        if self.paged is not None:
            need = -(-(tokens.size + int(max_new_tokens))
                     // self.paged.block_size)
            if need > self.paged.n_blocks:
                raise ValueError(
                    f"submit: request needs {need} pool blocks but the "
                    f"pool only has {self.paged.n_blocks} — it could "
                    "never be admitted")
        if domain is not None:
            if self.bank is None:
                raise ValueError("submit(domain=...) requires an engine "
                                 "constructed with an AdapterBank")
            if domain not in self.bank.domains:  # fail fast on unknown domains
                raise ValueError(
                    f"domain {domain!r} has no adapter slot "
                    f"(known: {list(self.bank.domains)})")
        # enforce the all-or-none tenancy invariant at the door (rejecting
        # the offending request, not poisoning the queue): a mixed drain
        # would otherwise surface as a shape error deep inside the
        # projection kernels (stacked adapter leaves served without
        # adapter_ids).
        if self._queue and (domain is None) != (self._queue[0].domain is None):
            raise ValueError("all requests in a drain must carry a domain "
                             "or none (mixing tenant-addressed and "
                             "merged-param requests is ambiguous)")
        uid = self._uid
        self._uid += 1
        self._queue.append(Request(uid, tokens, int(max_new_tokens), extras,
                                   domain, deadline_s, time.perf_counter(),
                                   bool(speculative),
                                   time.time(), sla))    # tracelint: ignore[R3] t_submit_wall is informational
        self._telemetry().count("engine.submitted")
        return uid

    def _telemetry(self) -> Telemetry:
        return self.tel if self.tel is not None else telemetry.get()

    def pending(self) -> int:
        return len(self._queue)

    # -- packing ------------------------------------------------------------
    def _fill_slots(self) -> list[tuple[int, Request]]:
        """Assign queued requests to free slots FIFO (no length bucketing).
        Returns [(slot_index, request)] for the rows to (re-)prefill.

        Paged admission is block-gated: the head request's pool blocks
        (shared prefix refs + private blocks for prompt tail and budget)
        must all be reservable NOW, else packing stops head-of-line — a
        later retirement frees blocks and the next segment boundary
        retries. FIFO order is preserved either way."""
        packed: list[tuple[int, Request]] = []
        for i, slot in enumerate(self.slot_table):
            if slot.active or not self._queue:
                continue
            if self.paged is not None:
                plan = self._plan_blocks(self._queue[0])
                if plan is None:
                    break                     # pool full: wait for a retire
                self._slot_blocks[i] = plan
            req = self._queue.popleft()
            slot.assign(req)
            packed.append((i, req))
        return packed

    def _plan_blocks(self, req: Request) -> Optional[dict]:
        """Reserve one request's pool blocks, or None if they don't fit.

        ``shared`` are prefix-cache hits (acquired, never written:
        copy-on-write by construction); ``owned`` are freshly allocated
        private blocks covering the prompt tail + decode budget. The
        match is capped at (len-1)//bs blocks so every row keeps at
        least one private suffix token — the suffix pass needs a token
        to produce the row's first logits from."""
        ps, alloc = self.paged, self._alloc
        total = -(-(len(req.tokens) + req.max_new_tokens) // ps.block_size)
        shared: list[int] = []
        if ps.share_prefix:
            ids, _ = alloc.match_prefix(req.tokens)
            shared = ids[:min(len(ids), (len(req.tokens) - 1)
                              // ps.block_size)]
        need = total - len(shared)
        # reviving a dead (rc==0) shared block consumes a free-list slot
        # too, so feasibility is checked BEFORE touching refcounts
        revive = sum(1 for b in shared if alloc.refcount[b] == 0)
        if need + revive > alloc.free_blocks:
            return None
        for b in shared:
            alloc.acquire(b)
        owned = alloc.alloc(need) if need else []
        # publish full-prefill rows' prompt blocks AT PLAN TIME so a
        # same-wave sibling already matches them (its suffix dispatch
        # consumes the prefill's output pool — device data dependence
        # orders the commit before any shared read). HIT rows stay
        # private: their suffix K/V is chunk-pass math, not bitwise
        # dense-prefill state.
        if ps.share_prefix and not shared:
            alloc.register(req.tokens, owned)
        return {"owned": owned, "shared": shared}

    def _ensure_pool(self) -> None:
        """Materialize the persistent device block pool (zeros) lazily —
        one (L, n_blocks, bs, Hkv, D) k/v pair per eligible sub-layer,
        shared by every drain this engine ever runs."""
        if self._pool is not None:
            return
        ps = self.paged
        spec = M.cache_spec(self.cfg, 1, ps.block_size,
                            paged=(ps.n_blocks, ps.block_size))
        pool: dict = {}
        for g, s in self._psubs:
            sub = spec[g][s]
            pool.setdefault(g, {})[s] = init_from_spec(
                jax.random.PRNGKey(0), {"k": sub["k"], "v": sub["v"]})
        self._pool = pool

    def _admit_due(self) -> None:
        """serve_trace: submit every arrival whose timestamp has passed."""
        while self._arrivals and \
                time.perf_counter() - self._trace_t0 >= self._arrivals[0][0]:
            _, tokens, gen, kw = self._arrivals.popleft()
            self.submit(tokens, gen, **kw)

    def _check_extras(self) -> frozenset:
        """Validate the all-or-none extras-keys invariant across the drain."""
        keys = {k for r in self._queue if r.extras for k in r.extras}
        if keys and any(r.extras is None or set(r.extras) != keys
                        for r in self._queue):
            raise ValueError("all requests in a drain must carry the same "
                             f"extras keys ({sorted(keys)}) or none")
        return frozenset(keys)

    def _wave_params(self, params, tenant: bool):
        """Per-dispatch params: re-read the bank so publishes are fresh."""
        return params if not tenant else \
            {**params, "adapters": self.bank.stacked}

    # -- serving ------------------------------------------------------------
    # tracelint: hot
    def run(self, params) -> tuple[list[Completion], EngineStats]:
        """Drain the queue as ONE ragged continuous-batching wave.

        Returns (completions, stats). See the module docstring for the
        wave lifecycle; the drain is token-for-token identical to serving
        every request alone."""
        stats = EngineStats()
        out: list[Completion] = []
        if not self._queue and not self._arrivals:
            return out, stats
        tel = self._telemetry()
        # drain-local latency histograms: always on (a few clock reads per
        # DISPATCH, never per token), summarized into EngineStats at exit
        h_ttft, h_queue, h_tok = Histogram(), Histogram(), Histogram()
        # per-SLA-class distributions (submit(sla=...)): lazily created
        # {cls: {"ttft": Histogram, "queue": Histogram, "miss": n, "n": n}}
        sla_acc: dict[str, dict] = {}
        t_all = time.perf_counter()
        extras_keys = self._check_extras()
        tenant = bool(self._queue) and self._queue[0].domain is not None
        # cache capacity: one size per drain keeps every refill shape-stable
        # (timed arrivals not yet submitted count too — they join THIS drain)
        cap = _pow2ceil(max(
            [len(r.tokens) + r.max_new_tokens for r in self._queue]
            + [e[1].size + e[2] for e in self._arrivals]))
        bs_ = nb_ = maxb = 0
        if self.paged is not None:
            bs_, nb_ = self.paged.block_size, self.paged.n_blocks
            cap = max(cap, bs_)            # pow2 cap >= pow2 bs divides evenly
            maxb = cap // bs_
            self._ensure_pool()
            stats.pool_block_size = bs_
        B = self.slots
        slot_req: list[Optional[Request]] = [None] * B
        slot_wave = [0] * B
        bufs: list[list[np.ndarray]] = [[] for _ in range(B)]
        remaining = np.zeros(B, np.int64)
        tok = caches = pos = None
        dtok = dcaches = dpos = None       # drafter wave state (spec serving)
        spec_rows = np.ones(B, bool)       # per-slot speculative opt-in
        ids = None                         # device (B,) adapter slot ids
        cur_extras: list[Optional[dict]] = [None] * B
        cur_dom: list[Optional[str]] = [None] * B
        # per-slot request lifecycle anchors (all monotonic):
        # submit (on the Request) -> admit (wave packing) -> first token
        # host-visible (first segment sync serving the row) -> retire
        t_admit = [0.0] * B
        t_first: list[Optional[float]] = [None] * B

        def retire(i: int, now: float, *, timed_out: bool = False) -> None:
            """Complete slot i's request: latency fields + trace span."""
            req = slot_req[i]
            toks_i = (np.concatenate(bufs[i]) if bufs[i]
                      else np.zeros(0, np.int32))
            ttft = t_first[i] - req.t_submit if t_first[i] is not None \
                else None
            decode_dt = now - t_admit[i]
            out.append(Completion(
                req.uid, toks_i, now - req.t_submit, slot_wave[i],
                timed_out=timed_out, queue_s=t_admit[i] - req.t_submit,
                ttft_s=ttft,
                tok_s=len(toks_i) / decode_dt if decode_dt > 0 else 0.0))
            stats.requests += 1
            if timed_out:
                stats.timed_out += 1
                tel.count("engine.timed_out")
            if ttft is not None:
                h_ttft.record(ttft)
                tel.observe("engine.ttft_s", ttft)
            if req.sla is not None:
                acc = sla_acc.setdefault(
                    req.sla, {"ttft": Histogram(), "queue": Histogram(),
                              "miss": 0, "n": 0})
                acc["n"] += 1
                acc["queue"].record(t_admit[i] - req.t_submit)
                if ttft is not None:
                    acc["ttft"].record(ttft)
                    tel.observe(f"engine.ttft_s.{req.sla}", ttft)
                if timed_out:
                    acc["miss"] += 1
                    tel.count(f"engine.deadline_miss.{req.sla}")
            if self.paged is not None and self._slot_blocks[i] is not None:
                pb = self._slot_blocks[i]
                self._alloc.free(pb["owned"] + pb["shared"])
                self._slot_blocks[i] = None
                tel.gauge("engine.pool_blocks_used", self._alloc.used_blocks)
            tel.count("engine.retired")
            tel.record_span("engine.request", req.t_submit, now,
                            uid=req.uid, wave=slot_wave[i],
                            tokens=len(toks_i), domain=req.domain,
                            timed_out=timed_out)
            bufs[i] = []
            remaining[i] = 0
            slot_req[i] = None
            self.slot_table[i].recycle()

        drain = tel.span("engine.drain", slots=B, queued=len(self._queue))
        drain.__enter__()
        while self._queue or remaining.any() or self._arrivals:
            self._admit_due()
            if not self._queue and not remaining.any():
                # arrival-driven drain, nothing live yet: sleep toward the
                # next arrival instead of spinning (capped so a deadline
                # sweep never starves)
                dt = self._trace_t0 + self._arrivals[0][0] \
                    - time.perf_counter()
                if dt > 0:
                    time.sleep(min(dt, 0.025))
                continue
            packed = self._fill_slots()
            if packed:
                stats.waves += 1
                # a drain admitted entirely from a timed trace learns its
                # tenancy from the first packed wave (submit() enforces
                # the all-or-none invariant queue-wide)
                tenant = packed[0][1].domain is not None
                t_adm = time.perf_counter()    # queue wait ends at admission
                for i, req in packed:
                    slot_req[i], slot_wave[i] = req, stats.waves - 1
                    remaining[i] = req.max_new_tokens
                    cur_extras[i], cur_dom[i] = req.extras, req.domain
                    spec_rows[i] = req.speculative
                    t_admit[i], t_first[i] = t_adm, None
                    h_queue.record(t_adm - req.t_submit)
                    tel.observe("engine.queue_s", t_adm - req.t_submit)
                    if self.paged is not None:
                        pb = self._slot_blocks[i]
                        nshared = len(pb["shared"])
                        stats.pool_blocks_alloc += len(pb["owned"])
                        stats.cache_tokens += (len(req.tokens)
                                               + req.max_new_tokens
                                               - nshared * bs_)
                        if nshared:
                            stats.prefix_hits += 1
                            stats.prefix_hit_tokens += nshared * bs_
                            tel.count("engine.prefix_hits")
                if self.paged is not None:
                    stats.pool_peak_blocks = max(stats.pool_peak_blocks,
                                                 self._alloc.used_blocks)
                    tel.gauge("engine.pool_blocks_used",
                              self._alloc.used_blocks)
                    tel.gauge("engine.pool_blocks_shared",
                              sum(1 for rc in self._alloc.refcount
                                  if rc > 1))
                live = [i for i in range(B) if slot_req[i] is not None]
                if tenant:                     # full-wave ids for segments
                    doms = [cur_dom[i] if cur_dom[i] is not None
                            else cur_dom[live[0]] for i in range(B)]
                    ids = self.bank.adapter_ids(doms)
                wp = self._wave_params(params, tenant)
                # right-pad the PACKED prompts to a pow2 width (jit-shape
                # bucketing both dims keeps the compile cache O(log² cap))
                S_pad = _pow2ceil(max(len(req.tokens) for _, req in packed))
                if self.paged is not None:
                    # paged waves: dense-prefill the packed rows, then
                    # commit their K/V into the block pool through the
                    # host-built tables. Prefix-HIT rows skip the main
                    # prefill entirely (1-token dummies, all-sentinel
                    # tables) and are admitted by a suffix-only chunk
                    # dispatch right after — the shared blocks are never
                    # re-prefilled (and never re-written: copy-on-write).
                    full_p = [(i, r) for i, r in packed
                              if not self._slot_blocks[i]["shared"]]
                    hit_p = [(i, r) for i, r in packed
                             if self._slot_blocks[i]["shared"]]

                    def table_row(i: int) -> np.ndarray:
                        pb = self._slot_blocks[i]
                        row = np.full(maxb, nb_, np.int32)
                        ids_b = pb["shared"] + pb["owned"]
                        row[:len(ids_b)] = ids_b
                        return row

                    if caches is None:
                        prompts = np.zeros((B, S_pad), np.int32)
                        lens = np.ones(B, np.int32)
                        tables = np.full((B, maxb), nb_, np.int32)
                        for i, req in full_p:
                            prompts[i, :len(req.tokens)] = req.tokens
                            lens[i] = len(req.tokens)
                            tables[i] = table_row(i)
                        batch = {"tokens": jnp.asarray(prompts),
                                 **self._stack_extras(
                                     [cur_extras[i] for i in range(B)],
                                     extras_keys, live)}
                        with tel.span("engine.prefill",
                                      wave=stats.waves - 1,
                                      rows=len(full_p), seq=S_pad,
                                      paged=True):
                            tok, caches, pos = M._paged_prefill_fn(
                                self.cfg, cap, bs_, self.mesh)(
                                wp, batch, jnp.asarray(lens),
                                jnp.asarray(tables), self._pool, ids)
                    elif full_p:
                        Br = min(_pow2ceil(len(full_p)), _pow2ceil(B))
                        prompts = np.zeros((Br, S_pad), np.int32)
                        lens = np.ones(Br, np.int32)
                        row_idx = np.full(Br, B, np.int32)
                        tables_r = np.full((Br, maxb), nb_, np.int32)
                        for r, (i, req) in enumerate(full_p):
                            prompts[r, :len(req.tokens)] = req.tokens
                            lens[r] = len(req.tokens)
                            row_idx[r] = i
                            tables_r[r] = table_row(i)
                        rex = [cur_extras[i] for i, _ in full_p]
                        rex += [rex[0]] * (Br - len(full_p))
                        batch = {"tokens": jnp.asarray(prompts),
                                 **self._stack_extras(rex, extras_keys,
                                                      [0])}
                        ids_rows = None
                        if tenant:
                            rdom = [req.domain for _, req in full_p]
                            rdom += [rdom[0]] * (Br - len(full_p))
                            ids_rows = self.bank.adapter_ids(rdom)
                        with tel.span("engine.refill",
                                      wave=stats.waves - 1,
                                      rows=len(full_p), seq=S_pad,
                                      paged=True):
                            tok, caches, pos = M._paged_refill_fn(
                                self.cfg, cap, bs_, self.mesh)(
                                wp, batch, jnp.asarray(lens),
                                jnp.asarray(row_idx),
                                jnp.asarray(tables_r),
                                tok, caches, pos, ids_rows)
                    if hit_p:
                        Br = min(_pow2ceil(len(hit_p)), _pow2ceil(B))
                        W = _pow2ceil(max(
                            len(r.tokens)
                            - len(self._slot_blocks[i]["shared"]) * bs_
                            for i, r in hit_p))
                        suf = np.zeros((Br, W), np.int32)
                        slens = np.zeros(Br, np.int32)
                        starts = np.zeros(Br, np.int32)
                        row_idx = np.full(Br, B, np.int32)
                        tables_r = np.full((Br, maxb), nb_, np.int32)
                        for r, (i, req) in enumerate(hit_p):
                            st = len(self._slot_blocks[i]["shared"]) * bs_
                            tail = req.tokens[st:]
                            suf[r, :len(tail)] = tail
                            slens[r], starts[r] = len(tail), st
                            row_idx[r] = i
                            tables_r[r] = table_row(i)
                        ids_rows = None
                        if tenant:
                            rdom = [req.domain for _, req in hit_p]
                            rdom += [rdom[0]] * (Br - len(hit_p))
                            ids_rows = self.bank.adapter_ids(rdom)
                        with tel.span("engine.suffix",
                                      wave=stats.waves - 1,
                                      rows=len(hit_p), seq=W):
                            tok, caches, pos = M._paged_suffix_fn(
                                self.cfg, cap, bs_, self.mesh)(
                                wp, jnp.asarray(suf), jnp.asarray(slens),
                                jnp.asarray(starts), jnp.asarray(row_idx),
                                jnp.asarray(tables_r), tok, caches, pos,
                                ids_rows)
                elif caches is None:
                    # initial wave prefill: all B slots (empty slots carry
                    # 1-token dummies and retire immediately)
                    prompts = np.zeros((B, S_pad), np.int32)
                    lens = np.ones(B, np.int32)
                    for i, req in packed:
                        prompts[i, :len(req.tokens)] = req.tokens
                        lens[i] = len(req.tokens)
                    batch = {"tokens": jnp.asarray(prompts),
                             **self._stack_extras(
                                 [cur_extras[i] for i in range(B)],
                                 extras_keys, live)}
                    with tel.span("engine.prefill", wave=stats.waves - 1,
                                  rows=len(packed), seq=S_pad):
                        tok, caches, pos = M._wave_prefill_fn(
                            self.cfg, cap, self.mesh)(
                            wp, batch, jnp.asarray(lens), ids)
                        if self.spec is not None:
                            # drafter rides the same wave: its own prefill
                            # builds the recurrent draft state per row (its
                            # next-token guess is discarded — the chunk
                            # carry is always the target's committed token)
                            dtok, dcaches, dpos = M._wave_prefill_fn(
                                self.spec.cfg, cap, self.mesh)(
                                self.spec.params,
                                {"tokens": batch["tokens"]},
                                jnp.asarray(lens), None)
                else:
                    # in-wave refill: prefill ONLY the admitted rows
                    # (pow2-padded row count) and scatter them into the
                    # live wave state at their slot indices
                    Br = min(_pow2ceil(len(packed)), _pow2ceil(B))
                    prompts = np.zeros((Br, S_pad), np.int32)
                    lens = np.ones(Br, np.int32)
                    row_idx = np.full(Br, B, np.int32)   # pad rows: dropped
                    for r, (i, req) in enumerate(packed):
                        prompts[r, :len(req.tokens)] = req.tokens
                        lens[r] = len(req.tokens)
                        row_idx[r] = i
                    rex = [cur_extras[i] for i, _ in packed]
                    rex += [rex[0]] * (Br - len(packed))
                    batch = {"tokens": jnp.asarray(prompts),
                             **self._stack_extras(rex, extras_keys, [0])}
                    ids_rows = None
                    if tenant:
                        rdom = [req.domain for _, req in packed]
                        rdom += [rdom[0]] * (Br - len(packed))
                        ids_rows = self.bank.adapter_ids(rdom)
                    with tel.span("engine.refill", wave=stats.waves - 1,
                                  rows=len(packed), seq=S_pad):
                        tok, caches, pos = M._refill_fn(
                            self.cfg, cap, self.mesh)(
                            wp, batch, jnp.asarray(lens),
                            jnp.asarray(row_idx), tok, caches, pos, ids_rows)
                        if self.spec is not None:
                            dtok, dcaches, dpos = M._refill_fn(
                                self.spec.cfg, cap, self.mesh)(
                                self.spec.params, {"tokens": batch["tokens"]},
                                jnp.asarray(lens), jnp.asarray(row_idx),
                                dtok, dcaches, dpos, None)
            # deadline sweep: a live row past its monotonic budget is
            # retired HERE, mid-wave, as a timed-out completion with the
            # tokens it has so far — over-budget rows never stall the drain
            now = time.perf_counter()
            for i in range(B):
                req = slot_req[i]
                if req is None or req.deadline_s is None:
                    continue
                if now - req.t_submit >= req.deadline_s:
                    retire(i, now, timed_out=True)
            if not remaining.any():
                continue                       # re-pack freed slots (or exit)
            # segment length: with queued work, the pow2 floor of the
            # smallest live budget — never longer than the next retirement,
            # so refills happen in-wave. With an empty queue there is
            # nothing to admit at a retirement, so run the longest pow2
            # segment that cannot overshoot the wave (per-row retirement
            # inside the scan idles finished rows either way; fewer
            # dispatches, identical padded_tokens).
            live_rem = remaining[remaining > 0]
            live_n = int((remaining > 0).sum())
            t_seg0 = time.perf_counter()
            if self.spec is not None:
                # speculative segment: `chunks` draft->verify chunks, each
                # committing 1..k+1 tokens per row. The chunk count is the
                # pow2 floor of the budget in CHUNK units (worst case one
                # committed token per chunk keeps every chunk useful), so
                # the jit cache stays {1, 2, 4, ...} exactly like `seg`.
                Tc = self.spec.k + 1
                budget = int(live_rem.min() if self._queue
                             else live_rem.max())
                chunks = max(1, _pow2floor(max(1, budget // Tc)))
                with tel.span("engine.segment", chunks=chunks, k=self.spec.k,
                              live=live_n, speculative=True) as ssp:
                    (toks, counts, dr, ac, tok, caches, dcaches, pos,
                     _) = M._spec_segment_fn(
                        self.cfg, self.spec.cfg, chunks, self.spec.k,
                        self.mesh)(
                        self._wave_params(params, tenant), self.spec.params,
                        tok, caches, dcaches, pos,
                        jnp.asarray(remaining, jnp.int32),
                        jnp.asarray(spec_rows), ids)
                    toks = np.asarray(toks)      # tracelint: ignore[R2] the ONE deliberate sync: segment done
                    counts = np.asarray(counts)  # tracelint: ignore[R2] same fetch, already synced
                    ssp.set(drafted=int(dr), accepted=int(ac))
                stats.drafted += int(dr)
                stats.accepted += int(ac)
                executed = chunks * Tc * B     # verify slot-steps run
            else:
                seg = _pow2floor(int(live_rem.min() if self._queue
                                     else live_rem.max()))
                key = None
                if not self.greedy:
                    self._key, key = jax.random.split(self._key)
                with tel.span("engine.segment", seg=seg, live=live_n,
                              speculative=False):
                    toks, tok, caches, pos, _, key = M._segment_fn(
                        self.cfg, seg, self.greedy, self.mesh)(
                        self._wave_params(params, tenant), tok, caches, pos,
                        jnp.asarray(remaining, jnp.int32), key, ids)
                    toks = np.asarray(toks)    # tracelint: ignore[R2] the ONE deliberate sync: segment done
                if key is not None:
                    self._key = key            # carried per-step splits
                counts = np.minimum(seg, remaining)
                executed = seg * B
            t_seg1 = time.perf_counter()
            seg_wall = t_seg1 - t_seg0
            stats.segments += 1
            served_now = 0
            for i in range(B):
                if remaining[i] <= 0:
                    continue
                served = int(counts[i])
                bufs[i].append(toks[i, :served])
                remaining[i] -= served
                served_now += served
                if served > 0:
                    # per-token latency: this row's share of the segment
                    # wall, one observation per served token
                    h_tok.record(seg_wall / served, n=served)
                    tel.observe("engine.tok_latency_s", seg_wall / served,
                                n=served)
                    if t_first[i] is None:     # first token host-visible
                        t_first[i] = t_seg1
                if remaining[i] == 0:          # retire: complete + free slot
                    retire(i, t_seg1)
            stats.tokens += served_now
            stats.padded_tokens += executed - served_now
            tel.observe("engine.segment_s", seg_wall)
        if self.paged is not None and caches is not None:
            # persist the committed pool across drains: a freed block's
            # K/V stays addressable until its slot is actually reused,
            # which is what lets a later drain's matching prompt revive
            # it (LRU free list keeps the hash — core/paged.py)
            for g, s in self._psubs:
                c = caches[g][s]
                self._pool[g][s] = {"k": c["k"], "v": c["v"]}
        stats.wall_s = time.perf_counter() - t_all
        stats.ttft_hist = h_ttft.summary()
        stats.queue_hist = h_queue.summary()
        stats.tok_latency_hist = h_tok.summary()
        if sla_acc:
            stats.sla_stats = {
                cls: {"ttft_hist": a["ttft"].summary(),
                      "queue_hist": a["queue"].summary(),
                      "deadline_miss": a["miss"], "requests": a["n"]}
                for cls, a in sla_acc.items()}
        tel.count("engine.tokens", stats.tokens)
        tel.count("engine.padded_tokens", stats.padded_tokens)
        drain.set(requests=stats.requests, tokens=stats.tokens,
                  waves=stats.waves, segments=stats.segments)
        drain.__exit__(None, None, None)
        return out, stats

    def _stack_extras(self, cur_extras, keys: frozenset, live) -> dict:
        """Stack each slot's extras row (empty slots replicate a live row)."""
        if not keys:
            return {}
        fallback = cur_extras[live[0]]
        rows = [e if e is not None else fallback for e in cur_extras]
        return {k: jnp.asarray(np.stack([np.asarray(r[k]) for r in rows]))
                for k in keys}

    def serve(self, params, prompts, *, gen: int,
              extra_batch: Optional[dict] = None,
              domains: Optional[list] = None
              ) -> tuple[np.ndarray, EngineStats]:
        """Serve an (N, S) prompt batch in one continuous-batching drain.

        One engine call per round: submits every row (with its
        ``extra_batch`` row, leading dim N, if given, and its ``domains[i]``
        adapter slot for multi-tenant rounds), drains the queue, and
        returns ((N, gen) tokens in submission order, stats)."""
        prompts = np.asarray(prompts)
        if domains is not None and len(domains) != len(prompts):
            raise ValueError(f"domains ({len(domains)}) must name one "
                             f"adapter slot per prompt ({len(prompts)})")
        # mirror the domains check for extra_batch: a short leading dim
        # would otherwise fail deep inside per-row indexing (or, worse,
        # silently truncate a longer one) instead of at the API boundary
        for k, v in (extra_batch or {}).items():
            n = np.shape(v)[0] if np.ndim(v) else 0
            if n != len(prompts):
                raise ValueError(
                    f"extra_batch[{k!r}] leading dim ({n}) must carry one "
                    f"row per prompt ({len(prompts)})")
        uids = [self.submit(p, gen,
                            extras=None if extra_batch is None else
                            {k: np.asarray(v[i]) for k, v in extra_batch.items()},
                            domain=None if domains is None else domains[i])
                for i, p in enumerate(prompts)]
        comps, stats = self.run(params)
        by_uid = {c.uid: c.tokens for c in comps}
        return np.stack([by_uid[u] for u in uids]), stats

    def serve_trace(self, params, trace
                    ) -> tuple[list[Completion], EngineStats]:
        """Serve a TIMED arrival trace with arrival-driven admission.

        ``trace`` is an iterable of ``(t_s, tokens, gen)`` or
        ``(t_s, tokens, gen, submit_kwargs)`` arrivals; ``t_s`` is the
        arrival offset in seconds from the drain start. Unlike
        :meth:`serve` (which front-loads the whole queue), requests are
        ``submit``-ted only when their timestamp comes due inside the
        running drain — queue wait and TTFT measure the engine under
        the OFFERED load (Poisson in benchmarks/latency_bench.py), and
        on a paged engine admission is additionally block-gated, so a
        burst beyond pool capacity queues head-of-line until blocks
        free. Returns (completions, stats) like :meth:`run`."""
        ev = sorted(((float(e[0]), np.asarray(e[1], np.int32), int(e[2]),
                      dict(e[3]) if len(e) > 3 else {}) for e in trace),
                    key=lambda e: e[0])
        self._arrivals = deque(ev)
        self._trace_t0 = time.perf_counter()
        try:
            return self.run(params)
        finally:
            self._arrivals = deque()
