"""Ragged continuous-batching decode engine.

The integrated runtime's "task inference" rounds (paper §IV) are throughput
bound: a round's profit is booked per served request, so requests must keep
the accelerator full under realistic edge traffic — heterogeneous prompt
lengths and token budgets from many tenants — not just equal-shaped waves.
This engine is the serving layer between a request queue and the fused
ragged-wave primitives in :mod:`repro.models.model`.

**Ragged wave lifecycle** (one ``run()`` drain):

1. **Pack** — free slots are filled from the queue FIFO, with NO length
   bucketing: one wave freely mixes prompt lengths, token budgets, and
   (against an AdapterBank) tenant domains. Prompts are right-padded to
   the pack's max length (bucketed to the next power of two so the jit
   cache stays O(log max_len)).
2. **Prefill** — one jitted dispatch builds every packed row's decode
   state with per-row cache positions (``model._wave_prefill_fn``). The
   cache capacity is sized once per drain to the largest
   ``prompt + budget`` in the queue.
3. **Decode segments** — generation runs as a sequence of jitted
   ``lax.scan`` segments (``model._segment_fn``). Each segment's length is
   the power-of-two floor of the smallest remaining budget among live
   rows, so segments are never longer than the next retirement and the
   set of compiled segment shapes is {1, 2, 4, ...} — the jit cache stops
   growing no matter how budgets mix.
4. **Retire + refill IN-WAVE** — a row that exhausts its budget retires
   inside the scan (per-row active mask: cache writes dropped, position
   frozen). At the next segment boundary the freed slot is re-prefilled
   from the queue (``model._refill_fn`` merges fresh cache rows into the
   live wave state) — true continuous batching: the wave never drains to
   a boundary just to admit new work.
5. **Account** — ``EngineStats.tokens`` counts served (budget) tokens;
   ``EngineStats.padded_tokens`` counts wasted slot-steps (retired or
   empty slots riding along in a segment), so ``utilization`` is the real
   accelerator efficiency, not just the served-token rate.

Every drain is token-for-token identical to serving each request alone:
per-row cache positions + sentinel masking keep rows independent in
attention, and the recurrent families freeze padded state
identity-exactly (see ``stack_seq(lengths=...)``).

Modality-conditioned requests (vision/audio extras) carry their extras row
with the request (``submit(..., extras={...})``); refills rebuild the wave
extras so each slot stays bound to its own conditioning. Every request in
one drain must agree on the extras keys (or carry none).

**Multi-tenant serving**: constructed with an
:class:`~repro.core.adapter_bank.AdapterBank`, requests gain a ``domain``
field and one wave freely mixes domains — each row's bank slot id rides
the wave as per-row ``adapter_ids`` into the batched multi-LoRA kernels.
``bank.stacked`` is re-read at every prefill/refill/segment dispatch, so
an ``AdapterBank.publish`` between drains (or between segments) is served
by the very next dispatch.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import telemetry
from repro.core.telemetry import Histogram, Telemetry
from repro.models import model as M


def _pow2ceil(n: int) -> int:
    return 1 << max(int(n) - 1, 0).bit_length()


def _pow2floor(n: int) -> int:
    return 1 << (int(n).bit_length() - 1)


@dataclasses.dataclass
class Request:
    uid: int
    tokens: np.ndarray                 # (S,) int32 prompt
    max_new_tokens: int
    extras: Optional[dict] = None      # per-request modality rows (no batch dim)
    domain: Optional[str] = None       # multi-tenant: AdapterBank slot owner
    deadline_s: Optional[float] = None  # monotonic budget from submit time
    # deadline / latency anchor: time.perf_counter() at submit. MONOTONIC
    # by contract — a wall-clock step (NTP slew, manual set) must never
    # spuriously retire a request as timed_out or corrupt its latency
    t_submit: float = 0.0
    speculative: bool = True           # opt this row out of spec drafting
                                       # (it then decodes plainly THROUGH
                                       # the verify pass — mixed waves)
    t_submit_wall: float = 0.0         # informational ONLY (never compared)


@dataclasses.dataclass
class Slot:
    """One fixed batch slot; live fields track the resident request."""
    uid: int = -1
    prompt_len: int = 0
    target: int = 0                    # requested new tokens
    active: bool = False

    def assign(self, req: Request) -> None:
        self.uid, self.prompt_len = req.uid, len(req.tokens)
        self.target = req.max_new_tokens
        self.active = True

    def recycle(self) -> None:
        self.uid, self.prompt_len, self.target = -1, 0, 0
        self.active = False


@dataclasses.dataclass
class Completion:
    uid: int
    tokens: np.ndarray                 # (max_new_tokens,) generated tokens
    latency_s: float                   # submit -> retirement (monotonic)
    wave: int                          # prefill wave that admitted the row
    timed_out: bool = False            # retired at its deadline (partial tokens)
    queue_s: float = 0.0               # submit -> wave admission (queue wait)
    ttft_s: Optional[float] = None     # submit -> first token host-visible
                                       # (None: retired before any token)
    tok_s: float = 0.0                 # tokens / (admission -> retirement)


@dataclasses.dataclass
class EngineStats:
    requests: int = 0
    waves: int = 0                     # prefill/refill dispatches
    segments: int = 0                  # jitted decode-scan dispatches
    tokens: int = 0                    # served (budgeted) tokens
    padded_tokens: int = 0             # wasted slot-steps (retired/empty rows)
    timed_out: int = 0                 # requests retired at their deadline
    wall_s: float = 0.0
    drafted: int = 0                   # drafter-proposed tokens (spec serving)
    accepted: int = 0                  # proposals the verify pass committed
    # per-request latency distributions, summarized from log-bucketed
    # histograms (core/telemetry.py::Histogram.summary: count/mean/p50/
    # p95/p99) — always recorded (a handful of perf_counter reads per
    # dispatch), independent of whether global telemetry is enabled
    ttft_hist: Optional[dict] = None       # time-to-first-token (s)
    queue_hist: Optional[dict] = None      # queue wait (s)
    tok_latency_hist: Optional[dict] = None  # per-token decode latency (s)

    @property
    def tok_per_s(self) -> float:
        return self.tokens / self.wall_s if self.wall_s > 0 else 0.0

    @property
    def acceptance_rate(self) -> float:
        """Committed fraction of drafted tokens (speculative serving)."""
        return self.accepted / self.drafted if self.drafted else 0.0

    @property
    def utilization(self) -> float:
        """Served fraction of executed decode slot-steps (1.0 = no waste;
        same convention as RoundCost.utilization)."""
        total = self.tokens + self.padded_tokens
        return self.tokens / total if total else 1.0


class DecodeEngine:
    """Packs queued requests into fixed slots and serves them ragged."""

    def __init__(self, cfg, *, slots: int = 8, greedy: bool = True,
                 seed: int = 0, bank=None, mesh=None, spec=None,
                 tel: Optional[Telemetry] = None):
        self.cfg = cfg
        self.slots = slots
        self.greedy = greedy
        self.bank = bank                   # Optional[AdapterBank]: multi-tenant
        # telemetry: spans/counters go to `tel` if given, else to the
        # module singleton resolved at CALL time (so telemetry.enable()
        # after construction still instruments this engine). Per-request
        # latency histograms in EngineStats are recorded regardless.
        self.tel = tel
        # speculative serving: with a core.spec_decode.SpecDecoder, decode
        # segments run draft->verify chunks (k proposals + ONE batched
        # verify pass) instead of plain per-token scans. Greedy-only:
        # acceptance is exact-match against the target argmax, which is
        # what makes spec drains token-identical to plain ones. Rows
        # submitted with speculative=False decode plainly THROUGH the
        # verify pass (commit=1/chunk), so one wave freely mixes both.
        self.spec = spec
        if spec is not None:
            if not greedy:
                raise ValueError(
                    "speculative serving is greedy-only (sampled residual "
                    "acceptance is a recorded follow-up)")
            spec.validate_target(cfg)
            if mesh is not None:
                self.spec = spec.place(mesh)
        # mesh-native waves: every fused dispatch (wave prefill / in-wave
        # refill / decode segment) traces under rules.serving_rules(), so
        # the wave batch shards over `data` and head/FF dims over `model`.
        # Params must already live on the mesh (model.place_params /
        # AdapterBank(mesh=...)); drains stay token-identical to unsharded
        # serving (see tests/test_mesh_sharding.py).
        self.mesh = mesh
        self.slot_table = [Slot() for _ in range(slots)]
        self._queue: deque[Request] = deque()
        self._uid = 0
        self._key = jax.random.PRNGKey(seed)

    # -- queue --------------------------------------------------------------
    def submit(self, tokens, max_new_tokens: int = 8,
               extras: Optional[dict] = None,
               domain: Optional[str] = None,
               deadline_s: Optional[float] = None,
               speculative: bool = True) -> int:
        """Enqueue one request; returns its uid. ``extras`` is one modality
        row per key (e.g. ``{"vision_embeds": (n_vis, d)}`` — no batch dim);
        it stays bound to this request across wave packing. ``domain`` names
        this request's adapter slot in the engine's AdapterBank (multi-tenant
        serving); it too stays bound across packing. ``deadline_s`` is a
        wall-clock budget from NOW: a row still live past it is retired
        mid-wave as a ``timed_out`` completion with its partial tokens.
        ``speculative=False`` opts this row out of drafting on a spec
        engine (it decodes plainly through the verify pass; ignored on
        plain engines).

        Malformed requests fail HERE with ``ValueError`` — an empty or
        non-1-D prompt, a non-positive token budget, or an unknown domain
        would otherwise surface as a shape error (or a silent stall) deep
        inside a traced wave."""
        tokens = np.asarray(tokens, np.int32)
        if tokens.ndim != 1 or tokens.size == 0:
            raise ValueError(
                f"submit: prompt must be a non-empty 1-D token row, got "
                f"shape {tokens.shape}")
        if int(max_new_tokens) < 1:
            raise ValueError(
                f"submit: max_new_tokens must be >= 1, got {max_new_tokens}")
        if deadline_s is not None and deadline_s < 0:
            raise ValueError(
                f"submit: deadline_s must be >= 0, got {deadline_s}")
        if domain is not None:
            if self.bank is None:
                raise ValueError("submit(domain=...) requires an engine "
                                 "constructed with an AdapterBank")
            if domain not in self.bank.domains:  # fail fast on unknown domains
                raise ValueError(
                    f"domain {domain!r} has no adapter slot "
                    f"(known: {list(self.bank.domains)})")
        # enforce the all-or-none tenancy invariant at the door (rejecting
        # the offending request, not poisoning the queue): a mixed drain
        # would otherwise surface as a shape error deep inside the
        # projection kernels (stacked adapter leaves served without
        # adapter_ids).
        if self._queue and (domain is None) != (self._queue[0].domain is None):
            raise ValueError("all requests in a drain must carry a domain "
                             "or none (mixing tenant-addressed and "
                             "merged-param requests is ambiguous)")
        uid = self._uid
        self._uid += 1
        self._queue.append(Request(uid, tokens, int(max_new_tokens), extras,
                                   domain, deadline_s, time.perf_counter(),
                                   bool(speculative), time.time()))
        self._telemetry().count("engine.submitted")
        return uid

    def _telemetry(self) -> Telemetry:
        return self.tel if self.tel is not None else telemetry.get()

    def pending(self) -> int:
        return len(self._queue)

    # -- packing ------------------------------------------------------------
    def _fill_slots(self) -> list[tuple[int, Request]]:
        """Assign queued requests to free slots FIFO (no length bucketing).
        Returns [(slot_index, request)] for the rows to (re-)prefill."""
        packed: list[tuple[int, Request]] = []
        for i, slot in enumerate(self.slot_table):
            if slot.active or not self._queue:
                continue
            req = self._queue.popleft()
            slot.assign(req)
            packed.append((i, req))
        return packed

    def _check_extras(self) -> frozenset:
        """Validate the all-or-none extras-keys invariant across the drain."""
        keys = {k for r in self._queue if r.extras for k in r.extras}
        if keys and any(r.extras is None or set(r.extras) != keys
                        for r in self._queue):
            raise ValueError("all requests in a drain must carry the same "
                             f"extras keys ({sorted(keys)}) or none")
        return frozenset(keys)

    def _wave_params(self, params, tenant: bool):
        """Per-dispatch params: re-read the bank so publishes are fresh."""
        return params if not tenant else \
            {**params, "adapters": self.bank.stacked}

    # -- serving ------------------------------------------------------------
    def run(self, params) -> tuple[list[Completion], EngineStats]:
        """Drain the queue as ONE ragged continuous-batching wave.

        Returns (completions, stats). See the module docstring for the
        wave lifecycle; the drain is token-for-token identical to serving
        every request alone."""
        stats = EngineStats()
        out: list[Completion] = []
        if not self._queue:
            return out, stats
        tel = self._telemetry()
        # drain-local latency histograms: always on (a few clock reads per
        # DISPATCH, never per token), summarized into EngineStats at exit
        h_ttft, h_queue, h_tok = Histogram(), Histogram(), Histogram()
        t_all = time.perf_counter()
        extras_keys = self._check_extras()
        tenant = self._queue[0].domain is not None
        # cache capacity: one size per drain keeps every refill shape-stable
        cap = _pow2ceil(max(len(r.tokens) + r.max_new_tokens
                            for r in self._queue))
        B = self.slots
        slot_req: list[Optional[Request]] = [None] * B
        slot_wave = [0] * B
        bufs: list[list[np.ndarray]] = [[] for _ in range(B)]
        remaining = np.zeros(B, np.int64)
        tok = caches = pos = None
        dtok = dcaches = dpos = None       # drafter wave state (spec serving)
        spec_rows = np.ones(B, bool)       # per-slot speculative opt-in
        ids = None                         # device (B,) adapter slot ids
        cur_extras: list[Optional[dict]] = [None] * B
        cur_dom: list[Optional[str]] = [None] * B
        # per-slot request lifecycle anchors (all monotonic):
        # submit (on the Request) -> admit (wave packing) -> first token
        # host-visible (first segment sync serving the row) -> retire
        t_admit = [0.0] * B
        t_first: list[Optional[float]] = [None] * B

        def retire(i: int, now: float, *, timed_out: bool = False) -> None:
            """Complete slot i's request: latency fields + trace span."""
            req = slot_req[i]
            toks_i = (np.concatenate(bufs[i]) if bufs[i]
                      else np.zeros(0, np.int32))
            ttft = t_first[i] - req.t_submit if t_first[i] is not None \
                else None
            decode_dt = now - t_admit[i]
            out.append(Completion(
                req.uid, toks_i, now - req.t_submit, slot_wave[i],
                timed_out=timed_out, queue_s=t_admit[i] - req.t_submit,
                ttft_s=ttft,
                tok_s=len(toks_i) / decode_dt if decode_dt > 0 else 0.0))
            stats.requests += 1
            if timed_out:
                stats.timed_out += 1
                tel.count("engine.timed_out")
            if ttft is not None:
                h_ttft.record(ttft)
                tel.observe("engine.ttft_s", ttft)
            tel.count("engine.retired")
            tel.record_span("engine.request", req.t_submit, now,
                            uid=req.uid, wave=slot_wave[i],
                            tokens=len(toks_i), domain=req.domain,
                            timed_out=timed_out)
            bufs[i] = []
            remaining[i] = 0
            slot_req[i] = None
            self.slot_table[i].recycle()

        drain = tel.span("engine.drain", slots=B, queued=len(self._queue))
        drain.__enter__()
        while self._queue or remaining.any():
            packed = self._fill_slots()
            if packed:
                stats.waves += 1
                t_adm = time.perf_counter()    # queue wait ends at admission
                for i, req in packed:
                    slot_req[i], slot_wave[i] = req, stats.waves - 1
                    remaining[i] = req.max_new_tokens
                    cur_extras[i], cur_dom[i] = req.extras, req.domain
                    spec_rows[i] = req.speculative
                    t_admit[i], t_first[i] = t_adm, None
                    h_queue.record(t_adm - req.t_submit)
                    tel.observe("engine.queue_s", t_adm - req.t_submit)
                live = [i for i in range(B) if slot_req[i] is not None]
                if tenant:                     # full-wave ids for segments
                    doms = [cur_dom[i] if cur_dom[i] is not None
                            else cur_dom[live[0]] for i in range(B)]
                    ids = self.bank.adapter_ids(doms)
                wp = self._wave_params(params, tenant)
                # right-pad the PACKED prompts to a pow2 width (jit-shape
                # bucketing both dims keeps the compile cache O(log² cap))
                S_pad = _pow2ceil(max(len(req.tokens) for _, req in packed))
                if caches is None:
                    # initial wave prefill: all B slots (empty slots carry
                    # 1-token dummies and retire immediately)
                    prompts = np.zeros((B, S_pad), np.int32)
                    lens = np.ones(B, np.int32)
                    for i, req in packed:
                        prompts[i, :len(req.tokens)] = req.tokens
                        lens[i] = len(req.tokens)
                    batch = {"tokens": jnp.asarray(prompts),
                             **self._stack_extras(
                                 [cur_extras[i] for i in range(B)],
                                 extras_keys, live)}
                    with tel.span("engine.prefill", wave=stats.waves - 1,
                                  rows=len(packed), seq=S_pad):
                        tok, caches, pos = M._wave_prefill_fn(
                            self.cfg, cap, self.mesh)(
                            wp, batch, jnp.asarray(lens), ids)
                        if self.spec is not None:
                            # drafter rides the same wave: its own prefill
                            # builds the recurrent draft state per row (its
                            # next-token guess is discarded — the chunk
                            # carry is always the target's committed token)
                            dtok, dcaches, dpos = M._wave_prefill_fn(
                                self.spec.cfg, cap, self.mesh)(
                                self.spec.params,
                                {"tokens": batch["tokens"]},
                                jnp.asarray(lens), None)
                else:
                    # in-wave refill: prefill ONLY the admitted rows
                    # (pow2-padded row count) and scatter them into the
                    # live wave state at their slot indices
                    Br = min(_pow2ceil(len(packed)), _pow2ceil(B))
                    prompts = np.zeros((Br, S_pad), np.int32)
                    lens = np.ones(Br, np.int32)
                    row_idx = np.full(Br, B, np.int32)   # pad rows: dropped
                    for r, (i, req) in enumerate(packed):
                        prompts[r, :len(req.tokens)] = req.tokens
                        lens[r] = len(req.tokens)
                        row_idx[r] = i
                    rex = [cur_extras[i] for i, _ in packed]
                    rex += [rex[0]] * (Br - len(packed))
                    batch = {"tokens": jnp.asarray(prompts),
                             **self._stack_extras(rex, extras_keys, [0])}
                    ids_rows = None
                    if tenant:
                        rdom = [req.domain for _, req in packed]
                        rdom += [rdom[0]] * (Br - len(packed))
                        ids_rows = self.bank.adapter_ids(rdom)
                    with tel.span("engine.refill", wave=stats.waves - 1,
                                  rows=len(packed), seq=S_pad):
                        tok, caches, pos = M._refill_fn(
                            self.cfg, cap, self.mesh)(
                            wp, batch, jnp.asarray(lens),
                            jnp.asarray(row_idx), tok, caches, pos, ids_rows)
                        if self.spec is not None:
                            dtok, dcaches, dpos = M._refill_fn(
                                self.spec.cfg, cap, self.mesh)(
                                self.spec.params, {"tokens": batch["tokens"]},
                                jnp.asarray(lens), jnp.asarray(row_idx),
                                dtok, dcaches, dpos, None)
            # deadline sweep: a live row past its monotonic budget is
            # retired HERE, mid-wave, as a timed-out completion with the
            # tokens it has so far — over-budget rows never stall the drain
            now = time.perf_counter()
            for i in range(B):
                req = slot_req[i]
                if req is None or req.deadline_s is None:
                    continue
                if now - req.t_submit >= req.deadline_s:
                    retire(i, now, timed_out=True)
            if not remaining.any():
                continue                       # re-pack freed slots (or exit)
            # segment length: with queued work, the pow2 floor of the
            # smallest live budget — never longer than the next retirement,
            # so refills happen in-wave. With an empty queue there is
            # nothing to admit at a retirement, so run the longest pow2
            # segment that cannot overshoot the wave (per-row retirement
            # inside the scan idles finished rows either way; fewer
            # dispatches, identical padded_tokens).
            live_rem = remaining[remaining > 0]
            live_n = int((remaining > 0).sum())
            t_seg0 = time.perf_counter()
            if self.spec is not None:
                # speculative segment: `chunks` draft->verify chunks, each
                # committing 1..k+1 tokens per row. The chunk count is the
                # pow2 floor of the budget in CHUNK units (worst case one
                # committed token per chunk keeps every chunk useful), so
                # the jit cache stays {1, 2, 4, ...} exactly like `seg`.
                Tc = self.spec.k + 1
                budget = int(live_rem.min() if self._queue
                             else live_rem.max())
                chunks = max(1, _pow2floor(max(1, budget // Tc)))
                with tel.span("engine.segment", chunks=chunks, k=self.spec.k,
                              live=live_n, speculative=True) as ssp:
                    (toks, counts, dr, ac, tok, caches, dcaches, pos,
                     _) = M._spec_segment_fn(
                        self.cfg, self.spec.cfg, chunks, self.spec.k,
                        self.mesh)(
                        self._wave_params(params, tenant), self.spec.params,
                        tok, caches, dcaches, pos,
                        jnp.asarray(remaining, jnp.int32),
                        jnp.asarray(spec_rows), ids)
                    toks = np.asarray(toks)    # device sync = segment done
                    counts = np.asarray(counts)  # per-row committed tokens
                    ssp.set(drafted=int(dr), accepted=int(ac))
                stats.drafted += int(dr)
                stats.accepted += int(ac)
                executed = chunks * Tc * B     # verify slot-steps run
            else:
                seg = _pow2floor(int(live_rem.min() if self._queue
                                     else live_rem.max()))
                key = None
                if not self.greedy:
                    self._key, key = jax.random.split(self._key)
                with tel.span("engine.segment", seg=seg, live=live_n,
                              speculative=False):
                    toks, tok, caches, pos, _, key = M._segment_fn(
                        self.cfg, seg, self.greedy, self.mesh)(
                        self._wave_params(params, tenant), tok, caches, pos,
                        jnp.asarray(remaining, jnp.int32), key, ids)
                    toks = np.asarray(toks)    # device sync = segment done
                if key is not None:
                    self._key = key            # carried per-step splits
                counts = np.minimum(seg, remaining)
                executed = seg * B
            t_seg1 = time.perf_counter()
            seg_wall = t_seg1 - t_seg0
            stats.segments += 1
            served_now = 0
            for i in range(B):
                if remaining[i] <= 0:
                    continue
                served = int(counts[i])
                bufs[i].append(toks[i, :served])
                remaining[i] -= served
                served_now += served
                if served > 0:
                    # per-token latency: this row's share of the segment
                    # wall, one observation per served token
                    h_tok.record(seg_wall / served, n=served)
                    tel.observe("engine.tok_latency_s", seg_wall / served,
                                n=served)
                    if t_first[i] is None:     # first token host-visible
                        t_first[i] = t_seg1
                if remaining[i] == 0:          # retire: complete + free slot
                    retire(i, t_seg1)
            stats.tokens += served_now
            stats.padded_tokens += executed - served_now
            tel.observe("engine.segment_s", seg_wall)
        stats.wall_s = time.perf_counter() - t_all
        stats.ttft_hist = h_ttft.summary()
        stats.queue_hist = h_queue.summary()
        stats.tok_latency_hist = h_tok.summary()
        tel.count("engine.tokens", stats.tokens)
        tel.count("engine.padded_tokens", stats.padded_tokens)
        drain.set(requests=stats.requests, tokens=stats.tokens,
                  waves=stats.waves, segments=stats.segments)
        drain.__exit__(None, None, None)
        return out, stats

    def _stack_extras(self, cur_extras, keys: frozenset, live) -> dict:
        """Stack each slot's extras row (empty slots replicate a live row)."""
        if not keys:
            return {}
        fallback = cur_extras[live[0]]
        rows = [e if e is not None else fallback for e in cur_extras]
        return {k: jnp.asarray(np.stack([np.asarray(r[k]) for r in rows]))
                for k in keys}

    def serve(self, params, prompts, *, gen: int,
              extra_batch: Optional[dict] = None,
              domains: Optional[list] = None
              ) -> tuple[np.ndarray, EngineStats]:
        """Serve an (N, S) prompt batch in one continuous-batching drain.

        One engine call per round: submits every row (with its
        ``extra_batch`` row, leading dim N, if given, and its ``domains[i]``
        adapter slot for multi-tenant rounds), drains the queue, and
        returns ((N, gen) tokens in submission order, stats)."""
        prompts = np.asarray(prompts)
        if domains is not None and len(domains) != len(prompts):
            raise ValueError(f"domains ({len(domains)}) must name one "
                             f"adapter slot per prompt ({len(prompts)})")
        # mirror the domains check for extra_batch: a short leading dim
        # would otherwise fail deep inside per-row indexing (or, worse,
        # silently truncate a longer one) instead of at the API boundary
        for k, v in (extra_batch or {}).items():
            n = np.shape(v)[0] if np.ndim(v) else 0
            if n != len(prompts):
                raise ValueError(
                    f"extra_batch[{k!r}] leading dim ({n}) must carry one "
                    f"row per prompt ({len(prompts)})")
        uids = [self.submit(p, gen,
                            extras=None if extra_batch is None else
                            {k: np.asarray(v[i]) for k, v in extra_batch.items()},
                            domain=None if domains is None else domains[i])
                for i, p in enumerate(prompts)]
        comps, stats = self.run(params)
        by_uid = {c.uid: c.tokens for c in comps}
        return np.stack([by_uid[u] for u in uids]), stats
