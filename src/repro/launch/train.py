"""Training launcher: HFSL fine-tuning (or plain PEFT/full FT) end-to-end.

Runs on whatever devices exist — a 1-device CPU box trains reduced configs
(examples use this), a real pod trains full configs with the same code path.

``--impl scan`` (default) runs the fused round engine: ``--log-every`` HFSL
steps per jitted ``lax.scan`` dispatch over a device-resident batch bank
(hfsl.make_hfsl_round); ``--impl loop`` keeps the legacy one-dispatch-per-
step path (benchmarks/finetune_bench.py measures the gap).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch vit-edge --reduced \
      --task classify --clusters 4 --steps 200 --sync-every 4 --impl scan
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import io as ckpt
from repro.configs.base import get_config
from repro.core import hfsl, telemetry
from repro.core.peft import trainable_fraction, tree_bytes
from repro.data.noniid import partition_by_classes
from repro.data.pipeline import BatchBank, cluster_batches
from repro.data.synthetic import ClassificationTask, LMStream
from repro.models import model as M
from repro.optim.optimizers import adamw
from repro.optim.schedules import warmup_cosine


def build_cfg(args):
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.task == "classify" and not cfg.peft.head_dim_out:
        cfg = cfg.with_(peft=dataclasses.replace(cfg.peft,
                                                 head_dim_out=args.classes))
    return cfg


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="vit-edge")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--task", choices=("lm", "classify"), default="classify")
    ap.add_argument("--clusters", type=int, default=4)
    ap.add_argument("--classes", type=int, default=5)
    ap.add_argument("--classes-per-client", type=int, default=5)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--sync-every", type=int, default=4)
    ap.add_argument("--impl", choices=("scan", "loop"), default="scan",
                    help="scan: fused round engine (one dispatch per "
                         "--log-every steps); loop: legacy per-step dispatch")
    ap.add_argument("--microbatches", type=int, default=1,
                    help="gradient-accumulation splits per cluster batch "
                         "(scan impl)")
    ap.add_argument("--remat", action="store_true",
                    help="checkpoint the per-layer forward (lm task, scan "
                         "impl): long-sequence activation memory relief")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="enable telemetry and write a Chrome trace-event "
                         "JSON here (open in Perfetto / chrome://tracing)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="enable telemetry and write the counter/histogram "
                         "snapshot as JSON here")
    args = ap.parse_args(argv)

    if args.trace_out or args.metrics_out:
        telemetry.enable()

    cfg = build_cfg(args)
    key = jax.random.PRNGKey(args.seed)
    opt = adamw(warmup_cosine(args.lr, args.steps // 10 + 1, args.steps))

    state = hfsl.init_hfsl_state(key, cfg, args.clusters, opt, M.init)
    print(f"[train] {cfg.name}: trainable fraction "
          f"{trainable_fraction(hfsl.consensus_params(state)):.4%}, "
          f"adapter bytes/cluster "
          f"{tree_bytes(jax.tree.map(lambda x: x[0], state['adapters_c']))}")

    if args.task == "classify":
        task = ClassificationTask(args.classes, cfg.vocab_size, args.seq,
                                  seed=args.seed)
        data = task.dataset(200 * args.clusters, seed=args.seed)
        parts = partition_by_classes(data["label"], args.clusters,
                                     args.classes_per_client, seed=args.seed)
        it = cluster_batches(data, parts, args.batch, seed=args.seed)
        loss_fn = M.classify_loss
    else:
        streams = [LMStream(cfg.vocab_size, args.batch, args.seq,
                            seed=args.seed + i) for i in range(args.clusters)]
        its = [iter(s) for s in streams]

        def it_gen():
            while True:
                bs = [next(i) for i in its]
                yield {k: jnp.stack([b[k] for b in bs]) for k in bs[0]}
        it = it_gen()
        loss_fn = M.lm_loss                  # accepts remat= for the scan impl

    t0 = time.perf_counter()
    if args.impl == "scan":
        remat = True if (args.remat and args.task == "lm") else None
        # pack the run's whole batch stream (same iterator + seed as the
        # loop impl, so the two impls are step-for-step identical); very
        # long runs recycle the first 512 rows modulo-epoch
        bank = BatchBank.from_iterator(it, min(args.steps, 512))
        rounds: dict[int, object] = {}      # one compiled round per chunk len
        done = 0
        while done < args.steps:
            chunk = min(args.log_every, args.steps - done)
            if chunk not in rounds:
                rounds[chunk] = hfsl.make_hfsl_round(
                    cfg, opt, loss_fn, steps=chunk,
                    sync_every=args.sync_every,
                    microbatches=args.microbatches, remat=remat)
            # the span covers dispatch + the metric host-read (the float()
            # below syncs), so its duration is the blocked round time — the
            # nested hfsl.round_dispatch span is the host-dispatch share
            with telemetry.get().span("train.round", steps=chunk,
                                      done=done) as rsp:
                state, metrics = rounds[chunk](state, bank.arrays,
                                               bank.advance(chunk))
                done += chunk
                m = {k: float(v[-1]) for k, v in metrics.items()
                     if jnp.ndim(v) == 1}
                rsp.set(**m)
            print(f"[train] step {done:5d} {m} "
                  f"({(time.perf_counter()-t0)/done:.2f}s/step)")
    else:
        step_fn = jax.jit(hfsl.make_hfsl_step(cfg, opt, loss_fn,
                                              sync_every=args.sync_every))
        for i in range(args.steps):
            state, metrics = step_fn(state, next(it))
            if (i + 1) % args.log_every == 0 or i == 0:
                m = {k: float(v) for k, v in metrics.items()
                     if jnp.ndim(v) == 0}
                print(f"[train] step {i+1:5d} {m} "
                      f"({(time.perf_counter()-t0)/(i+1):.2f}s/step)")
    print(f"[train] done in {time.perf_counter()-t0:.1f}s; "
          f"fedavg bytes/sync: {hfsl.sync_bytes(state['adapters_c'])}")

    if args.trace_out or args.metrics_out:
        tel = telemetry.get()
        if args.trace_out:
            n = tel.export_trace(args.trace_out)
            print(f"[train] wrote {n} trace events to {args.trace_out}")
        if args.metrics_out:
            tel.export_metrics(args.metrics_out)
            print(f"[train] wrote metrics snapshot to {args.metrics_out}")
        print(tel.report())

    if args.ckpt:
        params = hfsl.consensus_params(state)
        nb = ckpt.save_adapters(args.ckpt, params)
        print(f"[train] adapter-only checkpoint: {nb} bytes -> {args.ckpt}")
    return state


if __name__ == "__main__":
    main()
