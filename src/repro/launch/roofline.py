"""Roofline-term extraction from compiled HLO (DESIGN.md §7).

``compiled.cost_analysis()`` counts a ``while`` body exactly once, so with
scanned layer stacks it under-reports by ~n_layers (verified in-container).
This module re-derives the three roofline terms by walking the *text* HLO:

- ops inside ``while`` bodies are multiplied by the loop's
  ``backend_config.known_trip_count`` (nesting-aware);
- FLOPs come from ``dot``/``convolution`` ops (2 x out_elems x contraction);
- HBM bytes are counted at fusion boundaries (operands + results), modelling
  fused intermediates as register/VMEM-resident;
- collective bytes sum operand sizes of all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute.

All values are per-device (the SPMD module is per-partition), so terms
divide by a single chip's peak numbers.
"""
from __future__ import annotations

import dataclasses
import json
import re
from collections import defaultdict
from typing import Optional

# v5e hardware constants (from the task spec)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # B/s per chip
ICI_BW = 50e9                # B/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?[^=]*?\)?)\s+([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^)]*\))?.*\{\s*$")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _type_bytes(t: str) -> int:
    """Bytes of an HLO type string (handles tuples)."""
    total = 0
    for m in _SHAPE_RE.finditer(t):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _type_elems(t: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(t):
        if m.group(1) not in _DTYPE_BYTES:
            continue
        n = 1
        for d in m.group(2).split(","):
            if d:
                n *= int(d)
        total += n
    return total


@dataclasses.dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    rest: str                # everything after the '(' of the operand list


@dataclasses.dataclass
class Computation:
    name: str
    ops: list[Op]
    by_name: dict[str, Op]


def parse_hlo(text: str) -> tuple[dict[str, Computation], Optional[str]]:
    comps: dict[str, Computation] = {}
    entry = None
    cur: Optional[Computation] = None
    # tuple types embed /*index=N*/ comments whose '=' breaks the op regex
    text = re.sub(r"/\*.*?\*/", "", text)
    for line in text.splitlines():
        if cur is None:
            m = _COMP_RE.match(line)
            if m and "=" not in line.split("{")[0]:
                cur = Computation(m.group(1), [], {})
                if line.startswith("ENTRY"):
                    entry = m.group(1)
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if m:
            op = Op(m.group(1), m.group(2).strip(), m.group(3), m.group(4))
            cur.ops.append(op)
            cur.by_name[op.name] = op
    return comps, entry


def _called(op: Op, attr: str) -> list[str]:
    m = re.search(attr + r"=\{?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)\}?", op.rest)
    if not m:
        return []
    return [x.strip().lstrip("%") for x in m.group(1).split(",")]


def _trip_count(op: Op) -> int:
    m = re.search(r'known_trip_count[\\"]*:?\s*[{\\"]*n[\\"]*:+[\\"]*(\d+)',
                  op.rest)
    return int(m.group(1)) if m else 1


def _operand_names(op: Op) -> list[str]:
    # operand list terminates at the first ')' at depth 0
    depth, out, cur = 0, [], []
    for ch in op.rest:
        if ch == "(":
            depth += 1
        elif ch == ")":
            if depth == 0:
                break
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    out.append("".join(cur))
    names = []
    for o in out:
        o = o.strip()
        if o.startswith("%"):
            names.append(o[1:].split(" ")[0].split(")")[0])
        else:
            m = re.match(r"[a-z0-9]+\[[\d,]*\][^%]*%([\w.\-]+)", o)
            if m:
                names.append(m.group(1))
    return names


def _dot_flops(op: Op, comp: Computation) -> float:
    out_elems = _type_elems(op.type_str)
    operands = _operand_names(op)
    contr = 1
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
    if m and operands:
        lhs = comp.by_name.get(operands[0])
        lhs_t = lhs.type_str if lhs else ""
        sm = _SHAPE_RE.search(lhs_t)
        if sm:
            dims = [int(d) for d in sm.group(2).split(",") if d]
            for c in m.group(1).split(","):
                if c and int(c) < len(dims):
                    contr *= dims[int(c)]
    return 2.0 * out_elems * contr


def _conv_flops(op: Op, comp: Computation) -> float:
    # flops = 2 * out_elems * (kernel spatial x in_features)
    out_elems = _type_elems(op.type_str)
    operands = _operand_names(op)
    if len(operands) < 2:
        return 0.0
    rhs = comp.by_name.get(operands[1])
    if rhs is None:
        return 0.0
    sm = _SHAPE_RE.search(rhs.type_str)
    if not sm:
        return 0.0
    dims = [int(d) for d in sm.group(2).split(",") if d]
    out_feat = max(dims) if dims else 1      # conservative: exclude one dim
    k = 1
    for d in dims:
        k *= d
    return 2.0 * out_elems * (k / max(out_feat, 1))


@dataclasses.dataclass
class HloCosts:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: float = 0.0
    collectives: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    op_flops: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))

    def asdict(self) -> dict:
        return {"flops": self.flops, "bytes_accessed": self.bytes_accessed,
                "collective_bytes": self.collective_bytes,
                "collectives": dict(self.collectives),
                "op_flops": dict(self.op_flops)}


def walk(comps: dict[str, Computation], name: str, mult: float,
         acc: HloCosts, count_bytes: bool = True,
         _seen_fusion: bool = False) -> None:
    comp = comps.get(name)
    if comp is None:
        return
    for op in comp.ops:
        oc = op.opcode
        if oc == "while":
            trip = _trip_count(op)
            for body in _called(op, "body") + _called(op, "condition"):
                walk(comps, body, mult * trip, acc, count_bytes)
        elif oc == "fusion":
            if count_bytes:
                acc.bytes_accessed += mult * _fusion_bytes(op, comp, comps)
            for c in _called(op, "calls"):
                walk(comps, c, mult, acc, count_bytes=False)
        elif oc in ("call", "async-start", "custom-call"):
            for c in _called(op, "calls") + _called(op, "to_apply"):
                walk(comps, c, mult, acc, count_bytes)
        elif oc == "conditional":
            for c in (_called(op, "true_computation")
                      + _called(op, "false_computation")
                      + _called(op, "branch_computations")):
                walk(comps, c, mult, acc, count_bytes)
        elif oc == "dot":
            f = _dot_flops(op, comp) * mult
            acc.flops += f
            acc.op_flops["dot"] += f
            if count_bytes:
                acc.bytes_accessed += mult * _op_bytes(op, comp)
        elif oc == "convolution":
            f = _conv_flops(op, comp) * mult
            acc.flops += f
            acc.op_flops["convolution"] += f
            if count_bytes:
                acc.bytes_accessed += mult * _op_bytes(op, comp)
        elif any(oc.startswith(c) for c in COLLECTIVES):
            nb = sum(_type_bytes(comp.by_name[o].type_str)
                     for o in _operand_names(op) if o in comp.by_name)
            if nb == 0:                     # fall back to result size
                nb = _type_bytes(op.type_str)
            acc.collective_bytes += mult * nb
            acc.collectives[oc] += mult * nb
            if count_bytes:
                acc.bytes_accessed += mult * _op_bytes(op, comp)
        else:
            if count_bytes and oc not in (
                    "parameter", "constant", "get-tuple-element", "tuple",
                    "bitcast"):
                acc.bytes_accessed += mult * _op_bytes(op, comp)


def _fusion_bytes(op: Op, comp: Computation, comps: dict) -> float:
    """Fusion boundary traffic. In-place update fusions (root is a
    dynamic-update-slice) touch only the update slice, not the aliased
    buffer — critical for KV-cache writes inside scans."""
    for cname in _called(op, "calls"):
        inner = comps.get(cname)
        if inner is None or not inner.ops:
            continue
        dus = [o for o in inner.ops if o.opcode == "dynamic-update-slice"]
        if dus:
            total = 0.0
            for d in dus:
                ops_ = _operand_names(d)
                upd = inner.by_name.get(ops_[1]) if len(ops_) > 1 else None
                total += 2.0 * _type_bytes(
                    (upd or d).type_str if upd else d.type_str)
            return total
    nb = _type_bytes(op.type_str)
    for on in _operand_names(op):
        src = comp.by_name.get(on)
        if src is not None:
            nb += _type_bytes(src.type_str)
    return nb


def _op_bytes(op: Op, comp: Computation) -> float:
    """HBM traffic model per op.

    Slicing ops touch only the slice (XLA updates in place); everything else
    reads its operands and writes its result. This matters enormously for
    decode: a dynamic-update-slice of one token into a 32k-slot KV cache
    costs ~one token, not the cache."""
    oc = op.opcode
    if oc == "dynamic-update-slice":
        ops_ = _operand_names(op)
        upd = comp.by_name.get(ops_[1]) if len(ops_) > 1 else None
        return 2.0 * _type_bytes(upd.type_str if upd else op.type_str)
    if oc in ("dynamic-slice", "slice", "gather", "copy", "broadcast",
              "iota", "reshape", "transpose", "concatenate", "pad"):
        return 2.0 * _type_bytes(op.type_str)
    nb = _type_bytes(op.type_str)
    for on in _operand_names(op):
        src = comp.by_name.get(on)
        if src is not None:
            nb += _type_bytes(src.type_str)
    return nb


def analyze_hlo_text(text: str) -> HloCosts:
    comps, entry = parse_hlo(text)
    acc = HloCosts()
    if entry:
        walk(comps, entry, 1.0, acc)
    return acc


# ---------------------------------------------------------------------------
# Roofline report
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    useful_ratio: float
    bottleneck: str
    collectives: dict
    memory_analysis: Optional[dict] = None

    @staticmethod
    def from_costs(costs: HloCosts, *, arch: str, shape: str, mesh: str,
                   chips: int, model_flops: float,
                   memory_analysis: Optional[dict] = None) -> "Roofline":
        ct = costs.flops / PEAK_FLOPS
        mt = costs.bytes_accessed / HBM_BW
        lt = costs.collective_bytes / ICI_BW
        terms = {"compute": ct, "memory": mt, "collective": lt}
        useful = model_flops / max(costs.flops * chips, 1.0)
        return Roofline(
            arch=arch, shape=shape, mesh=mesh, chips=chips,
            flops_per_device=costs.flops,
            bytes_per_device=costs.bytes_accessed,
            collective_bytes_per_device=costs.collective_bytes,
            compute_s=ct, memory_s=mt, collective_s=lt,
            model_flops=model_flops, useful_ratio=useful,
            bottleneck=max(terms, key=terms.get),
            collectives=dict(costs.collectives),
            memory_analysis=memory_analysis)

    def asdict(self) -> dict:
        return dataclasses.asdict(self)


def model_flops_for(cfg, shape) -> float:
    """Analytic MODEL_FLOPS: 6·N_active·D (train) / 2·N_active per token."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.seq_len * shape.global_batch
    if shape.kind == "prefill":
        return 2.0 * n * shape.seq_len * shape.global_batch
    return 2.0 * n * shape.global_batch          # decode: one token per seq
