"""Production meshes (v5e).

Defined as functions, never module-level constants: importing this module
must not touch jax device state (the dry-run pins the device count before
any jax initialization).
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh


def _mesh(shape, axes):
    n = int(np.prod(shape))
    devs = jax.devices()
    # a real error, not an assert: a too-small device pool must fail loudly
    # even under `python -O` (a silently mis-shaped Mesh crashes far later)
    if len(devs) < n:
        raise ValueError(f"need {n} devices, have {len(devs)}")
    return Mesh(np.asarray(devs[:n]).reshape(shape), axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    if multi_pod:
        return _mesh((2, 16, 16), ("pod", "data", "model"))
    return _mesh((16, 16), ("data", "model"))


def make_test_mesh(n_data: int = 2, n_model: int = 2):
    """Small host-device mesh for tests (requires the XLA host-device flag)."""
    return _mesh((n_data, n_model), ("data", "model"))


def data_parallel_size(mesh) -> int:
    """Product of the cluster-carrying axes ('pod' x 'data')."""
    n = mesh.shape.get("data", 1)
    return n * mesh.shape.get("pod", 1)
