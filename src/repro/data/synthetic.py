"""Synthetic data generators.

Two workloads, mirroring the paper's pipeline:

1. **LM pretraining stream** — Markov-chain token sequences over the model's
   vocab (the "large-scale unlabeled corpus" of the cloud tier). A learnable
   structure (low-entropy transitions) so pretraining measurably reduces loss.

2. **Classification task** — the stand-in for the paper's flower dataset
   (§V): each class is a perturbed Markov chain sharing a common base, so a
   backbone pretrained on the mixture transfers to classification. Used by
   the Fig 6/7 and Table III/IV reproductions.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _row_normalize(m: np.ndarray) -> np.ndarray:
    return m / m.sum(axis=1, keepdims=True)


def markov_chain(rng: np.random.Generator, vocab: int,
                 concentration: float = 0.1) -> np.ndarray:
    """Sparse-ish transition matrix: low entropy => learnable."""
    m = rng.dirichlet(np.full(vocab, concentration), size=vocab)
    return m.astype(np.float64)


def sample_markov(rng: np.random.Generator, trans: np.ndarray, n: int,
                  seq: int) -> np.ndarray:
    vocab = trans.shape[0]
    out = np.empty((n, seq), np.int32)
    state = rng.integers(0, vocab, size=n)
    cum = np.cumsum(trans, axis=1)
    for t in range(seq):
        out[:, t] = state
        u = rng.random(n)[:, None]
        state = (u > cum[state]).sum(axis=1)
    return out


@dataclasses.dataclass
class LMStream:
    """Infinite next-token-prediction batches from a Markov corpus."""
    vocab: int
    batch: int
    seq: int
    seed: int = 0
    concentration: float = 0.1

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self.trans = markov_chain(rng, self.vocab, self.concentration)
        self._rng = np.random.default_rng(self.seed + 1)

    def __iter__(self) -> Iterator[dict]:
        while True:
            toks = sample_markov(self._rng, self.trans, self.batch, self.seq + 1)
            yield {"tokens": jnp.asarray(toks[:, :-1]),
                   "labels": jnp.asarray(toks[:, 1:])}


@dataclasses.dataclass
class ClassificationTask:
    """Class-conditional Markov sequences (the synthetic 'flowers')."""
    n_classes: int
    vocab: int
    seq: int
    seed: int = 0
    class_strength: float = 0.5     # 0 = identical classes, 1 = disjoint

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        base = markov_chain(rng, self.vocab)
        self.trans = []
        for _ in range(self.n_classes):
            pert = markov_chain(rng, self.vocab)
            self.trans.append(_row_normalize(
                (1 - self.class_strength) * base + self.class_strength * pert))
        self._rng = np.random.default_rng(self.seed + 7)

    def sample(self, n: int, rng: Optional[np.random.Generator] = None,
               classes: Optional[np.ndarray] = None) -> dict:
        rng = rng or self._rng
        labels = rng.integers(0, self.n_classes, size=n) if classes is None \
            else rng.choice(classes, size=n)
        toks = np.empty((n, self.seq), np.int32)
        for c in range(self.n_classes):
            idx = np.nonzero(labels == c)[0]
            if len(idx):
                toks[idx] = sample_markov(rng, self.trans[c], len(idx), self.seq)
        return {"tokens": jnp.asarray(toks),
                "label": jnp.asarray(labels.astype(np.int32))}

    def dataset(self, n: int, seed: int = 0) -> dict:
        """Fixed train/eval arrays (numpy, for partitioning)."""
        rng = np.random.default_rng(seed)
        d = self.sample(n, rng)
        return {"tokens": np.asarray(d["tokens"]),
                "label": np.asarray(d["label"])}

    def pretrain_stream(self, batch: int) -> Iterator[dict]:
        """LM batches over the class mixture (the 'unlabeled corpus')."""
        while True:
            d = self.sample(batch)
            toks = np.asarray(d["tokens"])
            yield {"tokens": jnp.asarray(toks[:, :-1]),
                   "labels": jnp.asarray(toks[:, 1:])}
