"""Batching pipeline over in-memory datasets (per-cluster shards).

Two ways to feed core/hfsl.py:

- :func:`cluster_batches` — legacy host iterator: one host->device copy per
  step (kept for parity tests and host-streamed datasets).
- :class:`BatchBank` — device-resident bank: a whole epoch of per-cluster
  batches pre-packed into stacked ``(steps, cluster, batch, ...)`` device
  arrays, gathered *inside* the scanned round by step index
  (hfsl.make_hfsl_round) — zero host transfers inside a round.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Iterator, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding.rules import dim_sharding


def batches(data: dict, batch_size: int, *, shuffle: bool = True,
            seed: int = 0, drop_last: bool = True,
            epochs: Optional[int] = None) -> Iterator[dict]:
    """Yield dict batches from a dict of equal-length arrays."""
    n = len(next(iter(data.values())))
    rng = np.random.default_rng(seed)
    epoch = 0
    while epochs is None or epoch < epochs:
        order = rng.permutation(n) if shuffle else np.arange(n)
        stop = (n // batch_size) * batch_size if drop_last else n
        for lo in range(0, stop, batch_size):
            idx = order[lo:lo + batch_size]
            yield {k: jnp.asarray(v[idx]) for k, v in data.items()}
        epoch += 1


def cluster_batches(data: dict, parts: Sequence[np.ndarray], batch_size: int,
                    *, seed: int = 0) -> Iterator[dict]:
    """Stacked per-cluster batches: leaves get a leading cluster dim.

    Used by core/hfsl.py — cluster c trains on parts[c] only (the paper's
    'personalized local data stays in its cluster')."""
    its = [batches({k: v[p] for k, v in data.items()}, batch_size,
                   seed=seed + i) for i, p in enumerate(parts)]
    while True:
        bs = [next(it) for it in its]
        yield {k: jnp.stack([b[k] for b in bs]) for k in bs[0]}


@dataclasses.dataclass
class BatchBank:
    """Device-resident epoch of stacked per-cluster batches.

    ``arrays`` leaves are ``(steps, n_clusters, batch, ...)`` device arrays.
    hfsl.make_hfsl_round gathers row ``(offset + i) % steps`` by the scanned
    step index, so a round of K steps touches the host zero times; the
    ``offset`` cursor (see :meth:`advance`) carries epoch position across
    rounds exactly like the legacy iterator would.

    Packed with a ``mesh``, every leaf is placed with its ``cluster`` dim on
    the mesh's (`pod`, `data`) axes (sharding/rules.py `cluster` rule): each
    cluster's batches live on the mesh slice that trains that cluster, so
    the scanned round's per-step gather never moves a batch off its slice.
    The same placement is what hfsl.make_hfsl_round(mesh=...) pins as its
    bank in_sharding — pack and round agree by construction.
    """
    arrays: dict
    offset: int = 0

    @staticmethod
    def shardings(arrays: dict, mesh, rules: Optional[dict] = None):
        """The bank's NamedSharding tree: cluster dim (axis 1) on `data`."""
        n_clusters = next(iter(jax.tree.leaves(arrays))).shape[1]
        sh = dim_sharding(mesh, n_clusters, "cluster", index=1, rules=rules)
        return jax.tree.map(lambda _: sh, arrays)

    @property
    def steps(self) -> int:
        return next(iter(jax.tree.leaves(self.arrays))).shape[0]

    @property
    def n_clusters(self) -> int:
        return next(iter(jax.tree.leaves(self.arrays))).shape[1]

    def advance(self, steps: int) -> int:
        """Return the current cursor and move it ``steps`` forward (wraps)."""
        off = self.offset
        self.offset = (self.offset + steps) % self.steps
        return off

    @classmethod
    def pack(cls, data: dict, parts: Sequence[np.ndarray], batch_size: int,
             *, seed: int = 0, steps: Optional[int] = None,
             mesh=None, rules: Optional[dict] = None) -> "BatchBank":
        """Pre-pack one epoch of :func:`cluster_batches`-shaped batches.

        The epoch length is the smallest cluster's batch count (every row
        must hold one batch per cluster) unless ``steps`` caps it. With a
        ``mesh``, leaves are placed cluster-sharded over `data` (see class
        docstring).
        """
        epoch = min(len(p) // batch_size for p in parts)
        if steps is not None:
            epoch = min(epoch, steps)
        if epoch < 1:
            raise ValueError(
                f"smallest cluster has < {batch_size} examples; "
                "cannot pack a BatchBank row")
        it = cluster_batches(data, parts, batch_size, seed=seed)
        return cls.from_iterator(it, epoch, mesh=mesh, rules=rules)

    @classmethod
    def from_iterator(cls, it: Iterator[dict], steps: int, *,
                      mesh=None, rules: Optional[dict] = None) -> "BatchBank":
        """Stack ``steps`` batches from any cluster-batch iterator."""
        rows = list(itertools.islice(it, steps))
        arrays = {k: jnp.stack([r[k] for r in rows]) for k in rows[0]}
        if mesh is not None:
            arrays = jax.device_put(arrays,
                                    cls.shardings(arrays, mesh, rules))
        return cls(arrays)
