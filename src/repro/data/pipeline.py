"""Batching pipeline over in-memory datasets (per-cluster shards)."""
from __future__ import annotations

from typing import Iterator, Optional, Sequence

import jax.numpy as jnp
import numpy as np


def batches(data: dict, batch_size: int, *, shuffle: bool = True,
            seed: int = 0, drop_last: bool = True,
            epochs: Optional[int] = None) -> Iterator[dict]:
    """Yield dict batches from a dict of equal-length arrays."""
    n = len(next(iter(data.values())))
    rng = np.random.default_rng(seed)
    epoch = 0
    while epochs is None or epoch < epochs:
        order = rng.permutation(n) if shuffle else np.arange(n)
        stop = (n // batch_size) * batch_size if drop_last else n
        for lo in range(0, stop, batch_size):
            idx = order[lo:lo + batch_size]
            yield {k: jnp.asarray(v[idx]) for k, v in data.items()}
        epoch += 1


def cluster_batches(data: dict, parts: Sequence[np.ndarray], batch_size: int,
                    *, seed: int = 0) -> Iterator[dict]:
    """Stacked per-cluster batches: leaves get a leading cluster dim.

    Used by core/hfsl.py — cluster c trains on parts[c] only (the paper's
    'personalized local data stays in its cluster')."""
    its = [batches({k: v[p] for k, v in data.items()}, batch_size,
                   seed=seed + i) for i, p in enumerate(parts)]
    while True:
        bs = [next(it) for it in its]
        yield {k: jnp.stack([b[k] for b in bs]) for k in bs[0]}
