"""Non-IID client partitioners (paper §V-D, Table III).

The paper's Non-IID knob is "number of data classes per client"; we provide
that partitioner plus the standard Dirichlet one.
"""
from __future__ import annotations

import numpy as np


def partition_by_classes(labels: np.ndarray, n_clients: int,
                         classes_per_client: int, seed: int = 0
                         ) -> list[np.ndarray]:
    """Each client sees exactly `classes_per_client` classes (paper Table III)."""
    rng = np.random.default_rng(seed)
    classes = np.unique(labels)
    assignments = [rng.choice(classes, size=classes_per_client, replace=False)
                   for _ in range(n_clients)]
    by_class = {c: rng.permutation(np.nonzero(labels == c)[0]) for c in classes}
    cursors = {c: 0 for c in classes}
    # count how many clients want each class to split fairly
    want = {c: sum(int(c in a) for a in assignments) for c in classes}
    out = []
    for a in assignments:
        idx = []
        for c in a:
            pool = by_class[c]
            share = len(pool) // max(want[c], 1)
            lo = cursors[c]
            idx.append(pool[lo:lo + share])
            cursors[c] += share
        out.append(np.concatenate(idx) if idx else np.empty((0,), np.int64))
    return out


def dirichlet_partition(labels: np.ndarray, n_clients: int,
                        alpha: float = 0.5, seed: int = 0) -> list[np.ndarray]:
    rng = np.random.default_rng(seed)
    classes = np.unique(labels)
    out: list[list[int]] = [[] for _ in range(n_clients)]
    for c in classes:
        idx = rng.permutation(np.nonzero(labels == c)[0])
        props = rng.dirichlet(np.full(n_clients, alpha))
        cuts = (np.cumsum(props)[:-1] * len(idx)).astype(int)
        for client, part in enumerate(np.split(idx, cuts)):
            out[client].extend(part.tolist())
    return [np.asarray(sorted(x)) for x in out]
