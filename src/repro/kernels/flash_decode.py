"""Split-KV flash-decode Pallas kernel (TPU target).

Decode attention: ONE query token per sequence attends to a length-T KV
cache. The cache is orders of magnitude larger than the query, so the kernel
is memory-bound and its only job is to stream K/V through VMEM exactly once.

Grid: ``(B, Hkv, num_kv_chunks)`` — the KV-chunk dimension is innermost and
sequential. Each step loads one ``(block_kv, D)`` K/V chunk and folds it into
f32 online-softmax partials ``(acc, m, l)`` held in VMEM scratch that persist
across the chunk dimension (the split-KV reduction); the normalized output is
written on the last chunk. GQA is expressed in the index_maps: the
``g = Hq // Hkv`` query heads sharing one KV head are stacked into the
sublane dim of a single ``(g, D)`` q tile, so grouped queries ride along for
free instead of duplicating KV reads per query head.

Masking is position-based and length-aware (kernels/ref.py semantics):
unwritten cache slots carry the ``+1e9`` sentinel position and are never
visible — decode never reads garbage K/V even though the buffer is padded to
``max_len``; prefix-KV slots carry negative positions and are always
visible. ``q_pos`` may be per-row ``(B,)`` and ``kv_pos`` per-row ``(B, T)``
so batch slots at different sequence positions (the serving engine's
continuous-batching layout) share one kernel launch.
"""
# tracelint: kernel-op=flash_decode oracle=decode_attention
# tracelint: kernel-op=flash_decode_paged oracle=paged_decode_attention
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(qpos_ref, kpos_ref, q_ref, k_ref, v_ref, o_ref,
            acc_ref, m_ref, l_ref, *, scale: float, causal: bool,
            window: int, nk: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0, :, :].astype(jnp.float32)              # (g, Dp)
    k = k_ref[0, :, 0, :].astype(jnp.float32)              # (bkv, Dp)
    v = v_ref[0, :, 0, :].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    qp = qpos_ref[0, 0]                                     # scalar position
    kpos = kpos_ref[0, :][None, :]                          # (1, bkv)
    vis = (kpos <= qp) if causal else (kpos < 10 ** 8)     # sentinel padding
    if window and window > 0:
        vis = jnp.logical_and(vis, (qp - kpos) < window)
    vis = jnp.logical_or(vis, kpos < 0)                     # prefix slots
    s = jnp.where(vis, s, NEG_INF)

    m_prev, l_prev, acc_prev = m_ref[...], l_ref[...], acc_ref[...]
    m_cur = jnp.max(s, axis=-1)[:, None]                    # (g, 1)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                                  # (g, bkv)
    l_new = l_prev * alpha + jnp.sum(p, axis=-1)[:, None]
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_new = acc_prev * alpha + pv

    m_ref[...] = m_new
    l_ref[...] = l_new
    acc_ref[...] = acc_new

    @pl.when(j == nk - 1)
    def _done():
        out = acc_new / jnp.maximum(l_new, 1e-30)
        o_ref[0, 0, :, :] = out.astype(o_ref.dtype)


def _pad(x, axis, mult, value=0):
    n = x.shape[axis]
    p = (-n) % mult
    if p == 0:
        return x
    w = [(0, 0)] * x.ndim
    w[axis] = (0, p)
    return jnp.pad(x, w, constant_values=value)


def _paged_kernel(tbl_ref, qpos_ref, q_ref, k_ref, v_ref, o_ref,
                  acc_ref, m_ref, l_ref, *, scale: float, bs: int, nk: int):
    """Block-table split-KV step: one grid step = one POOL BLOCK.

    Identical online-softmax math to :func:`_kernel`; the only
    differences are (a) K/V arrive through the scalar-prefetched block
    table (the index_maps below gather ``pool[table[b, j]]``), and (b)
    kv positions are implicit — pool blocks have no position plane, a
    table slot ``j`` holds tokens ``[j*bs, (j+1)*bs)`` by construction,
    so visibility is purely causal against ``q_pos``. Unwritten slots
    (garbage blocks, stale data past the row's length) sit at positions
    ``> q_pos`` and mask to an exact f32 zero, which is what makes the
    paged path bit-identical to the dense kernel at ``block_kv == bs``.
    """
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0, :, :].astype(jnp.float32)              # (g, Dp)
    k = k_ref[0, :, 0, :].astype(jnp.float32)              # (bs, Dp)
    v = v_ref[0, :, 0, :].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    qp = qpos_ref[0, 0]                                     # scalar position
    kpos = j * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
    s = jnp.where(kpos <= qp, s, NEG_INF)                   # causal only

    m_prev, l_prev, acc_prev = m_ref[...], l_ref[...], acc_ref[...]
    m_cur = jnp.max(s, axis=-1)[:, None]                    # (g, 1)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                                  # (g, bs)
    l_new = l_prev * alpha + jnp.sum(p, axis=-1)[:, None]
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_new = acc_prev * alpha + pv

    m_ref[...] = m_new
    l_ref[...] = l_new
    acc_ref[...] = acc_new

    @pl.when(j == nk - 1)
    def _done():
        out = acc_new / jnp.maximum(l_new, 1e-30)
        o_ref[0, 0, :, :] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def flash_decode_paged_pallas(q, k_pool, v_pool, table, *, q_pos,
                              scale: Optional[float] = None,
                              interpret: bool = False):
    """Paged flash-decode: gather KV chunks THROUGH the block table.

    q: (B, Hq, D); k_pool, v_pool: (n_blocks, bs, Hkv, D) device pool;
    table: (B, max_blocks) int32 — row b's logical token ``t`` lives at
    ``pool[table[b, t // bs], t % bs]``. One kv-chunk = one pool block:
    the table rides in as a scalar-prefetch operand so the K/V
    index_maps can dereference ``table[b, j]`` when scheduling block
    DMAs. Causal-only (full-window decode; sliding/prefix rows stay on
    the dense path). Out-of-pool table entries (the ``n_blocks``
    sentinel in unwritten slots) are clamped to block 0 — those slots
    are beyond ``q_pos`` and fully masked. Returns (B, Hq, D).
    """
    B, Hq, D = q.shape
    nb, bs, Hkv, _ = k_pool.shape
    g = Hq // Hkv
    maxb = table.shape[1]
    scale = scale if scale is not None else D ** -0.5

    Dp = max(128, D + (-D) % 128)
    qp4 = _pad(q.reshape(B, Hkv, g, D), 3, Dp)
    kp = _pad(k_pool, 3, Dp)
    vp = _pad(v_pool, 3, Dp)
    qpos = jnp.broadcast_to(jnp.asarray(q_pos, jnp.int32), (B,))[:, None]
    tbl = jnp.clip(table.astype(jnp.int32), 0, nb - 1)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hkv, maxb),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, j, tbl: (b, 0)),
            pl.BlockSpec((1, 1, g, Dp), lambda b, h, j, tbl: (b, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, Dp),
                         lambda b, h, j, tbl: (tbl[b, j], 0, h, 0)),
            pl.BlockSpec((1, bs, 1, Dp),
                         lambda b, h, j, tbl: (tbl[b, j], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, Dp),
                               lambda b, h, j, tbl: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, Dp), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_paged_kernel, scale=scale, bs=bs, nk=maxb),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, g, Dp), q.dtype),
        interpret=interpret,
    )(tbl, qpos, qp4, kp, vp)
    return out[..., :D].reshape(B, Hq, D)


@functools.partial(jax.jit, static_argnames=(
    "window", "causal", "scale", "block_kv", "interpret"))
def flash_decode_pallas(q, k, v, *, q_pos, kv_pos, window: int = 0,
                        causal: bool = True, scale: Optional[float] = None,
                        block_kv: int = 256, interpret: bool = False):
    """q: (B, Hq, D); k, v: (B, T, Hkv, D); q_pos: () or (B,);
    kv_pos: (T,) or (B, T). Returns (B, Hq, D) in q.dtype."""
    B, Hq, D = q.shape
    _, T, Hkv, _ = k.shape
    g = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    bkv = min(block_kv, T)

    Dp = max(128, D + (-D) % 128)
    qp4 = _pad(q.reshape(B, Hkv, g, D), 3, Dp)
    kp = _pad(_pad(k, 1, bkv), 3, Dp)
    vp = _pad(_pad(v, 1, bkv), 3, Dp)
    qpos = jnp.broadcast_to(jnp.asarray(q_pos, jnp.int32), (B,))[:, None]
    kvpos = _pad(jnp.broadcast_to(jnp.asarray(kv_pos, jnp.int32), (B, T)),
                 1, bkv, value=10 ** 9)                     # padding invisible
    Tp = kp.shape[1]
    nk = Tp // bkv

    grid = (B, Hkv, nk)
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal,
                          window=window, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, j: (b, 0)),
            pl.BlockSpec((1, bkv), lambda b, h, j: (b, j)),
            pl.BlockSpec((1, 1, g, Dp), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, bkv, 1, Dp), lambda b, h, j: (b, j, h, 0)),
            pl.BlockSpec((1, bkv, 1, Dp), lambda b, h, j: (b, j, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, Dp), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, g, Dp), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, Dp), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qpos, kvpos, qp4, kp, vp)
    return out[..., :D].reshape(B, Hq, D)
