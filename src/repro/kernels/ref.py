"""Pure-jnp reference oracles for every Pallas kernel.

These are the ground truth for the kernel allclose sweeps in
``tests/test_kernels.py`` — deliberately naive, no blocking, f32 math.

Shared semantics (flash attention): masking is *position based*. Each query
row has an absolute position ``q_pos[i]`` and each key/value slot a position
``kv_pos[j]``. A slot is visible iff

    kv_pos[j] < 0                        (prefix-KV slots: always visible)
 or (kv_pos[j] <= q_pos[i]              (causal)
     and q_pos[i] - kv_pos[j] < window)  (sliding window; window<=0 => off)

Padding slots use kv_pos = +LARGE so they are never visible.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def visibility_mask(q_pos: jax.Array, kv_pos: jax.Array,
                    window: int = 0, causal: bool = True) -> jax.Array:
    """(S, T) boolean visibility per the shared semantics above."""
    q = q_pos[:, None].astype(jnp.int32)
    k = kv_pos[None, :].astype(jnp.int32)
    vis = (k <= q) if causal else \
        jnp.broadcast_to(k < 10 ** 8, (q.shape[0], k.shape[1]))  # hide sentinels
    if window and window > 0:
        vis = vis & ((q - k) < window)
    vis = vis | (k < 0)                     # prefix slots
    return vis


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              q_pos: jax.Array, kv_pos: jax.Array,
              window: int = 0, causal: bool = True,
              scale: Optional[float] = None) -> jax.Array:
    """Naive GQA attention.

    q: (B, S, Hq, D); k, v: (B, T, Hkv, D); Hq % Hkv == 0.
    Returns (B, S, Hq, D) in q.dtype.
    """
    B, S, Hq, D = q.shape
    _, T, Hkv, _ = k.shape
    g = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    qf = q.reshape(B, S, Hkv, g, D).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    scores = jnp.einsum("bsngd,btnd->bngst", qf, kf) * scale
    vis = visibility_mask(q_pos, kv_pos, window, causal)
    scores = jnp.where(vis[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bngst,btnd->bsngd", probs, v.astype(jnp.float32))
    return out.reshape(B, S, Hq, D).astype(q.dtype)


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                     q_pos: jax.Array, kv_pos: jax.Array,
                     window: int = 0, causal: bool = True,
                     scale: Optional[float] = None) -> jax.Array:
    """Naive single-token decode attention against a (padded) KV cache.

    q: (B, Hq, D) — one query token per sequence; k, v: (B, T, Hkv, D).
    q_pos: scalar or (B,) absolute query positions; kv_pos: (T,) or (B, T)
    cache-slot positions (shared masking semantics above: negative = prefix,
    +LARGE sentinel = unwritten slot, never visible).
    Returns (B, Hq, D) in q.dtype.
    """
    B, Hq, D = q.shape
    T = k.shape[1]
    qp = jnp.broadcast_to(jnp.asarray(q_pos, jnp.int32), (B,))
    kp = jnp.broadcast_to(jnp.asarray(kv_pos, jnp.int32), (B, T))

    def one(qb, kb, vb, qpb, kpb):
        out = attention(qb[None, None], kb[None], vb[None],
                        q_pos=qpb[None], kv_pos=kpb,
                        window=window, causal=causal, scale=scale)
        return out[0, 0]

    return jax.vmap(one)(q, k, v, qp, kp)


def paged_decode_attention(q: jax.Array, k_pool: jax.Array,
                           v_pool: jax.Array, table: jax.Array, *,
                           q_pos: jax.Array,
                           scale: Optional[float] = None) -> jax.Array:
    """Naive paged decode attention: gather the block pool, then
    :func:`decode_attention`.

    q: (B, Hq, D); k_pool, v_pool: (n_blocks, bs, Hkv, D);
    table: (B, max_blocks) int32 — logical token ``t`` of row ``b``
    lives at ``pool[table[b, t // bs], t % bs]``, so kv positions are
    the slot indices themselves (causal-only, no sentinel plane;
    unwritten slots are hidden by ``kv_pos > q_pos``). Out-of-pool
    table entries clamp to block 0 (masked the same way).
    Returns (B, Hq, D) in q.dtype.
    """
    nb, bs, Hkv, D = k_pool.shape
    B, maxb = table.shape
    tbl = jnp.clip(table.astype(jnp.int32), 0, nb - 1)
    k = k_pool[tbl].reshape(B, maxb * bs, Hkv, D)          # (B, T, Hkv, D)
    v = v_pool[tbl].reshape(B, maxb * bs, Hkv, D)
    kv_pos = jnp.arange(maxb * bs, dtype=jnp.int32)
    return decode_attention(q, k, v, q_pos=q_pos, kv_pos=kv_pos,
                            window=0, causal=True, scale=scale)


def selective_scan(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
                   C: jax.Array, D: jax.Array,
                   h0: Optional[jax.Array] = None) -> tuple[jax.Array, jax.Array]:
    """Mamba-1 selective scan (naive lax.scan over time).

    x, dt: (B, S, Di); A: (Di, N); Bm, C: (B, S, N); D: (Di,)
    h0: optional (B, Di, N) initial state (the PEFT "state prompt").
    Returns (y (B, S, Di), h_final (B, Di, N)); f32 math.
    """
    Bb, S, Di = x.shape
    N = A.shape[-1]
    xf, dtf = x.astype(jnp.float32), dt.astype(jnp.float32)
    Af, Bf, Cf = A.astype(jnp.float32), Bm.astype(jnp.float32), C.astype(jnp.float32)
    h = jnp.zeros((Bb, Di, N), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(h, t):
        xt, dtt, bt, ct = t
        dA = jnp.exp(dtt[..., None] * Af)                 # (B, Di, N)
        dBx = dtt[..., None] * bt[:, None, :] * xt[..., None]
        h = h * dA + dBx
        y = jnp.einsum("bdn,bn->bd", h, ct) + D.astype(jnp.float32) * xt
        return h, y

    ts = (jnp.swapaxes(xf, 0, 1), jnp.swapaxes(dtf, 0, 1),
          jnp.swapaxes(Bf, 0, 1), jnp.swapaxes(Cf, 0, 1))
    h, ys = jax.lax.scan(step, h, ts)
    return jnp.swapaxes(ys, 0, 1).astype(x.dtype), h


def rglru(x: jax.Array, r_gate: jax.Array, i_gate: jax.Array, a_param: jax.Array,
          h0: Optional[jax.Array] = None, c: float = 8.0) -> tuple[jax.Array, jax.Array]:
    """RG-LRU recurrence (RecurrentGemma eq. 5-7), naive scan.

    x, r_gate, i_gate: (B, S, W) — pre-computed gate pre-activations.
    a_param: (W,) raw; a = sigmoid(a_param); a_t = a ** (c * sigmoid(r_t)).
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (sigmoid(i_t) * x_t)
    Returns (h_seq (B, S, W), h_final (B, W)).
    """
    B, S, W = x.shape
    log_a = -c * jax.nn.softplus(-a_param.astype(jnp.float32))  # log sigmoid(a)*c... see note
    # a = sigmoid(a_param); a_t = exp(c * r_t * log(a)) with log(a) = -softplus(-a_param)
    h = jnp.zeros((B, W), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(h, t):
        xt, rt, it = t
        r = jax.nn.sigmoid(rt.astype(jnp.float32))
        log_at = r * log_a                                # (B, W), log_a includes factor c
        a_t = jnp.exp(log_at)
        gated = jax.nn.sigmoid(it.astype(jnp.float32)) * xt.astype(jnp.float32)
        h = a_t * h + jnp.sqrt(jnp.maximum(1.0 - a_t * a_t, 0.0)) * gated
        return h, h

    _, hs = jax.lax.scan(step, h, (jnp.swapaxes(x, 0, 1), jnp.swapaxes(r_gate, 0, 1),
                                   jnp.swapaxes(i_gate, 0, 1)))
    hs = jnp.swapaxes(hs, 0, 1)
    return hs.astype(x.dtype), hs[:, -1].astype(jnp.float32)


def lora_matmul(x: jax.Array, w: jax.Array, a: jax.Array, b: jax.Array,
                scale: float, bias: Optional[jax.Array] = None) -> jax.Array:
    """y = x @ w + scale * (x @ a) @ b (+ bias). x: (..., K); w: (K, N)."""
    xf = x.astype(jnp.float32)
    y = xf @ w.astype(jnp.float32)
    y = y + scale * (xf @ a.astype(jnp.float32)) @ b.astype(jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return y.astype(x.dtype)


def lora_bgmv(x: jax.Array, w: jax.Array, a_stack: jax.Array,
              b_stack: jax.Array, adapter_ids: jax.Array, scale: float,
              bias: Optional[jax.Array] = None) -> jax.Array:
    """Naive multi-LoRA matmul: per-row adapter gather, f32 math.

    x: (M, K) with adapter_ids (M,), or (B, S, K) with adapter_ids (B,)
    (one adapter per sequence). a_stack: (n_slots, K, r);
    b_stack: (n_slots, r, N). Row i computes
    ``x_i @ w + scale * (x_i @ a[id_i]) @ b[id_i]`` (+ bias).
    """
    shp = x.shape
    x2 = x.reshape(-1, shp[-1]).astype(jnp.float32)
    ids = jnp.asarray(adapter_ids, jnp.int32)
    if ids.shape[0] != x2.shape[0]:                # per-sequence -> per-row
        ids = jnp.repeat(ids, shp[1])
    a_sel = a_stack.astype(jnp.float32)[ids]       # (M, K, r)
    b_sel = b_stack.astype(jnp.float32)[ids]       # (M, r, N)
    y = x2 @ w.astype(jnp.float32)
    u = jnp.einsum("mk,mkr->mr", x2, a_sel)
    y = y + scale * jnp.einsum("mr,mrn->mn", u, b_sel)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return y.astype(x.dtype).reshape(*shp[:-1], w.shape[-1])


def lora_matmul_bwd(x: jax.Array, w: jax.Array, a: jax.Array, b: jax.Array,
                    scale: float, dy: jax.Array):
    """Naive einsum VJP of :func:`lora_matmul` wrt (x, a, b) — f32 math.

    The frozen-weight grad ``dW = x^T dy`` is deliberately absent: under the
    paper's PEFT regime it must never be materialized. Returns (dx, dA, dB).
    """
    xf, dyf = x.astype(jnp.float32), dy.astype(jnp.float32)
    af, bf, wf = (t.astype(jnp.float32) for t in (a, b, w))
    dx = dyf @ wf.T + scale * (dyf @ bf.T) @ af.T
    da = scale * xf.T @ (dyf @ bf.T)
    db = scale * (xf @ af).T @ dyf
    return dx.astype(x.dtype), da, db
