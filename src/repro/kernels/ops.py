"""Kernel dispatch layer.

Every hot-spot op has three interchangeable implementations:

- ``xla``       — pure-jnp *blocked* algorithm (same tiling/online-softmax
                  structure as the Pallas kernel). This is what the 512-way
                  CPU dry-run lowers, so the roofline reflects the intended
                  kernel structure (Mosaic only lowers on real TPUs).
- ``pallas``    — the TPU-target ``pl.pallas_call`` kernel.
- ``interpret`` — the same Pallas kernel with ``interpret=True`` (CPU
                  correctness path used by tests).

Select globally with :func:`set_backend` or per-call with ``backend=``.
"""
from __future__ import annotations

import contextlib
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref

_BACKEND = "xla"
NEG_INF = -1e30
_FLASH_BQ, _FLASH_BKV = 512, 1024     # default tiles; perf knob below


def set_flash_blocks(bq: int, bkv: int) -> None:
    """Perf knob (EXPERIMENTS.md §Perf): flash attention tile sizes."""
    global _FLASH_BQ, _FLASH_BKV
    _FLASH_BQ, _FLASH_BKV = bq, bkv


def set_backend(name: str) -> None:
    global _BACKEND
    if name not in ("xla", "pallas", "interpret"):
        raise ValueError(f"unknown kernel backend {name!r}: expected "
                         "'xla', 'pallas', or 'interpret'")
    _BACKEND = name


def get_backend() -> str:
    return _BACKEND


@contextlib.contextmanager
def backend(name: str):
    prev = get_backend()
    set_backend(name)
    try:
        yield
    finally:
        set_backend(prev)


def _pick(b: Optional[str]) -> str:
    return b or _BACKEND


# ---------------------------------------------------------------------------
# Flash attention (GQA + prefix-KV + sliding window, position-based masking)
# ---------------------------------------------------------------------------

def _pad_to(x: jax.Array, axis: int, mult: int, value=0.0) -> jax.Array:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    q_pos: jax.Array, kv_pos: jax.Array,
                    window: int = 0, causal: bool = True,
                    scale: Optional[float] = None,
                    block_q: Optional[int] = None,
                    block_kv: Optional[int] = None,
                    backend: Optional[str] = None) -> jax.Array:
    """Blocked online-softmax attention. Shapes as in :func:`ref.attention`."""
    block_q = block_q or _FLASH_BQ
    block_kv = block_kv or _FLASH_BKV
    impl = _pick(backend)
    if impl in ("pallas", "interpret"):
        from repro.kernels import flash_attention as fk
        return fk.flash_attention_pallas(
            q, k, v, q_pos=q_pos, kv_pos=kv_pos, window=window, causal=causal,
            scale=scale, block_q=block_q, block_kv=block_kv,
            interpret=(impl == "interpret"))
    return _flash_xla(q, k, v, q_pos=q_pos, kv_pos=kv_pos, window=window,
                      causal=causal, scale=scale, block_q=block_q,
                      block_kv=block_kv)


def _flash_xla(q, k, v, *, q_pos, kv_pos, window, causal, scale,
               block_q, block_kv):
    """Blocked online-softmax attention, head-flat layout.

    GQA KV heads are repeated up to the full head count before blocking so
    every block tensor carries one `heads` dim — under tensor parallelism
    each device then holds exactly its heads' K/V slice (the standard TP
    layout; without this GSPMD invents pathological shardings for the
    (Hkv, group) split dims). Explicit constraints keep the scan carry
    head-sharded.
    """
    from repro.sharding.rules import shard

    B, S, Hq, D = q.shape
    _, T, Hkv, _ = k.shape
    g = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    bq, bkv = min(block_q, S), min(block_kv, T)

    if g > 1:                                  # head-flat GQA (TP layout)
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    k = shard(k, "batch", "attn_seq", "heads", "head_dim")
    v = shard(v, "batch", "attn_seq", "heads", "head_dim")

    qp = _pad_to(q, 1, bq)
    q_posp = _pad_to(q_pos, 0, bq, value=-(10 ** 9))      # padded q rows see nothing
    kp = _pad_to(k, 1, bkv)
    vp = _pad_to(v, 1, bkv)
    kv_posp = _pad_to(kv_pos, 0, bkv, value=10 ** 9)      # padded kv never visible
    Sp, Tp = qp.shape[1], kp.shape[1]
    nq, nk = Sp // bq, Tp // bkv

    qb = qp.reshape(B, nq, bq, Hq, D).astype(jnp.float32)
    qb = shard(qb, "batch", None, None, "heads", "head_dim")
    qpb = q_posp.reshape(nq, bq)
    kb = jnp.moveaxis(kp.reshape(B, nk, bkv, Hq, D), 1, 0)
    vb = jnp.moveaxis(vp.reshape(B, nk, bkv, Hq, D), 1, 0)
    kvb = kv_posp.reshape(nk, bkv)

    def blk_step(qi, qpi, carry, blk):
        """One (q block, kv block) online-softmax update."""
        acc, m, l = carry
        kj, vj, kvp = blk
        kj = shard(kj, "batch", None, "heads", "head_dim")
        vj = shard(vj, "batch", None, "heads", "head_dim")
        s = jnp.einsum("bsnd,btnd->bnst", qi, kj.astype(jnp.float32)) * scale
        qpos = qpi[None, None, :, None]
        kpos = kvp[None, None, None, :]
        vis = (kpos <= qpos) if causal else (kpos < 10 ** 8)  # mask padding
        if window and window > 0:
            vis = vis & ((qpos - kpos) < window)
        vis = vis | (kpos < 0)
        s = jnp.where(vis, s, NEG_INF)
        s = shard(s, "batch", "heads", None, None)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bnst,btnd->bnsd", p, vj.astype(jnp.float32))
        acc_new = shard(acc_new, "batch", "heads", None, "head_dim")
        return acc_new, m_new, l_new

    # Block pruning (EXPERIMENTS.md §Perf iter q2): a causal q block only
    # touches kv blocks covering positions <= its last row; a sliding-window
    # block additionally skips blocks older than the window. Prefix slots
    # occupy the first ceil(n_p/bkv) blocks and are never pruned. This cuts
    # score traffic/FLOPs ~2x for causal training and ~S/window for long
    # sliding prefill versus the dense nq x nk sweep.
    # static prefix length from shapes: kv rows = n_prefix + S for
    # (prefix-tuned) self-attention; cross-attention is non-causal.
    n_prefix = max(T - S, 0) if causal else 0

    outs = []
    for i in range(nq):
        qi = qb[:, i]
        qpi = qpb[i]
        if causal:
            hi = n_prefix + min((i + 1) * bq, Sp)          # last visible kv row
            j_hi = min((hi + bkv - 1) // bkv, nk)
            j_lo = 0
            if window and window > 0:
                lo = n_prefix + max(i * bq - window + 1, 0)
                j_lo = max(lo // bkv, 0)
        else:
            j_lo, j_hi = 0, nk
        acc = jnp.zeros((B, Hq, bq, D), jnp.float32)
        m = jnp.full((B, Hq, bq), NEG_INF, jnp.float32)
        l = jnp.zeros((B, Hq, bq), jnp.float32)
        acc = shard(acc, "batch", "heads", None, "head_dim")
        if causal and window and window > 0 and j_lo > 0 and n_prefix > 0:
            # prefix blocks are below j_lo but always visible: visit block 0..
            pre_hi = (n_prefix + bkv - 1) // bkv
            for j in range(0, min(pre_hi, j_lo)):
                acc, m, l = blk_step(qi, qpi, (acc, m, l),
                                     (kb[j], vb[j], kvb[j]))
        if j_hi > j_lo:
            (acc, m, l), _ = jax.lax.scan(
                lambda c, blk: (blk_step(qi, qpi, c, blk), None),
                (acc, m, l), (kb[j_lo:j_hi], vb[j_lo:j_hi], kvb[j_lo:j_hi]))
        out_i = acc / jnp.maximum(l[..., None], 1e-30)     # (B, Hq, bq, D)
        outs.append(out_i.transpose(0, 2, 1, 3))           # (B, bq, Hq, D)
    out = jnp.concatenate(outs, axis=1)
    return out[:, :S].astype(q.dtype)


# ---------------------------------------------------------------------------
# Flash decode (single-token attention against a padded KV cache)
# ---------------------------------------------------------------------------

_DECODE_BKV = 256                      # default split-KV chunk; perf knob


def set_decode_block(bkv: int) -> None:
    """Perf knob: flash-decode KV chunk size."""
    global _DECODE_BKV
    _DECODE_BKV = bkv


def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array, *,
                 q_pos: jax.Array, kv_pos: jax.Array,
                 prefix_k: Optional[jax.Array] = None,
                 prefix_v: Optional[jax.Array] = None,
                 window: int = 0, causal: bool = True,
                 scale: Optional[float] = None,
                 block_kv: Optional[int] = None,
                 backend: Optional[str] = None) -> jax.Array:
    """One decode token per sequence against a KV cache (+ prefix bank).

    q: (B, Hq, D); k, v: (B, T, Hkv, D); q_pos: scalar or (B,);
    kv_pos: (T,) or (B, T) cache-slot positions (``+1e9`` sentinel marks
    unwritten slots — length-aware masking keeps them invisible).
    prefix_k/v: (n_p, Hkv, D) or (B, n_p, Hkv, D) always-visible learned
    slots (prefix-KV prompts; position < 0 in the shared semantics).
    Returns (B, Hq, D) in q.dtype.
    """
    block_kv = block_kv or _DECODE_BKV
    impl = _pick(backend)
    if impl in ("pallas", "interpret"):
        from repro.kernels import flash_decode as fdk
        B, T = k.shape[0], k.shape[1]
        if prefix_k is not None:
            pk, pv = _broadcast_prefix(prefix_k, prefix_v, B)
            n_p = pk.shape[1]
            k = jnp.concatenate([pk.astype(k.dtype), k], axis=1)
            v = jnp.concatenate([pv.astype(v.dtype), v], axis=1)
            kv_pos = jnp.concatenate(
                [jnp.full((B, n_p), -1, jnp.int32),
                 jnp.broadcast_to(jnp.asarray(kv_pos, jnp.int32), (B, T))],
                axis=1)
        return fdk.flash_decode_pallas(
            q, k, v, q_pos=q_pos, kv_pos=kv_pos, window=window,
            causal=causal, scale=scale, block_kv=block_kv,
            interpret=(impl == "interpret"))
    return _flash_decode_xla(q, k, v, q_pos=q_pos, kv_pos=kv_pos,
                             prefix_k=prefix_k, prefix_v=prefix_v,
                             window=window, causal=causal, scale=scale)


def _broadcast_prefix(prefix_k, prefix_v, B):
    if prefix_k.ndim == 3:                       # (n_p, Hkv, D) -> batched
        prefix_k = jnp.broadcast_to(prefix_k[None], (B, *prefix_k.shape))
        prefix_v = jnp.broadcast_to(prefix_v[None], (B, *prefix_v.shape))
    return prefix_k, prefix_v


def _flash_decode_xla(q, k, v, *, q_pos, kv_pos, prefix_k, prefix_v,
                      window, causal, scale):
    """Decode attention in XLA: native-dtype dots with f32 accumulation.

    Prefix-KV slots are attended SEPARATELY and merged with an
    online-softmax combine (EXPERIMENTS.md §Perf d2): concatenating n_p
    slots onto the seq-sharded cache misaligns its tiling and makes GSPMD
    all-gather the whole cache every layer (measured: the dominant decode
    traffic).
    """
    from repro.sharding.rules import shard

    B, Hq, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    g = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    qf = q.reshape(B, Hkv, g, D)
    qp = jnp.broadcast_to(jnp.asarray(q_pos, jnp.int32), (B,))
    kp = jnp.broadcast_to(jnp.asarray(kv_pos, jnp.int32), (B, T))
    k = shard(k, "batch", "kv_seq", "kv_heads", "head_dim")
    v = shard(v, "batch", "kv_seq", "kv_heads", "head_dim")

    def scores(kk, prefix: bool):
        """Masked scores against one KV bank (casting the cache to f32
        before the dot doubles HBM traffic — keep native dtype)."""
        s = jnp.einsum("bngd,btnd->bngt", qf, kk.astype(qf.dtype),
                       preferred_element_type=jnp.float32) * scale
        if prefix:
            return s                              # always fully visible
        vis = (kp <= qp[:, None]) if causal else (kp < 10 ** 8)
        if window and window > 0:
            vis = vis & ((qp[:, None] - kp) < window)
        vis = vis | (kp < 0)
        return jnp.where(vis[:, None, None, :], s, NEG_INF)

    def pv(p, vv):
        return jnp.einsum("bngt,btnd->bngd", p.astype(vv.dtype), vv,
                          preferred_element_type=jnp.float32)

    s_main = scores(k, prefix=False)              # (B, Hkv, g, T) sharded T
    if prefix_k is not None:
        pk, pvv = _broadcast_prefix(prefix_k, prefix_v, B)
        s_pfx = scores(pk, prefix=True)           # (B, Hkv, g, n_p)
        m = jnp.maximum(jnp.max(s_main, -1), jnp.max(s_pfx, -1))
        e_main = jnp.exp(s_main - m[..., None])
        e_pfx = jnp.exp(s_pfx - m[..., None])
        l = jnp.sum(e_main, -1) + jnp.sum(e_pfx, -1)    # (B, Hkv, g)
        denom = jnp.maximum(l, 1e-30)[..., None]
        o = (pv(e_main, v) + pv(e_pfx, pvv.astype(v.dtype))) / denom
    else:
        p = jax.nn.softmax(s_main, axis=-1)
        o = pv(p, v)
    return o.reshape(B, Hq, D).astype(q.dtype)


def flash_decode_paged(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                       table: jax.Array, *, q_pos: jax.Array,
                       prefix_k: Optional[jax.Array] = None,
                       prefix_v: Optional[jax.Array] = None,
                       scale: Optional[float] = None,
                       backend: Optional[str] = None) -> jax.Array:
    """One decode token per sequence against a PAGED block-pool cache.

    q: (B, Hq, D); k_pool, v_pool: (n_blocks, bs, Hkv, D); table:
    (B, max_blocks) int32 block table — row b's logical token ``t``
    lives at ``pool[table[b, t // bs], t % bs]``, so kv positions are
    implicit slot indices (causal-only; sliding-window layers stay on
    the dense rolling buffer). On pallas|interpret without a prefix
    bank the block table is dereferenced inside the kernel's index_maps
    (scalar prefetch, one kv-chunk = one block); the xla path and the
    prefix-bank fallback gather ``pool[table]`` into the dense layout
    and reuse :func:`_flash_decode_xla` / the dense kernel — which is
    exactly what makes paged drains bit-identical to dense ones (same
    visible values, masked slots contribute an exact f32 zero either
    way). Returns (B, Hq, D) in q.dtype.
    """
    impl = _pick(backend)
    nb, bs, Hkv, D = k_pool.shape
    B, maxb = table.shape
    if impl in ("pallas", "interpret") and prefix_k is None:
        from repro.kernels import flash_decode as fdk
        return fdk.flash_decode_paged_pallas(
            q, k_pool, v_pool, table, q_pos=q_pos, scale=scale,
            interpret=(impl == "interpret"))
    tbl = jnp.clip(table.astype(jnp.int32), 0, nb - 1)
    k = k_pool[tbl].reshape(B, maxb * bs, Hkv, D)
    v = v_pool[tbl].reshape(B, maxb * bs, Hkv, D)
    kv_pos = jnp.arange(maxb * bs, dtype=jnp.int32)
    if impl in ("pallas", "interpret"):           # prefix bank: dense kernel
        return flash_decode(q, k, v, q_pos=q_pos, kv_pos=kv_pos,
                            prefix_k=prefix_k, prefix_v=prefix_v,
                            window=0, causal=True, scale=scale, backend=impl)
    return _flash_decode_xla(q, k, v, q_pos=q_pos, kv_pos=kv_pos,
                             prefix_k=prefix_k, prefix_v=prefix_v,
                             window=0, causal=True, scale=scale)


# ---------------------------------------------------------------------------
# Selective scan (Mamba-1)
# ---------------------------------------------------------------------------

_SSM_XLA_IMPL = "assoc"     # "step" (naive scan) | "assoc" (chunked parallel)


def set_ssm_xla_impl(name: str) -> None:
    """Perf knob (EXPERIMENTS.md §Perf): XLA selective-scan algorithm."""
    global _SSM_XLA_IMPL
    if name not in ("step", "assoc"):
        raise ValueError(f"unknown selective-scan XLA impl {name!r}: "
                         "expected 'step' or 'assoc'")
    _SSM_XLA_IMPL = name


def selective_scan(x, dt, A, Bm, C, D, h0=None, *,
                   backend: Optional[str] = None):
    impl = _pick(backend)
    if impl in ("pallas", "interpret"):
        from repro.kernels import selective_scan as sk
        return sk.selective_scan_pallas(x, dt, A, Bm, C, D, h0,
                                        interpret=(impl == "interpret"))
    if _SSM_XLA_IMPL == "assoc":
        return _selective_scan_assoc(x, dt, A, Bm, C, D, h0)
    return ref.selective_scan(x, dt, A, Bm, C, D, h0)


def _selective_scan_assoc(x, dt, A, Bm, C, D, h0=None, chunk: int = 256):
    """Chunked parallel selective scan (the TPU kernel's dataflow in XLA).

    The recurrence h_t = a_t h_{t-1} + b_t is a first-order linear scan, so
    within a chunk we use `jax.lax.associative_scan` (log-depth, fully
    parallel on the VPU) and carry the state across chunks with an outer
    `lax.scan`. HBM traffic drops from O(S) state read/writes (the naive
    per-step scan) to O(S/chunk) state + streaming activations — matching
    what the Pallas kernel achieves with VMEM-resident state.
    """
    B, S, Di = x.shape
    N = A.shape[-1]
    cs = min(chunk, S)
    if S % cs:
        return ref.selective_scan(x, dt, A, Bm, C, D, h0)
    nchunks = S // cs

    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    Bf = Bm.astype(jnp.float32)
    Cf = C.astype(jnp.float32)
    Df = D.astype(jnp.float32)

    # per-step coefficients: h = dA * h_prev + dBx,  (B, S, Di, N)
    h = jnp.zeros((B, Di, N), jnp.float32) if h0 is None \
        else h0.astype(jnp.float32)

    def chunk_body(h_in, blk):
        xc, dtc, bc, cc = blk                       # (B, cs, Di/N)
        dA = jnp.exp(dtc[..., None] * Af)           # (B, cs, Di, N)
        dBx = (dtc * xc)[..., None] * bc[:, :, None, :]
        # fold the incoming state into the first step's additive term
        dBx = dBx.at[:, 0].add(dA[:, 0] * h_in)

        def combine(a, b):
            # (a2, b2) o (a1, b1) = (a1*a2, a2*b1 + b2) along time
            return a[0] * b[0], b[0] * a[1] + b[1]

        _, hs = jax.lax.associative_scan(combine, (dA, dBx), axis=1)
        y = jnp.einsum("bsdn,bsn->bsd", hs, cc) + Df * xc
        return hs[:, -1], y

    xcs = xf.reshape(B, nchunks, cs, Di).swapaxes(0, 1)
    dtcs = dtf.reshape(B, nchunks, cs, Di).swapaxes(0, 1)
    bcs = Bf.reshape(B, nchunks, cs, N).swapaxes(0, 1)
    ccs = Cf.reshape(B, nchunks, cs, N).swapaxes(0, 1)
    hT, ys = jax.lax.scan(chunk_body, h, (xcs, dtcs, bcs, ccs))
    y = ys.swapaxes(0, 1).reshape(B, S, Di)
    return y.astype(x.dtype), hT


def selective_scan_step(x, dt, A, Bm, C, D, h):
    """Single decode step. x, dt: (B, Di); Bm, C: (B, N); h: (B, Di, N)."""
    dA = jnp.exp(dt.astype(jnp.float32)[..., None] * A.astype(jnp.float32))
    dBx = dt.astype(jnp.float32)[..., None] * Bm.astype(jnp.float32)[:, None, :] \
        * x.astype(jnp.float32)[..., None]
    h = h.astype(jnp.float32) * dA + dBx
    y = jnp.einsum("bdn,bn->bd", h, C.astype(jnp.float32)) \
        + D.astype(jnp.float32) * x.astype(jnp.float32)
    return y.astype(x.dtype), h


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------

def rglru(x, r_gate, i_gate, a_param, h0=None, *, c: float = 8.0,
          backend: Optional[str] = None):
    impl = _pick(backend)
    if impl in ("pallas", "interpret"):
        from repro.kernels import rglru_scan as rk
        return rk.rglru_pallas(x, r_gate, i_gate, a_param, h0, c=c,
                               interpret=(impl == "interpret"))
    if _SSM_XLA_IMPL == "assoc":
        return _rglru_assoc(x, r_gate, i_gate, a_param, h0, c=c)
    return ref.rglru(x, r_gate, i_gate, a_param, h0, c=c)


def _rglru_assoc(x, r_gate, i_gate, a_param, h0=None, *, c: float = 8.0,
                 chunk: int = 256):
    """Chunked parallel RG-LRU (same first-order-linear-scan treatment as
    _selective_scan_assoc; diagonal state so no N blowup)."""
    B, S, W = x.shape
    cs = min(chunk, S)
    if S % cs:
        return ref.rglru(x, r_gate, i_gate, a_param, h0, c=c)
    nchunks = S // cs

    log_a = -c * jax.nn.softplus(-a_param.astype(jnp.float32))
    r = jax.nn.sigmoid(r_gate.astype(jnp.float32))
    a_t = jnp.exp(r * log_a)                                   # (B, S, W)
    gated = jax.nn.sigmoid(i_gate.astype(jnp.float32)) * x.astype(jnp.float32)
    b_t = jnp.sqrt(jnp.maximum(1.0 - a_t * a_t, 0.0)) * gated

    h = jnp.zeros((B, W), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def chunk_body(h_in, blk):
        ac, bc = blk
        bc = bc.at[:, 0].add(ac[:, 0] * h_in)

        def combine(p, q):
            return p[0] * q[0], q[0] * p[1] + q[1]

        _, hs = jax.lax.associative_scan(combine, (ac, bc), axis=1)
        return hs[:, -1], hs

    acs = a_t.reshape(B, nchunks, cs, W).swapaxes(0, 1)
    bcs = b_t.reshape(B, nchunks, cs, W).swapaxes(0, 1)
    hT, hs = jax.lax.scan(chunk_body, h, (acs, bcs))
    out = hs.swapaxes(0, 1).reshape(B, S, W)
    return out.astype(x.dtype), hT


def rglru_step(x, r_gate, i_gate, a_param, h, c: float = 8.0):
    """Single decode step; all (B, W)."""
    log_a = -c * jax.nn.softplus(-a_param.astype(jnp.float32))
    r = jax.nn.sigmoid(r_gate.astype(jnp.float32))
    a_t = jnp.exp(r * log_a)
    gated = jax.nn.sigmoid(i_gate.astype(jnp.float32)) * x.astype(jnp.float32)
    h = a_t * h.astype(jnp.float32) + jnp.sqrt(jnp.maximum(1 - a_t * a_t, 0.0)) * gated
    return h.astype(x.dtype), h


# ---------------------------------------------------------------------------
# LoRA-fused matmul (trainable: custom VJP so `grad` traverses the kernel)
# ---------------------------------------------------------------------------

def lora_matmul(x, w, a=None, b=None, scale: float = 1.0, bias=None, *,
                backend: Optional[str] = None):
    """y = x @ w (+ scale * (x@a)@b) (+ bias). Falls back to plain matmul.

    Differentiable on every backend: a custom VJP makes the fused Pallas
    forward usable under ``jax.grad``. On the PEFT hot path the backward
    costs only ``dx``/``dA``/``dB`` (+ ``dbias``) — adapter-only training
    (core/peft.py) never differentiates w, so the frozen-weight gradient
    ``dW = x^T dy`` is dead code under jit and never materializes; full
    fine-tuning (``trainable='all'``) still receives the exact dW.
    """
    if a is None:
        y = x @ w
        return (y + bias.astype(y.dtype)) if bias is not None else y
    return _lora_vjp(_pick(backend), float(scale), x, w, a, b, bias)


@partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _lora_vjp(impl, scale, x, w, a, b, bias):
    return _lora_forward(impl, scale, x, w, a, b, bias)


def _lora_forward(impl, scale, x, w, a, b, bias):
    if impl in ("pallas", "interpret") and x.ndim == 2:
        from repro.kernels import lora_matmul as lk
        return lk.lora_matmul_pallas(x, w, a, b, scale, bias,
                                     interpret=(impl == "interpret"))
    return _lora_xla(x, w, a, b, scale, bias)


def _lora_fwd_rule(impl, scale, x, w, a, b, bias):
    y = _lora_forward(impl, scale, x, w, a, b, bias)
    return y, (x, w, a, b, bias)


def _lora_bwd_rule(impl, scale, res, dy):
    """dx reuses the *forward* fused kernel (dx = dy W^T + s (dy B^T) A^T is
    itself a LoRA matmul with (W, A, B) -> (W^T, B^T, A^T)); dA/dB go through
    the dedicated adapter-grad kernel (kernels/lora_matmul.py::_bwd_kernel).
    dW = x^T dy is exact for full fine-tuning (peft.py trainable='all'), and
    under the PEFT regime — where w is never a differentiation target — the
    jitted round drops the dense matmul as dead code, so adapter-only
    training never materializes it."""
    x, w, a, b, bias = res
    x2 = x.reshape(-1, x.shape[-1])
    dy2 = dy.reshape(-1, dy.shape[-1])
    dx = _lora_forward(impl, scale, dy2, w.T, b.T, a.T, None)
    if impl in ("pallas", "interpret"):
        from repro.kernels import lora_matmul as lk
        da, db = lk.lora_matmul_bwd_pallas(x2, dy2, a, b, scale,
                                           interpret=(impl == "interpret"))
    else:
        da, db = _lora_bwd_xla(x2, dy2, a, b, scale)
    dw = jax.lax.dot_general(x2, dy2, (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    dbias = None if bias is None else \
        jnp.sum(dy2.astype(jnp.float32), axis=0).astype(bias.dtype)
    return (dx.reshape(x.shape).astype(x.dtype), dw.astype(w.dtype),
            da.astype(a.dtype), db.astype(b.dtype), dbias)


_lora_vjp.defvjp(_lora_fwd_rule, _lora_bwd_rule)


def _lora_bwd_xla(x, dy, a, b, scale):
    """Adapter grads, native-dtype dots with f32 accumulation (the kernel's
    dataflow in XLA): both rank-r intermediates are (M, r), so the extra HBM
    traffic over reading x/dy once is negligible."""
    g = jax.lax.dot_general(dy, b, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)   # dy @ b^T
    u = jax.lax.dot_general(x, a, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # x @ a
    da = scale * jax.lax.dot_general(
        x, g.astype(x.dtype), (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                       # x^T @ g
    db = scale * jax.lax.dot_general(
        u.astype(dy.dtype), dy, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                       # u^T @ dy
    return da, db


def lora_bgmv(x, w, a, b, adapter_ids, scale: float = 1.0, bias=None, *,
              backend: Optional[str] = None):
    """Multi-tenant LoRA matmul: per-row adapter selection from a stacked
    bank (kernels/lora_bgmv.py; serving-only, no VJP).

    x: (M, K) with adapter_ids (M,), or (B, S, K) with adapter_ids (B,).
    a: (n_slots, K, r); b: (n_slots, r, N); ids in [0, n_slots).
    Row i gets ``x_i @ w + scale * (x_i @ a[id_i]) @ b[id_i]`` (+ bias) —
    bit-identical per row to :func:`lora_matmul` with that row's adapter,
    which is what makes mixed-domain waves match per-domain serving
    token-for-token.
    """
    ids = jnp.asarray(adapter_ids, jnp.int32)
    # ids address x's LEADING dim on every backend: rows for 2D x, whole
    # sequences for 3D x. Reject per-token ids for 3D x here — the XLA
    # fallback would happily broadcast them while the gathered Pallas path
    # reads only ids[0:B], a silent cross-backend divergence.
    if ids.shape != (x.shape[0],):
        raise ValueError(
            f"adapter_ids {ids.shape} must be ({x.shape[0]},): one id per "
            f"{'sequence' if x.ndim == 3 else 'row'} of x {x.shape}")
    impl = _pick(backend)
    if impl in ("pallas", "interpret"):
        from repro.kernels import lora_bgmv as bk
        interp = impl == "interpret"
        if x.ndim == 3 and x.shape[1] > 1:         # prefill: gathered path
            return bk.lora_bgmv_seq_pallas(x, w, a, b, ids, float(scale),
                                           bias, interpret=interp)
        shp = x.shape                               # decode rows: BGMV path
        out = bk.lora_bgmv_rows_pallas(x.reshape(-1, shp[-1]), w, a, b, ids,
                                       float(scale), bias, interpret=interp)
        return out.reshape(*shp[:-1], w.shape[-1])
    return _bgmv_xla(x, w, a, b, ids, float(scale), bias)


def _bgmv_xla(x, w, a, b, ids, scale, bias=None):
    """Segment-matmul fallback: sweep the (static) slot dim with disjoint
    row masks instead of gathering (M, K, r) adapter copies. Per-row math
    mirrors :func:`_lora_xla` exactly (native-dtype dots, f32 accumulation,
    same cast points) so single- and multi-tenant serving agree bitwise.
    """
    shp = x.shape
    x2 = x.reshape(-1, shp[-1])
    if ids.shape[0] != x2.shape[0]:                # per-sequence -> per-row
        ids = jnp.repeat(ids, shp[1])
    y = jax.lax.dot_general(x2, w, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    mask = ids[:, None]
    for s in range(a.shape[0]):                    # static slot sweep
        xs = jnp.where(mask == s, x2, jnp.zeros((), x2.dtype))
        u = jax.lax.dot_general(xs, a[s], (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
        y = y + scale * jax.lax.dot_general(
            u.astype(x2.dtype), b[s], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return y.astype(x.dtype).reshape(*shp[:-1], w.shape[-1])


def _lora_xla(x, w, a, b, scale, bias=None):
    """Native-dtype dots with f32 accumulation (what the MXU does).

    The naive oracle upcasts x/w to f32 — on the XLA path that doubles HBM
    traffic for EVERY projection and drags f32 tensors through the backward
    collectives (EXPERIMENTS.md §Perf iter q4, found via the roofline
    profile)."""
    nd = x.ndim - 1
    y = jax.lax.dot_general(x, w, (((nd,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    u = jax.lax.dot_general(x, a, (((nd,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    y = y + scale * jax.lax.dot_general(
        u.astype(x.dtype), b, (((u.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return y.astype(x.dtype)
