"""Flash attention Pallas kernel (TPU target).

TPU-native adaptation: online-softmax over KV tiles held in VMEM, MXU-aligned
(block_q x head_dim) @ (head_dim x block_kv) dots, f32 accumulators in VMEM
scratch persisting across the sequential last grid dimension. Masking is
position-based (prefix-KV slots have negative positions and are always
visible; see kernels/ref.py for the shared semantics), so the same kernel
serves causal, sliding-window, and prefix-tuned attention.

Grid: (B, Hq, num_q_blocks, num_kv_blocks) — the kv dimension is innermost
and sequential; scratch (acc, m, l) carries across it, out is written on the
last kv step. GQA is expressed in the k/v index_maps (head h reads kv head
h // group).
"""
# tracelint: kernel-op=flash_attention oracle=attention
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(qpos_ref, kpos_ref, q_ref, k_ref, v_ref, o_ref,
            acc_ref, m_ref, l_ref, *, scale: float, causal: bool,
            window: int, nk: int):
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, :, 0, :].astype(jnp.float32)              # (bq, D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)              # (bkv, D)
    v = v_ref[0, :, 0, :].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    qpos = qpos_ref[:, 0][:, None]                          # (bq, 1)
    kpos = kpos_ref[:, 0][None, :]                          # (1, bkv)
    vis = (kpos <= qpos) if causal else (kpos < 10 ** 8)   # mask padding
    if window and window > 0:
        vis = jnp.logical_and(vis, (qpos - kpos) < window)
    vis = jnp.logical_or(vis, kpos < 0)
    s = jnp.where(vis, s, NEG_INF)

    m_prev, l_prev, acc_prev = m_ref[...], l_ref[...], acc_ref[...]
    m_cur = jnp.max(s, axis=-1)[:, None]                    # (bq, 1)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                                  # (bq, bkv)
    l_new = l_prev * alpha + jnp.sum(p, axis=-1)[:, None]
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_new = acc_prev * alpha + pv

    m_ref[...] = m_new
    l_ref[...] = l_new
    acc_ref[...] = acc_new

    @pl.when(j == nk - 1)
    def _done():
        out = acc_new / jnp.maximum(l_new, 1e-30)
        o_ref[0, :, 0, :] = out.astype(o_ref.dtype)


def _pad(x, axis, mult, value=0):
    n = x.shape[axis]
    p = (-n) % mult
    if p == 0:
        return x
    w = [(0, 0)] * x.ndim
    w[axis] = (0, p)
    return jnp.pad(x, w, constant_values=value)


@functools.partial(jax.jit, static_argnames=(
    "window", "causal", "scale", "block_q", "block_kv", "interpret"))
def flash_attention_pallas(q, k, v, *, q_pos, kv_pos, window: int = 0,
                           causal: bool = True, scale: Optional[float] = None,
                           block_q: int = 512, block_kv: int = 1024,
                           interpret: bool = False):
    B, S, Hq, D = q.shape
    _, T, Hkv, _ = k.shape
    g = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    bq, bkv = min(block_q, S), min(block_kv, T)

    # Pad: seq dims to block multiples, head_dim to the 128-lane MXU width.
    Dp = max(128, D + (-D) % 128)
    qp = _pad(_pad(q, 1, bq), 3, Dp)
    kp = _pad(_pad(k, 1, bkv), 3, Dp)
    vp = _pad(_pad(v, 1, bkv), 3, Dp)
    qpos = _pad(q_pos.astype(jnp.int32), 0, bq, value=-(10 ** 9))[:, None]
    kpos = _pad(kv_pos.astype(jnp.int32), 0, bkv, value=10 ** 9)[:, None]
    Sp, Tp = qp.shape[1], kp.shape[1]
    nq, nk = Sp // bq, Tp // bkv

    grid = (B, Hq, nq, nk)
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal,
                          window=window, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, 1), lambda b, h, i, j: (i, 0)),
            pl.BlockSpec((bkv, 1), lambda b, h, i, j: (j, 0)),
            pl.BlockSpec((1, bq, 1, Dp), lambda b, h, i, j: (b, i, h, 0)),
            pl.BlockSpec((1, bkv, 1, Dp), lambda b, h, i, j: (b, j, h // g, 0)),
            pl.BlockSpec((1, bkv, 1, Dp), lambda b, h, i, j: (b, j, h // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, Dp), lambda b, h, i, j: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sp, Hq, Dp), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, Dp), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qpos, kpos, qp, kp, vp)
    return out[:, :S, :, :D]
