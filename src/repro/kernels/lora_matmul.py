"""LoRA-fused matmul Pallas kernel (TPU target).

The paper's parameter-efficient path makes ``y = x W + s (x A) B`` the hot
matmul of both fine-tuning and parameter-efficient inference. Fusing the
low-rank branch into the frozen-weight matmul reads ``x`` from HBM once and
keeps the rank-r intermediate entirely in VMEM scratch (r <= 64 << N), so the
branch costs no extra HBM traffic.

Grid: (M/bm, N/bn, K/bk) with the K dimension innermost/sequential; f32
accumulators (bm, bn) and (bm, r) persist across K steps in VMEM scratch.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, a_ref, b_ref, bias_ref, o_ref, acc_ref, u_ref, *,
            nk: int, scale: float, has_bias: bool):
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        u_ref[...] = jnp.zeros_like(u_ref)

    x = x_ref[...].astype(jnp.float32)
    acc_ref[...] += jax.lax.dot(x, w_ref[...].astype(jnp.float32),
                                preferred_element_type=jnp.float32)
    u_ref[...] += jax.lax.dot(x, a_ref[...].astype(jnp.float32),
                              preferred_element_type=jnp.float32)

    @pl.when(kk == nk - 1)
    def _done():
        y = acc_ref[...] + scale * jax.lax.dot(
            u_ref[...], b_ref[...].astype(jnp.float32),
            preferred_element_type=jnp.float32)
        if has_bias:
            y = y + bias_ref[0, :].astype(jnp.float32)[None, :]
        o_ref[...] = y.astype(o_ref.dtype)


def _pad(x, axis, mult):
    p = (-x.shape[axis]) % mult
    if p == 0:
        return x
    w = [(0, 0)] * x.ndim
    w[axis] = (0, p)
    return jnp.pad(x, w)


@functools.partial(jax.jit, static_argnames=(
    "scale", "block_m", "block_n", "block_k", "interpret"))
def lora_matmul_pallas(x, w, a, b, scale: float = 1.0,
                       bias: Optional[jax.Array] = None, *,
                       block_m: int = 256, block_n: int = 512,
                       block_k: int = 512, interpret: bool = False):
    """x: (M, K); w: (K, N); a: (K, r); b: (r, N); bias: (N,) or None."""
    M, K = x.shape
    N = w.shape[1]
    r = a.shape[1]
    bm, bn, bk = min(block_m, M), min(block_n, N), min(block_k, K)
    rp = max(r + (-r) % 128, 128)                     # lane-align the rank dim

    xp, wp = _pad(_pad(x, 0, bm), 1, bk), _pad(_pad(w, 0, bk), 1, bn)
    ap = _pad(_pad(a, 0, bk), 1, rp)
    bp = _pad(_pad(b, 0, rp), 1, bn)
    has_bias = bias is not None
    biasp = _pad((bias if has_bias else jnp.zeros((N,), x.dtype))[None, :], 1, bn)
    Mp, Kp = xp.shape
    Np = wp.shape[1]
    nm, nn, nk = Mp // bm, Np // bn, Kp // bk

    out = pl.pallas_call(
        functools.partial(_kernel, nk=nk, scale=scale, has_bias=has_bias),
        grid=(nm, nn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bk, rp), lambda i, j, k: (k, 0)),
            pl.BlockSpec((rp, bn), lambda i, j, k: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32),
                        pltpu.VMEM((bm, rp), jnp.float32)],
        interpret=interpret,
    )(xp, wp, ap, bp, biasp)
    return out[:M, :N]
