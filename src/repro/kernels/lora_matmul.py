"""LoRA-fused matmul Pallas kernels (TPU target): forward + adapter backward.

The paper's parameter-efficient path makes ``y = x W + s (x A) B`` the hot
matmul of both fine-tuning and parameter-efficient inference. Fusing the
low-rank branch into the frozen-weight matmul reads ``x`` from HBM once and
keeps the rank-r intermediate entirely in VMEM scratch (r <= 64 << N), so the
branch costs no extra HBM traffic.

Forward grid: (M/bm, N/bn, K/bk) with the K dimension innermost/sequential;
f32 accumulators (bm, bn) and (bm, r) persist across K steps in VMEM scratch.

Backward (fine-tuning) only ever needs the *adapter* grads — the frozen
``dW = x^T dy`` is never formed (that would be a dense (K, N) matmul and a
dense gradient buffer per projection). ``lora_matmul_bwd_pallas`` computes

    dA = x^T (dy B^T) * s        (K, r)
    dB = (x A)^T dy * s          (r, N)

in ONE kernel: grid (M/bm,) sequential over row blocks, both rank-r
intermediates ``u = x A`` and ``g = s dy B^T`` are VMEM locals, and the two
adapter-sized outputs accumulate in their (revisited) output blocks — x and
dy are each read from HBM exactly once. ``dx`` reuses the *forward* kernel:
``dx = dy W^T + s (dy B^T) A^T`` is itself a LoRA-fused matmul with
``(W, A, B) -> (W^T, B^T, A^T)`` (see ops.py::lora_matmul's custom VJP).
"""
# tracelint: kernel-op=lora_matmul oracle=lora_matmul
# tracelint: kernel-op=lora_matmul oracle=lora_matmul_bwd
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, a_ref, b_ref, bias_ref, o_ref, acc_ref, u_ref, *,
            nk: int, scale: float, has_bias: bool):
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        u_ref[...] = jnp.zeros_like(u_ref)

    x = x_ref[...].astype(jnp.float32)
    acc_ref[...] += jax.lax.dot(x, w_ref[...].astype(jnp.float32),
                                preferred_element_type=jnp.float32)
    u_ref[...] += jax.lax.dot(x, a_ref[...].astype(jnp.float32),
                              preferred_element_type=jnp.float32)

    @pl.when(kk == nk - 1)
    def _done():
        y = acc_ref[...] + scale * jax.lax.dot(
            u_ref[...], b_ref[...].astype(jnp.float32),
            preferred_element_type=jnp.float32)
        if has_bias:
            y = y + bias_ref[0, :].astype(jnp.float32)[None, :]
        o_ref[...] = y.astype(o_ref.dtype)


def _pad(x, axis, mult):
    p = (-x.shape[axis]) % mult
    if p == 0:
        return x
    w = [(0, 0)] * x.ndim
    w[axis] = (0, p)
    return jnp.pad(x, w)


@functools.partial(jax.jit, static_argnames=(
    "scale", "block_m", "block_n", "block_k", "interpret"))
def lora_matmul_pallas(x, w, a, b, scale: float = 1.0,
                       bias: Optional[jax.Array] = None, *,
                       block_m: int = 256, block_n: int = 512,
                       block_k: int = 512, interpret: bool = False):
    """x: (M, K); w: (K, N); a: (K, r); b: (r, N); bias: (N,) or None."""
    M, K = x.shape
    N = w.shape[1]
    r = a.shape[1]
    bm, bn, bk = min(block_m, M), min(block_n, N), min(block_k, K)
    rp = max(r + (-r) % 128, 128)                     # lane-align the rank dim

    xp, wp = _pad(_pad(x, 0, bm), 1, bk), _pad(_pad(w, 0, bk), 1, bn)
    ap = _pad(_pad(a, 0, bk), 1, rp)
    bp = _pad(_pad(b, 0, rp), 1, bn)
    has_bias = bias is not None
    biasp = _pad((bias if has_bias else jnp.zeros((N,), x.dtype))[None, :], 1, bn)
    Mp, Kp = xp.shape
    Np = wp.shape[1]
    nm, nn, nk = Mp // bm, Np // bn, Kp // bk

    out = pl.pallas_call(
        functools.partial(_kernel, nk=nk, scale=scale, has_bias=has_bias),
        grid=(nm, nn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bk, rp), lambda i, j, k: (k, 0)),
            pl.BlockSpec((rp, bn), lambda i, j, k: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32),
                        pltpu.VMEM((bm, rp), jnp.float32)],
        interpret=interpret,
    )(xp, wp, ap, bp, biasp)
    return out[:M, :N]


# ---------------------------------------------------------------------------
# Backward: adapter grads dA, dB (never the frozen dW)
# ---------------------------------------------------------------------------

def _bwd_kernel(x_ref, dy_ref, a_ref, b_ref, da_ref, db_ref, *,
                scale: float):
    mm = pl.program_id(0)

    @pl.when(mm == 0)
    def _init():
        da_ref[...] = jnp.zeros_like(da_ref)
        db_ref[...] = jnp.zeros_like(db_ref)

    x = x_ref[...].astype(jnp.float32)                   # (bm, K)
    dy = dy_ref[...].astype(jnp.float32)                 # (bm, N)
    # rank-r intermediates never leave VMEM
    g = scale * jax.lax.dot_general(                     # s * dy @ b^T: (bm, r)
        dy, b_ref[...].astype(jnp.float32), (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    u = jax.lax.dot(x, a_ref[...].astype(jnp.float32),   # x @ a: (bm, r)
                    preferred_element_type=jnp.float32)
    da_ref[...] += jax.lax.dot_general(                  # x^T @ g: (K, r)
        x, g, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    db_ref[...] += scale * jax.lax.dot_general(          # s * u^T @ dy: (r, N)
        u, dy, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("scale", "block_m", "interpret"))
def lora_matmul_bwd_pallas(x, dy, a, b, scale: float = 1.0, *,
                           block_m: int = 128, interpret: bool = False):
    """Adapter grads of the fused forward. x: (M, K); dy: (M, N);
    a: (K, r); b: (r, N). Returns (dA (K, r) f32, dB (r, N) f32).

    One sequential sweep over M row blocks; K and N stay whole per block, so
    VMEM holds bm*(K+N) activations plus the two adapter-sized outputs —
    shrink ``block_m`` for very wide projections.
    """
    M, K = x.shape
    N = dy.shape[1]
    r = a.shape[1]
    bm = min(block_m, M)
    rp = max(r + (-r) % 128, 128)                     # lane-align the rank dim
    Kp = K + (-K) % 128
    Np = N + (-N) % 128

    xp = _pad(_pad(x, 0, bm), 1, 128)
    dyp = _pad(_pad(dy, 0, bm), 1, 128)
    ap = _pad(_pad(a, 0, 128), 1, rp)
    bp = _pad(_pad(b, 0, rp), 1, 128)
    nm = xp.shape[0] // bm

    da, db = pl.pallas_call(
        functools.partial(_bwd_kernel, scale=scale),
        grid=(nm,),
        in_specs=[
            pl.BlockSpec((bm, Kp), lambda i: (i, 0)),
            pl.BlockSpec((bm, Np), lambda i: (i, 0)),
            pl.BlockSpec((Kp, rp), lambda i: (0, 0)),
            pl.BlockSpec((rp, Np), lambda i: (0, 0)),
        ],
        out_specs=[pl.BlockSpec((Kp, rp), lambda i: (0, 0)),
                   pl.BlockSpec((rp, Np), lambda i: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((Kp, rp), jnp.float32),
                   jax.ShapeDtypeStruct((rp, Np), jnp.float32)],
        interpret=interpret,
    )(xp, dyp, ap, bp)
    return da[:K, :r], db[:r, :N]
