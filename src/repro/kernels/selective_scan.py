"""Mamba-1 selective scan Pallas kernel (TPU target).

TPU adaptation of the CUDA selective-scan: the channel dimension is tiled to
the 8x128 VPU lanes (block ``bd`` channels), the sequence is processed in
VMEM-resident chunks, and the (bd, N) state lives in f32 VMEM scratch that
persists across the sequential chunk grid dimension. All per-step math is
(bd, N)-vectorized; there is no cross-channel reduction except the final
C-contraction, which is an (bd, N) x (N,) elementwise-sum kept on the VPU
(N=16 is far below MXU utility).

Grid: (B, num_channel_blocks, num_seq_chunks) — chunks innermost/sequential.
"""
# tracelint: kernel-op=selective_scan oracle=selective_scan
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, A_ref, B_ref, C_ref, D_ref, h0_ref,
            y_ref, hT_ref, h_ref, *, cs: int, n_chunks: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        h_ref[...] = h0_ref[0].astype(jnp.float32)          # (bd, N)

    A = A_ref[...].astype(jnp.float32)                      # (bd, N)
    Dp = D_ref[:, 0].astype(jnp.float32)                    # (bd,)

    def step(t, h):
        xt = x_ref[0, t, :].astype(jnp.float32)             # (bd,)
        dtt = dt_ref[0, t, :].astype(jnp.float32)           # (bd,)
        bt = B_ref[0, t, :].astype(jnp.float32)             # (N,)
        ct = C_ref[0, t, :].astype(jnp.float32)             # (N,)
        dA = jnp.exp(dtt[:, None] * A)                      # (bd, N)
        h = h * dA + (dtt * xt)[:, None] * bt[None, :]
        y = jnp.sum(h * ct[None, :], axis=1) + Dp * xt      # (bd,)
        y_ref[0, t, :] = y.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, cs, step, h_ref[...])
    h_ref[...] = h

    @pl.when(j == n_chunks - 1)
    def _done():
        hT_ref[0] = h


@functools.partial(jax.jit, static_argnames=("chunk", "block_d", "interpret"))
def selective_scan_pallas(x, dt, A, Bm, C, D, h0=None, *,
                          chunk: int = 256, block_d: int = 512,
                          interpret: bool = False):
    """Shapes as kernels/ref.selective_scan. Returns (y, h_final)."""
    B, S, Di = x.shape
    N = A.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((B, Di, N), jnp.float32)
    cs = min(chunk, S)
    bd = min(block_d, Di)
    if S % cs != 0 or Di % bd != 0:
        raise ValueError(f"selective_scan_pallas tiling must divide the "
                         f"operand: seq {S} % chunk {cs}, d_inner {Di} % "
                         f"block {bd}")
    n_chunks, n_db = S // cs, Di // bd
    D2 = D[:, None]

    grid = (B, n_db, n_chunks)
    y, hT = pl.pallas_call(
        functools.partial(_kernel, cs=cs, n_chunks=n_chunks),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, cs, bd), lambda b, d, j: (b, j, d)),   # x
            pl.BlockSpec((1, cs, bd), lambda b, d, j: (b, j, d)),   # dt
            pl.BlockSpec((bd, N), lambda b, d, j: (d, 0)),          # A
            pl.BlockSpec((1, cs, N), lambda b, d, j: (b, j, 0)),    # B
            pl.BlockSpec((1, cs, N), lambda b, d, j: (b, j, 0)),    # C
            pl.BlockSpec((bd, 1), lambda b, d, j: (d, 0)),          # D
            pl.BlockSpec((1, bd, N), lambda b, d, j: (b, d, 0)),    # h0
        ],
        out_specs=[
            pl.BlockSpec((1, cs, bd), lambda b, d, j: (b, j, d)),   # y
            pl.BlockSpec((1, bd, N), lambda b, d, j: (b, d, 0)),    # h_final
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, Di), x.dtype),
            jax.ShapeDtypeStruct((B, Di, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bd, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, Bm, C, D2, h0)
    return y, hT
