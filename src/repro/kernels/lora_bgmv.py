"""Batched multi-LoRA (BGMV-style) Pallas kernels (TPU target).

Multi-tenant serving (S-LoRA / Punica layout): ONE frozen weight ``w`` is
shared by every request in a batch while each request selects its own
low-rank adapter pair out of a device-resident stack ``a: (n_slots, K, r)``,
``b: (n_slots, r, N)`` via an ``adapter_id``. This is what lets one decode
wave mix requests from different domains against the AdapterBank
(core/adapter_bank.py) instead of draining the engine once per domain.

Two shapes, two kernels:

- **Rows (decode)** — ``x: (M, K)`` with one ``adapter_id`` per row (BGMV:
  batched gather matrix-vector). Gathering ``(M, K, r)`` adapter copies per
  row would blow HBM traffic, so the kernel instead sweeps the slot dim with
  *masked accumulation*: per K step, ``u += (x masked to slot s) @ a[s]`` for
  each s — rows end up with exactly ``x_i @ a[id_i]`` because the row masks
  are disjoint, and every extra term is an exact 0. The rank-r intermediate
  and the dense accumulator live in VMEM scratch across the sequential K
  grid dim, so x/w are still read from HBM exactly once (the adapter stack
  is re-read per (i, j) block — it is rank-r sized, i.e. negligible).
- **Sequence (prefill)** — ``x: (B, S, K)`` with one ``adapter_id`` per
  sequence. Here the gather is free: the adapter id is *scalar-prefetched*
  and the BlockSpec index_map picks block ``a[ids[b]]`` directly, so each
  sequence's grid rows DMA only its own adapter (the gathered path).

Both produce bit-identical per-row results to the single-LoRA kernel run
with that row's adapter (the mixed-domain == per-domain serving parity the
engine tests assert). Dispatched from ops.py::lora_bgmv behind the usual
``xla|pallas|interpret`` switch. Block sizes follow lora_matmul.py and are
validated in interpret mode only — revalidate on real TPU hardware.
"""
# tracelint: kernel-op=lora_bgmv oracle=lora_bgmv
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _pad(x, axis, mult):
    p = (-x.shape[axis]) % mult
    if p == 0:
        return x
    w = [(0, 0)] * x.ndim
    w[axis] = (0, p)
    return jnp.pad(x, w)


# ---------------------------------------------------------------------------
# Rows variant (decode shape): one adapter_id per row, masked accumulation
# ---------------------------------------------------------------------------

def _rows_kernel(ids_ref, x_ref, w_ref, a_ref, b_ref, bias_ref, o_ref,
                 acc_ref, u_ref, *, nk: int, n_slots: int, scale: float,
                 has_bias: bool):
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        u_ref[...] = jnp.zeros_like(u_ref)

    x = x_ref[...].astype(jnp.float32)                     # (bm, bk)
    ids = ids_ref[...]                                     # (bm, 1) int32
    acc_ref[...] += jax.lax.dot(x, w_ref[...].astype(jnp.float32),
                                preferred_element_type=jnp.float32)
    for s in range(n_slots):                               # static, unrolled
        xs = jnp.where(ids == s, x, 0.0)
        u_ref[...] += jax.lax.dot(xs, a_ref[s].astype(jnp.float32),
                                  preferred_element_type=jnp.float32)

    @pl.when(kk == nk - 1)
    def _done():
        y = acc_ref[...]
        u = u_ref[...]                                     # (bm, rp): x_i @ a[id_i]
        for s in range(n_slots):
            us = jnp.where(ids == s, u, 0.0)
            y = y + scale * jax.lax.dot(us, b_ref[s].astype(jnp.float32),
                                        preferred_element_type=jnp.float32)
        if has_bias:
            y = y + bias_ref[0, :].astype(jnp.float32)[None, :]
        o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "scale", "block_m", "block_n", "block_k", "interpret"))
def lora_bgmv_rows_pallas(x, w, a, b, adapter_ids, scale: float = 1.0,
                          bias: Optional[jax.Array] = None, *,
                          block_m: int = 256, block_n: int = 512,
                          block_k: int = 512, interpret: bool = False):
    """x: (M, K); w: (K, N); a: (n_slots, K, r); b: (n_slots, r, N);
    adapter_ids: (M,) int32 in [0, n_slots). Returns (M, N) in x.dtype."""
    M, K = x.shape
    N = w.shape[1]
    n_slots, _, r = a.shape
    bm, bn, bk = min(block_m, M), min(block_n, N), min(block_k, K)
    rp = max(r + (-r) % 128, 128)                     # lane-align the rank dim

    xp, wp = _pad(_pad(x, 0, bm), 1, bk), _pad(_pad(w, 0, bk), 1, bn)
    ap = _pad(_pad(a, 1, bk), 2, rp)
    bp = _pad(_pad(b, 1, rp), 2, bn)
    idsp = _pad(adapter_ids.astype(jnp.int32)[:, None], 0, bm)
    has_bias = bias is not None
    biasp = _pad((bias if has_bias else jnp.zeros((N,), x.dtype))[None, :],
                 1, bn)
    Mp, Kp = xp.shape
    Np = wp.shape[1]
    nm, nn, nk = Mp // bm, Np // bn, Kp // bk

    out = pl.pallas_call(
        functools.partial(_rows_kernel, nk=nk, n_slots=n_slots, scale=scale,
                          has_bias=has_bias),
        grid=(nm, nn, nk),
        in_specs=[
            pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((n_slots, bk, rp), lambda i, j, k: (0, k, 0)),
            pl.BlockSpec((n_slots, rp, bn), lambda i, j, k: (0, 0, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32),
                        pltpu.VMEM((bm, rp), jnp.float32)],
        interpret=interpret,
    )(idsp, xp, wp, ap, bp, biasp)
    return out[:M, :N]


# ---------------------------------------------------------------------------
# Sequence variant (prefill shape): scalar-prefetched adapter gather
# ---------------------------------------------------------------------------

def _seq_kernel(ids_ref, x_ref, w_ref, a_ref, b_ref, bias_ref, o_ref,
                acc_ref, u_ref, *, nk: int, scale: float, has_bias: bool):
    # ids_ref was consumed by the index_maps; the a/b blocks arriving here
    # are already THIS sequence's adapter pair.
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        u_ref[...] = jnp.zeros_like(u_ref)

    x = x_ref[0].astype(jnp.float32)                       # (Sp, bk)
    acc_ref[...] += jax.lax.dot(x, w_ref[...].astype(jnp.float32),
                                preferred_element_type=jnp.float32)
    u_ref[...] += jax.lax.dot(x, a_ref[0].astype(jnp.float32),
                              preferred_element_type=jnp.float32)

    @pl.when(kk == nk - 1)
    def _done():
        y = acc_ref[...] + scale * jax.lax.dot(
            u_ref[...], b_ref[0].astype(jnp.float32),
            preferred_element_type=jnp.float32)
        if has_bias:
            y = y + bias_ref[0, :].astype(jnp.float32)[None, :]
        o_ref[0] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "scale", "block_n", "block_k", "interpret"))
def lora_bgmv_seq_pallas(x, w, a, b, adapter_ids, scale: float = 1.0,
                         bias: Optional[jax.Array] = None, *,
                         block_n: int = 512, block_k: int = 512,
                         interpret: bool = False):
    """x: (B, S, K); w: (K, N); a: (n_slots, K, r); b: (n_slots, r, N);
    adapter_ids: (B,) int32. Returns (B, S, N) in x.dtype.

    The whole (padded) sequence is one block — shrink S upstream (or extend
    to an S grid dim) if ``S * block_k`` floats outgrow VMEM.
    """
    B, S, K = x.shape
    N = w.shape[1]
    n_slots, _, r = a.shape
    bn, bk = min(block_n, N), min(block_k, K)
    rp = max(r + (-r) % 128, 128)

    xp = _pad(_pad(x, 1, 8), 2, bk)
    wp = _pad(_pad(w, 0, bk), 1, bn)
    ap = _pad(_pad(a, 1, bk), 2, rp)
    bp = _pad(_pad(b, 1, rp), 2, bn)
    has_bias = bias is not None
    biasp = _pad((bias if has_bias else jnp.zeros((N,), x.dtype))[None, :],
                 1, bn)
    Sp, Kp = xp.shape[1], xp.shape[2]
    Np = wp.shape[1]
    nn, nk = Np // bn, Kp // bk

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, nn, nk),
        in_specs=[
            pl.BlockSpec((1, Sp, bk), lambda bi, j, k, ids: (bi, 0, k)),
            pl.BlockSpec((bk, bn), lambda bi, j, k, ids: (k, j)),
            pl.BlockSpec((1, bk, rp), lambda bi, j, k, ids: (ids[bi], k, 0)),
            pl.BlockSpec((1, rp, bn), lambda bi, j, k, ids: (ids[bi], 0, j)),
            pl.BlockSpec((1, bn), lambda bi, j, k, ids: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, Sp, bn), lambda bi, j, k, ids: (bi, 0, j)),
        scratch_shapes=[pltpu.VMEM((Sp, bn), jnp.float32),
                        pltpu.VMEM((Sp, rp), jnp.float32)],
    )
    out = pl.pallas_call(
        functools.partial(_seq_kernel, nk=nk, scale=scale, has_bias=has_bias),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Sp, Np), x.dtype),
        interpret=interpret,
    )(adapter_ids.astype(jnp.int32), xp, wp, ap, bp, biasp)
    return out[:, :S, :N]
