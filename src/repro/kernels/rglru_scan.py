"""RG-LRU recurrence Pallas kernel (TPU target).

Same chunked-sequential structure as the selective scan: channel blocks on
the VPU lanes, diagonal f32 state (1, bw) in VMEM scratch persisting across
sequence chunks. Gate nonlinearities are fused into the scan step so the HBM
traffic per token is exactly x/r/i in + h out.

Grid: (B, num_channel_blocks, num_seq_chunks), chunks innermost.
"""
# tracelint: kernel-op=rglru oracle=rglru
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, r_ref, i_ref, a_ref, h0_ref, hs_ref, hT_ref, h_ref, *,
            cs: int, n_chunks: int, c: float):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        h_ref[...] = h0_ref[...].astype(jnp.float32)        # (1, bw)

    a_param = a_ref[:, 0].astype(jnp.float32)               # (bw,)
    log_a = -c * jax.nn.softplus(-a_param)[None, :]         # (1, bw)

    def step(t, h):
        xt = x_ref[0, t, :].astype(jnp.float32)[None, :]
        rt = jax.nn.sigmoid(r_ref[0, t, :].astype(jnp.float32))[None, :]
        it = jax.nn.sigmoid(i_ref[0, t, :].astype(jnp.float32))[None, :]
        a_t = jnp.exp(rt * log_a)
        h = a_t * h + jnp.sqrt(jnp.maximum(1.0 - a_t * a_t, 0.0)) * (it * xt)
        hs_ref[0, t, :] = h[0].astype(hs_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, cs, step, h_ref[...])
    h_ref[...] = h

    @pl.when(j == n_chunks - 1)
    def _done():
        hT_ref[...] = h


@functools.partial(jax.jit, static_argnames=("c", "chunk", "block_w", "interpret"))
def rglru_pallas(x, r_gate, i_gate, a_param, h0=None, *, c: float = 8.0,
                 chunk: int = 256, block_w: int = 512,
                 interpret: bool = False):
    """Shapes as kernels/ref.rglru. Returns (h_seq, h_final)."""
    B, S, W = x.shape
    if h0 is None:
        h0 = jnp.zeros((B, W), jnp.float32)
    cs = min(chunk, S)
    bw = min(block_w, W)
    if S % cs != 0 or W % bw != 0:
        raise ValueError(f"rglru_pallas tiling must divide the operand: "
                         f"seq {S} % chunk {cs}, width {W} % block {bw}")
    n_chunks = S // cs
    a2 = a_param[:, None]

    grid = (B, W // bw, n_chunks)
    hs, hT = pl.pallas_call(
        functools.partial(_kernel, cs=cs, n_chunks=n_chunks, c=c),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, cs, bw), lambda b, d, j: (b, j, d)),
            pl.BlockSpec((1, cs, bw), lambda b, d, j: (b, j, d)),
            pl.BlockSpec((1, cs, bw), lambda b, d, j: (b, j, d)),
            pl.BlockSpec((bw, 1), lambda b, d, j: (d, 0)),
            pl.BlockSpec((1, bw), lambda b, d, j: (b, d)),
        ],
        out_specs=[
            pl.BlockSpec((1, cs, bw), lambda b, d, j: (b, j, d)),
            pl.BlockSpec((1, bw), lambda b, d, j: (b, d)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, W), x.dtype),
            jax.ShapeDtypeStruct((B, W), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, bw), jnp.float32)],
        interpret=interpret,
    )(x, r_gate, i_gate, a2, h0)
    return hs, hT
