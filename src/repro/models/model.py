"""Public model API: spec / init / train forward / prefill / decode.

Params are split at the top level into ``backbone`` (frozen under the
paper's PEFT regime) and ``adapters`` (the tunable modules: prefix-KV
prompts, LoRA, state prompts, classification head). core/peft.py and
core/hfsl.py operate on this split.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.models import encdec
from repro.models.layers import (cross_entropy, embed, embed_spec, rmsnorm,
                                 rmsnorm_spec, unembed)
from repro.models.transformer import (adapter_stack_spec, cache_group_spec,
                                      paged_subs, rec_cache_part, stack_chunk,
                                      stack_decode, stack_seq, stack_spec,
                                      stack_verify)
from repro.sharding.rules import (ParamSpec, init_from_spec, serving_rules,
                                  shard, use_rules)

# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------


def backbone_spec(cfg: ModelConfig) -> dict:
    s: dict = {"embed": embed_spec(cfg.vocab_size, cfg.d_model, jnp.dtype(cfg.dtype)),
               "final_norm": rmsnorm_spec(cfg.d_model)}
    if cfg.family == "audio":
        s["encdec"] = encdec.encdec_stack_spec(cfg)
    else:
        s["layers"] = stack_spec(cfg)
    if not cfg.tie_embeddings:
        s["lm_head"] = embed_spec(cfg.vocab_size, cfg.d_model, jnp.dtype(cfg.dtype))
    return s


def adapter_spec(cfg: ModelConfig) -> dict:
    if cfg.family == "audio":
        a: dict = {"stack": encdec.encdec_adapter_spec(cfg)}
    else:
        a = {"stack": adapter_stack_spec(cfg)}
    if cfg.peft.head_dim_out:
        a["head"] = {
            "w": ParamSpec((cfg.d_model, cfg.peft.head_dim_out), jnp.float32,
                           ("fsdp", None), init="scaled"),
            "b": ParamSpec((cfg.peft.head_dim_out,), jnp.float32, (None,),
                           init="zeros"),
        }
    return a


def model_spec(cfg: ModelConfig) -> dict:
    return {"backbone": backbone_spec(cfg), "adapters": adapter_spec(cfg)}


def init(cfg: ModelConfig, key: jax.Array) -> dict:
    return init_from_spec(key, model_spec(cfg))


def cache_spec(cfg: ModelConfig, batch: int, seq_len: int, *,
               paged=None) -> dict:
    """``paged=(n_blocks, block_size)`` describes the paged layout for the
    eligible (full-window attention) sub-layers — see
    transformer.cache_group_spec / attention.cache_spec."""
    if cfg.family == "audio":
        return encdec.encdec_cache_spec(cfg, batch, seq_len)
    return cache_group_spec(cfg, batch, seq_len, paged=paged)


# ---------------------------------------------------------------------------
# Inputs
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    """ShapeDtypeStruct stand-ins for every model input (dry-run contract)."""
    B, S = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    tok = lambda s: jax.ShapeDtypeStruct((B, s), jnp.int32)
    if shape.kind == "decode":
        batch: dict = {"token": jax.ShapeDtypeStruct((B, 1), jnp.int32)}
    elif cfg.family == "vlm":
        n_vis = cfg.vlm.n_vis_tokens
        batch = {"tokens": tok(S - n_vis),
                 "vision_embeds": jax.ShapeDtypeStruct(
                     (B, n_vis, cfg.d_model), dt)}
    elif cfg.family == "audio":
        batch = {"tokens": tok(S),
                 "frames": jax.ShapeDtypeStruct(
                     (B, cfg.audio.n_audio_frames, cfg.d_model), dt)}
    else:
        batch = {"tokens": tok(S)}
    if shape.kind == "train" and "tokens" in batch:
        batch["labels"] = jax.ShapeDtypeStruct(batch["tokens"].shape, jnp.int32)
    return batch


def input_pspec_axes(cfg: ModelConfig, shape: InputShape) -> dict:
    """Logical axes per input leaf (same tree structure as input_specs)."""
    out = {}
    for k, v in input_specs(cfg, shape).items():
        out[k] = ("batch",) + ("seq",) * (len(v.shape) - 1) if v.ndim <= 2 \
            else ("batch", "seq", "d_model")
    return out


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------


def _embed_inputs(params: dict, batch: dict, cfg: ModelConfig):
    """Token (+modality) embedding. Returns (x, positions, label_offset)."""
    x = embed(params["backbone"]["embed"], batch["tokens"])
    x = shard(x, "batch", "seq", "d_model")
    n_vis = 0
    if cfg.family == "vlm" and "vision_embeds" in batch:
        vis = batch["vision_embeds"].astype(x.dtype)
        x = jnp.concatenate([vis, x], axis=1)
        n_vis = vis.shape[1]
    S = x.shape[1]
    return x, jnp.arange(S, dtype=jnp.int32), n_vis


def forward(params: dict, batch: dict, cfg: ModelConfig, *,
            mode: str = "train", remat: Optional[bool] = None,
            adapter_ids: Optional[jax.Array] = None) -> dict:
    """Full-sequence forward. Returns {'hidden', 'logits', 'aux'}.

    ``adapter_ids`` (B,) enables multi-tenant serving: adapter stack leaves
    carry an ``n_slots`` dim after the layer dim (the AdapterBank serving
    layout) and each batch row computes with its own domain's adapters.
    """
    remat = (mode == "train") if remat is None else remat
    adapters = params.get("adapters", {}).get("stack", {})
    if cfg.family == "audio":
        if adapter_ids is not None:
            raise NotImplementedError(
                "multi-tenant adapter_ids not supported for the audio "
                "encoder-decoder family")
        enc_out = encdec.encode(params["backbone"]["encdec"], adapters,
                                batch["frames"], cfg, remat=remat)
        tok_emb = embed(params["backbone"]["embed"], batch["tokens"])
        x, _ = encdec.decode_seq(params["backbone"]["encdec"], adapters,
                                 tok_emb, enc_out, cfg, remat=remat)
        aux = jnp.zeros((), jnp.float32)
    else:
        x, positions, _ = _embed_inputs(params, batch, cfg)
        x, _, aux = stack_seq(params["backbone"]["layers"], adapters, x, cfg,
                              positions=positions, remat=remat,
                              adapter_ids=adapter_ids)
    x = rmsnorm(params["backbone"]["final_norm"], x)
    head_tbl = params["backbone"].get("lm_head", params["backbone"]["embed"])
    logits = unembed(head_tbl, x)
    logits = shard(logits, "batch", "seq", "vocab")
    return {"hidden": x, "logits": logits, "aux": aux}


def lm_loss(params: dict, batch: dict, cfg: ModelConfig, *,
            remat: Optional[bool] = None) -> tuple[jax.Array, dict]:
    out = forward(params, batch, cfg, mode="train", remat=remat)
    logits = out["logits"]
    labels = batch["labels"]
    if logits.shape[1] != labels.shape[1]:          # vlm: loss on text only
        logits = logits[:, -labels.shape[1]:]
    loss = cross_entropy(logits, labels) + out["aux"]
    return loss, {"aux": out["aux"]}


def classify(params: dict, batch: dict, cfg: ModelConfig, *,
             remat: bool = False,
             adapter_ids: Optional[jax.Array] = None) -> jax.Array:
    """Paper case-study head: mean-pool hidden states -> adapter head logits.

    With ``adapter_ids`` the head is stacked (n_slots, d, out) and each row
    is scored by its own domain's head (mixed-domain accuracy in one call).
    """
    out = forward(params, batch, cfg, mode="eval", remat=remat,
                  adapter_ids=adapter_ids)
    pooled = jnp.mean(out["hidden"].astype(jnp.float32), axis=1)
    h = params["adapters"]["head"]
    if adapter_ids is not None:
        w = jnp.take(h["w"], adapter_ids, axis=0)      # (B, d, out)
        b = jnp.take(h["b"], adapter_ids, axis=0)      # (B, out)
        return jnp.einsum("bd,bdo->bo", pooled, w) + b
    return pooled @ h["w"] + h["b"]


def classify_loss(params: dict, batch: dict, cfg: ModelConfig) -> tuple[jax.Array, dict]:
    logits = classify(params, batch, cfg)
    loss = cross_entropy(logits, batch["label"])
    acc = jnp.mean((jnp.argmax(logits, -1) == batch["label"]).astype(jnp.float32))
    return loss, {"acc": acc}


# ---------------------------------------------------------------------------
# Serving: prefill + decode
# ---------------------------------------------------------------------------


def prefill(params: dict, batch: dict, cfg: ModelConfig,
            max_len: Optional[int] = None,
            adapter_ids: Optional[jax.Array] = None,
            prompt_lens: Optional[jax.Array] = None) -> tuple[jax.Array, dict]:
    """Run the prompt, build caches (padded to max_len for decoding into).

    ``prompt_lens`` (B,) serves a RAGGED wave: prompts are right-padded to
    a shared width and row b's valid tokens are ``tokens[b, :prompt_lens
    [b]]``. The caches come out bitwise identical to prefilling each row
    alone (per-row sentinel cache positions; identity-frozen recurrent
    state over padding), and the returned logits are each row's own
    last-token logits — decode then continues from per-row positions.

    Returns ((B, 1, vocab) last-token logits, caches)."""
    adapters = params.get("adapters", {}).get("stack", {})
    if cfg.family == "audio":
        if adapter_ids is not None:
            raise NotImplementedError(
                "multi-tenant adapter_ids not supported for the audio "
                "encoder-decoder family")
        enc_out = encdec.encode(params["backbone"]["encdec"], adapters,
                                batch["frames"], cfg)
        tok_emb = embed(params["backbone"]["embed"], batch["tokens"])
        lengths = prompt_lens
        x, caches = encdec.decode_seq(params["backbone"]["encdec"], adapters,
                                      tok_emb, enc_out, cfg, make_cache=True,
                                      cache_len=max_len, lengths=lengths)
    else:
        x, positions, n_vis = _embed_inputs(params, batch, cfg)
        lengths = None if prompt_lens is None else prompt_lens + n_vis
        x, caches, _ = stack_seq(params["backbone"]["layers"], adapters, x,
                                 cfg, positions=positions, make_cache=True,
                                 remat=False, cache_len=max_len,
                                 adapter_ids=adapter_ids, lengths=lengths)
    if lengths is None:
        x = x[:, -1:]
    else:                                  # per-row last VALID token
        B = x.shape[0]
        x = x[jnp.arange(B)[:, None], (lengths - 1)[:, None]]
    x = rmsnorm(params["backbone"]["final_norm"], x)
    head_tbl = params["backbone"].get("lm_head", params["backbone"]["embed"])
    return unembed(head_tbl, x), caches


def _scan_steps(params: dict, cfg: ModelConfig, steps: int, greedy: bool,
                tok, caches, pos, remaining, key, adapter_ids,
                with_state: bool = False):
    """Scan ``steps`` decode steps with per-row positions and retirement.

    The carry is (token, caches, pos (B,), remaining (B,), key); each step
    emits the carried token then computes the next. Rows with
    ``remaining <= 0`` are RETIRED: their cache writes are dropped, their
    position and carried token freeze, and their emitted tokens are
    padding the caller discards — so a retired row costs the step's FLOPs
    (counted by the engine as ``padded_tokens``) but cannot perturb its
    own or any other row's generation.

    ``with_state`` additionally emits the post-step recurrent cache parts
    (transformer.rec_cache_part) per step — the drafter in speculative
    decoding IS this scan: step j's snapshot is the drafter state after
    processing chunk offset j, the exact rollback points spec_decode
    needs. Returns (toks (B, steps), carry[, snaps (L, B, steps, ...)])."""

    def step(carry, _):
        tok, caches, pos, remaining, key = carry
        active = remaining > 0
        logits, caches = decode_step(params, tok, caches, pos, cfg,
                                     adapter_ids=adapter_ids, active=active)
        if greedy:
            nxt = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        else:
            key, sub = jax.random.split(key)
            nxt = jax.random.categorical(sub, logits[:, -1])[:, None]
        nxt = jnp.where(active[:, None], nxt.astype(jnp.int32), tok)
        pos = pos + active.astype(jnp.int32)
        remaining = remaining - active.astype(jnp.int32)
        ys = (tok, rec_cache_part(caches)) if with_state else tok
        return (nxt, caches, pos, remaining, key), ys

    carry, ys = jax.lax.scan(step, (tok, caches, pos, remaining, key),
                             None, length=steps)
    if with_state:
        toks, snaps = ys
        snaps = jax.tree.map(lambda s: jnp.moveaxis(s, 0, 2), snaps)
        return jnp.swapaxes(toks[..., 0], 0, 1), carry, snaps
    return jnp.swapaxes(ys[..., 0], 0, 1), carry           # (B, steps), carry


def _prefill_state(params: dict, batch: dict, cfg: ModelConfig, cap: int,
                   adapter_ids, prompt_lens):
    """Shared prefill -> (tok0, caches, pos0) decode-entry state.

    ``cap`` is the cache capacity in PROMPT+GENERATION tokens; the vlm
    vision prefix is added on top internally."""
    S = batch["tokens"].shape[1]
    n_vis = cfg.vlm.n_vis_tokens if cfg.family == "vlm" else 0
    logits, caches = prefill(params, batch, cfg, max_len=cap + n_vis,
                             adapter_ids=adapter_ids, prompt_lens=prompt_lens)
    tok0 = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    B = batch["tokens"].shape[0]
    if prompt_lens is None:
        pos0 = jnp.full((B,), S + n_vis, jnp.int32)
    else:
        pos0 = prompt_lens.astype(jnp.int32) + n_vis
    return tok0, caches, pos0


def _wave_rules(mesh):
    """(mesh, rules) context for the fused serving dispatches.

    With a mesh, every wave/refill/segment jit traces under
    rules.serving_rules(): the wave batch constrains onto `data`, head/FF
    dims onto `model` (the shard() calls inside attention/moe/ssm resolve
    against the active rule set). Without one this is a no-op context —
    the unsharded path is byte-identical to before.
    """
    return use_rules(mesh, serving_rules() if mesh is not None else None)


# tracelint: keys=cfg,cap,mesh
@functools.lru_cache(maxsize=64)
def _wave_prefill_fn(cfg: ModelConfig, cap: int, mesh=None):
    """Jitted ragged wave prefill: batch + prompt_lens -> decode state."""

    def impl(params, batch, prompt_lens, adapter_ids):
        with _wave_rules(mesh):
            return _prefill_state(params, batch, cfg, cap, adapter_ids,
                                  prompt_lens)

    return jax.jit(impl)


# tracelint: keys=cfg,cap,mesh
@functools.lru_cache(maxsize=64)
def _refill_fn(cfg: ModelConfig, cap: int, mesh=None):
    """Jitted in-wave slot refill: prefill fresh rows INTO a live wave.

    ``batch`` holds ONLY the rows being admitted (padded to a pow2 row
    count — usually far fewer than the wave width), and ``row_idx`` maps
    each to its wave slot; padding rows carry an out-of-range index and
    are dropped by the scatter. Every cache leaf has batch at dim 1
    ((L, B, ...) group stacking), so the merge is one row-scatter per
    leaf and the surviving rows' generation state stays bitwise
    untouched. This is what makes the drain TRUE continuous batching: a
    freed slot is re-prefilled between scan segments at the cost of a
    refill-sized prefill, not a wave-sized one."""

    def impl(params, batch, prompt_lens, row_idx, tok, caches, pos,
             adapter_ids):
        with _wave_rules(mesh):
            tok_n, caches_n, pos_n = _prefill_state(params, batch, cfg, cap,
                                                    adapter_ids, prompt_lens)

            def merge(old, new):
                return old.at[:, row_idx].set(new.astype(old.dtype),
                                              mode="drop")

            caches = jax.tree.map(merge, caches, caches_n)
            tok = tok.at[row_idx].set(tok_n, mode="drop")
            pos = pos.at[row_idx].set(pos_n, mode="drop")
            return tok, caches, pos

    return jax.jit(impl)


# tracelint: keys=cfg,steps,greedy,mesh
@functools.lru_cache(maxsize=64)
def _segment_fn(cfg: ModelConfig, steps: int, greedy: bool, mesh=None):
    """Jitted decode segment: ``steps`` scanned steps of a ragged wave.

    Segment lengths are powers of two (the engine buckets them), so the
    jit cache stays O(log max_budget) across any mix of per-row budgets
    instead of growing per distinct budget."""

    def impl(params, tok, caches, pos, remaining, key, adapter_ids):
        with _wave_rules(mesh):
            toks, (tok, caches, pos, remaining, key) = _scan_steps(
                params, cfg, steps, greedy, tok, caches, pos, remaining, key,
                adapter_ids)
            return toks, tok, caches, pos, remaining, key

    return jax.jit(impl)


# -- paged KV cache (block pool + per-row tables) ---------------------------

def _pool_commit(pool_sub: dict, dense_k, dense_v, tables, lens):
    """Scatter dense prefill K/V for B rows into the block pool.

    pool_sub: {'k','v'[,'table']} with pool leaves (L, nb, bs, Hkv, D);
    dense_k/v: (L, B, S_pad, ...) freshly prefilled rows; tables:
    (B, maxb) int32 block tables; lens: (B,) valid lengths. Token ``t``
    of row ``b`` lands at ``pool[:, tables[b, t//bs], t%bs]``; tokens at
    or beyond ``lens[b]`` route to the ``nb`` sentinel and drop (pad
    rows and prefix-HIT rows are excluded by an all-sentinel table /
    lens of 1 over a dummy prompt... their real state arrives via
    :func:`_paged_suffix_fn`). The values written are EXACTLY the dense
    prefill's — which is what keeps paged drains bit-identical."""
    nb, bs = pool_sub["k"].shape[1], pool_sub["k"].shape[2]
    S_pad = dense_k.shape[2]
    t_idx = jnp.arange(S_pad, dtype=jnp.int32)
    blk = jnp.where(t_idx[None, :] < lens[:, None],
                    tables[:, t_idx // bs], nb)            # (B, S_pad)
    off = jnp.broadcast_to(t_idx % bs, blk.shape)
    k = pool_sub["k"].at[:, blk, off].set(
        dense_k.astype(pool_sub["k"].dtype), mode="drop")
    v = pool_sub["v"].at[:, blk, off].set(
        dense_v.astype(pool_sub["v"].dtype), mode="drop")
    return k, v


# tracelint: keys=cfg,cap,bs,mesh
@functools.lru_cache(maxsize=64)
def _paged_prefill_fn(cfg: ModelConfig, cap: int, bs: int, mesh=None):
    """Jitted paged wave prefill: dense prefill -> pool commit.

    Runs the EXACT dense ragged prefill (same numerics, bit-for-bit),
    then scatters each eligible sub-layer's K/V into the device block
    pool through the host-built tables and swaps the sub-tree to the
    paged {'k','v','table'} layout (table broadcast over the scanned
    layer dim). Ineligible sub-layers (sliding window, recurrent) keep
    their dense cache untouched. ``pool`` is the persistent device pool
    tree {group: {sub: {'k','v'}}} for eligible subs."""
    subs = frozenset(paged_subs(cfg))

    def impl(params, batch, prompt_lens, tables, pool, adapter_ids):
        with _wave_rules(mesh):
            tok0, dense, pos0 = _prefill_state(params, batch, cfg, cap,
                                               adapter_ids, prompt_lens)
            tables = jnp.asarray(tables, jnp.int32)
            B, maxb = tables.shape
            lens = prompt_lens.astype(jnp.int32)
            caches = {}
            for g, grp in dense.items():
                caches[g] = {}
                for s, c in grp.items():
                    if (g, s) in subs:
                        k, v = _pool_commit(pool[g][s], c["k"], c["v"],
                                            tables, lens)
                        L = k.shape[0]
                        caches[g][s] = {
                            "k": k, "v": v,
                            "table": jnp.broadcast_to(tables[None],
                                                      (L, B, maxb))}
                    else:
                        caches[g][s] = c
            return tok0, caches, pos0

    return jax.jit(impl)


# tracelint: keys=cfg,cap,bs,mesh
@functools.lru_cache(maxsize=64)
def _paged_refill_fn(cfg: ModelConfig, cap: int, bs: int, mesh=None):
    """Jitted paged in-wave refill: admitted rows' K/V commit into the
    LIVE pool through their fresh tables; table rows scatter at
    ``row_idx``; ineligible leaves row-merge exactly like _refill_fn."""
    subs = frozenset(paged_subs(cfg))

    def impl(params, batch, prompt_lens, row_idx, tables_rows, tok, caches,
             pos, adapter_ids):
        with _wave_rules(mesh):
            tok_n, dense_n, pos_n = _prefill_state(params, batch, cfg, cap,
                                                   adapter_ids, prompt_lens)
            tables_rows = jnp.asarray(tables_rows, jnp.int32)
            Br, maxb = tables_rows.shape
            lens = prompt_lens.astype(jnp.int32)
            out = {}
            for g, grp in caches.items():
                out[g] = {}
                for s, old in grp.items():
                    if (g, s) in subs:
                        cn = dense_n[g][s]
                        k, v = _pool_commit(old, cn["k"], cn["v"],
                                            tables_rows, lens)
                        L = k.shape[0]
                        table = old["table"].at[:, row_idx].set(
                            jnp.broadcast_to(tables_rows[None],
                                             (L, Br, maxb)), mode="drop")
                        out[g][s] = {"k": k, "v": v, "table": table}
                    else:
                        out[g][s] = jax.tree.map(
                            lambda o, n: o.at[:, row_idx].set(
                                n.astype(o.dtype), mode="drop"),
                            old, dense_n[g][s])
            tok = tok.at[row_idx].set(tok_n, mode="drop")
            pos = pos.at[row_idx].set(pos_n, mode="drop")
            return tok, out, pos

    return jax.jit(impl)


# tracelint: keys=cfg,cap,bs,mesh
@functools.lru_cache(maxsize=64)
def _paged_suffix_fn(cfg: ModelConfig, cap: int, bs: int, mesh=None):
    """Jitted prefix-HIT admission: prefill ONLY the private suffix.

    A row whose prompt prefix matched cached blocks skips re-prefilling
    them — its table already maps the shared blocks (acquired, never
    written: copy-on-write), and this dispatch runs just the suffix
    chunk through the stack (transformer.stack_chunk), scattering
    suffix K/V into the row's private blocks and producing the row's
    first decode token + position. Requires a fully paged stack (the
    engine gates prefix sharing to such configs)."""

    def impl(params, tokens, suffix_lens, start, row_idx, tables_rows,
             tok, caches, pos, adapter_ids):
        with _wave_rules(mesh):
            adapters = params.get("adapters", {}).get("stack", {})
            Br, W = tokens.shape
            tables_rows = jnp.asarray(tables_rows, jnp.int32)
            maxb = tables_rows.shape[1]
            suffix_lens = suffix_lens.astype(jnp.int32)
            start = start.astype(jnp.int32)
            x = embed(params["backbone"]["embed"], tokens)
            x = shard(x, "batch", "seq", "d_model")
            valid = jnp.arange(W, dtype=jnp.int32)[None, :] \
                < suffix_lens[:, None]
            sub_caches = {
                g: {s: {"k": c["k"], "v": c["v"],
                        "table": jnp.broadcast_to(
                            tables_rows[None], (c["k"].shape[0], Br, maxb))}
                    for s, c in grp.items()}
                for g, grp in caches.items()}
            x, new_sub = stack_chunk(params["backbone"]["layers"], adapters,
                                     x, sub_caches, cfg, start=start,
                                     valid=valid, adapter_ids=adapter_ids)
            xl = x[jnp.arange(Br)[:, None],
                   jnp.maximum(suffix_lens - 1, 0)[:, None]]
            xl = rmsnorm(params["backbone"]["final_norm"], xl)
            head_tbl = params["backbone"].get("lm_head",
                                              params["backbone"]["embed"])
            logits = unembed(head_tbl, xl)
            tok_n = jnp.argmax(logits[:, -1], axis=-1)[:, None] \
                .astype(jnp.int32)
            pos_n = start + suffix_lens
            out = {}
            for g, grp in caches.items():
                out[g] = {}
                for s, old in grp.items():
                    ns = new_sub[g][s]
                    table = old["table"].at[:, row_idx].set(
                        jnp.broadcast_to(
                            tables_rows[None],
                            (old["k"].shape[0], Br, maxb)), mode="drop")
                    out[g][s] = {"k": ns["k"], "v": ns["v"], "table": table}
            tok = tok.at[row_idx].set(tok_n, mode="drop")
            pos = pos.at[row_idx].set(pos_n, mode="drop")
            return tok, out, pos

    return jax.jit(impl)


# Fused-fn cache-key invariant: every trace-shaping argument must appear
# in the lru key, and ONLY trace-shaping arguments (a spurious key arg
# would fork identical jits). The key tuples are machine-checked — each
# factory carries a ``# tracelint: keys=...`` declaration that
# repro.analysis rule R1 cross-checks against the signature AND against
# the names its jitted impl actually closes over. Non-obvious choices:
#   - _verify_fn deliberately excludes k: the chunk width T is a jit
#     input shape, so verify re-specializes per width for free.
#   - _segment_fn serves paged and dense waves through ONE key — jit
#     re-specializes on the cache TREE STRUCTURE, not the key tuple.
#   - Prompt/suffix widths and n_blocks/maxb are jit shapes everywhere,
#     never keys; mesh is a key everywhere (it picks the sharding rules).
# tests/test_spec_decode.py sweeps draft_k and asserts the caches stay
# bounded by exactly these key tuples.


# tracelint: keys=dcfg,k,mesh
@functools.lru_cache(maxsize=64)
def _draft_fn(dcfg: ModelConfig, k: int, mesh=None):
    """Jitted draft segment: k+1 scanned greedy drafter steps.

    The drafter processes [carry_tok, d1..dk] — one step more than it
    proposes — so its per-step state snapshots cover every rollback point
    a chunk can commit to (0..k accepted drafts). Returns (drafts (B, k),
    final drafter caches, per-step recurrent snapshots)."""

    from repro.core import spec_decode as sd                # lazy: no cycle

    def impl(dparams, tok, dcaches, pos, active):
        with _wave_rules(mesh):
            return sd.draft_chunk(dparams, dcfg, k, tok, dcaches, pos,
                                  active)

    return jax.jit(impl)


# tracelint: keys=cfg,mesh
@functools.lru_cache(maxsize=64)
def _verify_fn(cfg: ModelConfig, mesh=None):
    """Jitted one-pass chunk verify (see verify_step)."""

    def impl(params, tokens, caches, pos, active, adapter_ids):
        with _wave_rules(mesh):
            return verify_step(params, tokens, caches, pos, cfg,
                               adapter_ids=adapter_ids, active=active)

    return jax.jit(impl)


# tracelint: keys=cfg,dcfg,chunks,k,mesh
@functools.lru_cache(maxsize=64)
def _spec_segment_fn(cfg: ModelConfig, dcfg: ModelConfig, chunks: int,
                     k: int, mesh=None):
    """Jitted speculative decode segment: ``chunks`` scanned draft+verify
    chunks of a ragged wave (core/spec_decode.py::spec_segment). Chunk
    counts are pow2-bucketed by the engine, mirroring _segment_fn."""
    from repro.core import spec_decode as sd                # lazy: no cycle

    def impl(params, dparams, tok, caches, dcaches, pos, remaining,
             spec_rows, adapter_ids):
        with _wave_rules(mesh):
            return sd.spec_segment(params, dparams, cfg, dcfg, chunks, k,
                                   tok, caches, dcaches, pos, remaining,
                                   spec_rows, adapter_ids, mesh=mesh)

    return jax.jit(impl)


# tracelint: keys=cfg,gen,greedy,mesh
@functools.lru_cache(maxsize=64)
def _generate_fn(cfg: ModelConfig, gen: int, greedy: bool, mesh=None):
    """Build + jit the fused prefill-and-scan generator for one config.

    The whole request — prefill, ``gen`` decode steps, sampling — is ONE
    jitted computation: the decode loop is a ``jax.lax.scan`` whose carry
    (token, caches, per-row positions, key) stays on device, so XLA
    donates the cache buffers step-to-step and the host dispatches once
    per request instead of once per token. Cached per (cfg, gen, greedy);
    jit re-specializes per input shape as usual.
    """

    def impl(params: dict, batch: dict, key: jax.Array,
             adapter_ids, prompt_lens) -> jax.Array:
        with _wave_rules(mesh):
            S = batch["tokens"].shape[1]
            tok0, caches, pos0 = _prefill_state(params, batch, cfg, S + gen,
                                                adapter_ids, prompt_lens)
            B = batch["tokens"].shape[0]
            remaining = jnp.full((B,), gen, jnp.int32)
            toks, _ = _scan_steps(params, cfg, gen, greedy, tok0, caches,
                                  pos0, remaining, key, adapter_ids)
            return toks                                    # (B, gen)

    return jax.jit(impl)


def place_params(params: dict, cfg: ModelConfig, mesh,
                 rules: Optional[dict] = None) -> dict:
    """device_put a {backbone, adapters} tree onto ``mesh`` per the rule
    set (default serving_rules): weight dims shard where they divide, the
    rest replicate. Callers of the mesh-sharded serving path must place
    params before the first dispatch — jit rejects committed inputs whose
    placement disagrees with the computation's mesh."""
    from repro.sharding.rules import named_shardings
    spec = model_spec(cfg)
    spec = {k: spec[k] for k in params if k in spec}
    sh = named_shardings(spec, mesh, rules or serving_rules())
    return {**params, **jax.device_put({k: params[k] for k in sh}, sh)}


def generate_scan(params: dict, cfg: ModelConfig, prompts: jax.Array, *,
                  gen: int, extra_batch: Optional[dict] = None,
                  greedy: bool = True,
                  key: Optional[jax.Array] = None,
                  adapter_ids: Optional[jax.Array] = None,
                  prompt_lens=None, mesh=None) -> jax.Array:
    """Single-dispatch generation: prefill + scanned decode in one jit call.

    prompts: (B, S) int32. Returns (B, gen) generated tokens. Matches the
    legacy per-token loop (launch/serve.py::generate_loop) token-for-token:
    the first emitted token is the prefill argmax, subsequent tokens are
    argmax (greedy) or categorical samples drawn with the same per-step key
    splits.

    ``adapter_ids`` (B,) int32 serves a multi-tenant wave: params carry the
    AdapterBank stacked-adapter layout and row i generates with adapter
    slot ``adapter_ids[i]`` — token-for-token equal to serving row i alone
    with that slot's adapters.

    ``prompt_lens`` (B,) int32 serves a RAGGED wave: prompts are
    right-padded to the shared width and row b generates from position
    ``prompt_lens[b]`` — token-for-token equal to serving row b alone with
    its unpadded prompt.

    ``mesh`` traces the dispatch under rules.serving_rules() (batch over
    `data`, head/FF dims over `model`); params must already be placed on
    the mesh (:func:`place_params` / AdapterBank(mesh=...)).
    """
    batch = {"tokens": prompts, **(extra_batch or {})}
    if greedy or key is None:
        greedy, key = True, jax.random.PRNGKey(0)          # key unused
    ids = None if adapter_ids is None else \
        jnp.asarray(adapter_ids, jnp.int32)
    lens = None if prompt_lens is None else \
        jnp.asarray(prompt_lens, jnp.int32)
    return _generate_fn(cfg, int(gen), bool(greedy), mesh)(params, batch,
                                                           key, ids, lens)


def decode_step(params: dict, token: jax.Array, caches: dict,
                pos: jax.Array, cfg: ModelConfig,
                adapter_ids: Optional[jax.Array] = None,
                active: Optional[jax.Array] = None
                ) -> tuple[jax.Array, dict]:
    """One token. token: (B, 1) int32; pos: scalar or per-row (B,) int32
    (current position). ``active`` (B,) bool freezes retired rows' caches
    (ragged serving — see :func:`_scan_steps`)."""
    adapters = params.get("adapters", {}).get("stack", {})
    B = token.shape[0]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    x = embed(params["backbone"]["embed"], token)
    x = shard(x, "batch", "seq", "d_model")
    if cfg.family == "audio":
        x, caches = encdec.decode_step(params["backbone"]["encdec"], adapters,
                                       x, caches, cfg, pos=pos, active=active)
    else:
        x, caches = stack_decode(params["backbone"]["layers"], adapters, x,
                                 caches, cfg, pos=pos,
                                 adapter_ids=adapter_ids, active=active)
    x = rmsnorm(params["backbone"]["final_norm"], x)
    head_tbl = params["backbone"].get("lm_head", params["backbone"]["embed"])
    logits = unembed(head_tbl, x)
    return logits, caches


def verify_step(params: dict, tokens: jax.Array, caches: dict,
                pos: jax.Array, cfg: ModelConfig,
                adapter_ids: Optional[jax.Array] = None,
                active: Optional[jax.Array] = None):
    """Speculative verify: run the target model over a whole draft chunk in
    ONE pass against the live caches. tokens: (B, T) int32 — row b's chunk
    sits at positions ``pos[b] .. pos[b]+T-1``. Returns (logits (B, T,
    vocab), new_caches, rec_snaps); ``logits[:, j]`` is the distribution
    AFTER processing chunk offset j, so greedy targets are
    ``argmax(logits, -1)``. ``new_caches`` assumes full acceptance and
    ``rec_snaps`` carries per-step recurrent state — both feed
    core/spec_decode.py::rollback_caches, which is mandatory before the
    next chunk (see stack_verify)."""
    if cfg.family in ("audio", "vlm"):
        raise NotImplementedError(
            f"speculative verify not supported for family={cfg.family!r}")
    adapters = params.get("adapters", {}).get("stack", {})
    B = tokens.shape[0]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    x = embed(params["backbone"]["embed"], tokens)
    x = shard(x, "batch", "seq", "d_model")
    x, caches, snaps = stack_verify(params["backbone"]["layers"], adapters,
                                    x, caches, cfg, pos=pos,
                                    adapter_ids=adapter_ids, active=active)
    x = rmsnorm(params["backbone"]["final_norm"], x)
    head_tbl = params["backbone"].get("lm_head", params["backbone"]["embed"])
    return unembed(head_tbl, x), caches, snaps
