"""Mixture-of-Experts FFN with sort-based capacity dispatch.

TPU adaptation (DESIGN.md §2): instead of the GShard (tokens, E, C) one-hot
dispatch einsum — O(T·E·C) memory, hopeless at 1M tokens x 384 experts — we
sort token->expert assignments, scatter tokens into a per-expert capacity
buffer (E, C, d) sharded over the `model` axis, run the expert FFNs as one
batched einsum against the expert-sharded stacked weights, and gather back.
Under GSPMD this lowers to the expected all-to-all-style collectives between
the token (data) and expert (model) shardings.

Experts and the router stay frozen under the paper's PEFT regime (DESIGN.md
§5); adapters only touch attention/head elsewhere.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import mlp, mlp_spec
from repro.sharding.rules import ParamSpec, shard


def moe_spec(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    m = cfg.moe
    dt = jnp.dtype(cfg.dtype)
    s = {
        "router": ParamSpec((d, m.n_experts), jnp.float32, ("moe_fsdp", "experts"),
                            init="scaled"),
        # experts shard over `model` (training default; serving rules flip
        # to expert-parallel-over-`data`); the d_model dim shards over the
        # dedicated `moe_fsdp` axis. d_ff stays unsharded (would double-map).
        "gate": ParamSpec((m.n_experts, d, m.d_ff_expert), dt,
                          ("experts", "moe_fsdp", None), init="scaled"),
        "up": ParamSpec((m.n_experts, d, m.d_ff_expert), dt,
                        ("experts", "moe_fsdp", None), init="scaled"),
        "down": ParamSpec((m.n_experts, m.d_ff_expert, d), dt,
                          ("experts", None, "moe_fsdp"), init="scaled"),
    }
    if m.n_shared_experts:
        s["shared"] = mlp_spec(d, m.n_shared_experts * m.d_ff_expert, dt)
    return s


def capacity(n_tokens: int, cfg: ModelConfig, factor=None) -> int:
    m = cfg.moe
    f = m.capacity_factor if factor is None else factor
    if f <= 0:                           # no-drop mode: full fan-in capacity
        return n_tokens * m.top_k
    c = math.ceil(n_tokens * m.top_k / m.n_experts * f)
    return max(8, c + (-c) % 8)


def moe_apply(params: dict, x: jax.Array, cfg: ModelConfig,
              capacity_factor=None):
    """x: (B, S, d) or (B, d). Returns (y, aux_loss)."""
    m = cfg.moe
    orig_shape = x.shape
    d = x.shape[-1]
    xt = x.reshape(-1, d)                                   # (T, d)
    T = xt.shape[0]
    E, k = m.n_experts, m.top_k
    C = capacity(T, cfg, capacity_factor)

    logits = (xt.astype(jnp.float32) @ params["router"])    # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)                  # (T, k)
    top_w = top_w / jnp.maximum(jnp.sum(top_w, -1, keepdims=True), 1e-9)

    # ---- sort-based dispatch -------------------------------------------
    e_flat = top_e.reshape(-1)                              # (T*k,)
    w_flat = top_w.reshape(-1)
    tok_idx = jnp.arange(T * k, dtype=jnp.int32) // k
    order = jnp.argsort(e_flat, stable=True)
    se, st, sw = e_flat[order], tok_idx[order], w_flat[order]
    starts = jnp.searchsorted(se, jnp.arange(E, dtype=se.dtype))
    pos = jnp.arange(T * k, dtype=jnp.int32) - starts[se].astype(jnp.int32)
    keep = pos < C
    dest = jnp.where(keep, se.astype(jnp.int32) * C + pos, E * C)  # drop slot

    buf = jnp.zeros((E * C, d), x.dtype)
    buf = buf.at[dest].set(xt[st], mode="drop")
    buf = shard(buf.reshape(E, C, d), "act_experts", None, "d_model")

    # ---- expert FFN (stacked, expert-sharded) --------------------------
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, params["gate"])) \
        * jnp.einsum("ecd,edf->ecf", buf, params["up"])
    h = shard(h, "act_experts", None, None)
    out = jnp.einsum("ecf,efd->ecd", h, params["down"]).reshape(E * C, d)

    # ---- combine --------------------------------------------------------
    safe = jnp.minimum(dest, E * C - 1)
    yc = out[safe] * keep[:, None].astype(out.dtype)
    y = jnp.zeros((T, d), jnp.float32).at[st].add(
        sw[:, None] * yc.astype(jnp.float32))
    y = y.astype(x.dtype)

    if m.n_shared_experts:
        y = y + mlp(params["shared"], xt)

    # ---- load-balance aux loss (Switch-style) ---------------------------
    route_frac = jnp.zeros((E,), jnp.float32).at[e_flat].add(1.0) / (T * k)
    prob_frac = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(route_frac * prob_frac) * m.router_aux_loss

    return y.reshape(orig_shape), aux
