"""RecurrentGemma recurrent block (RG-LRU + temporal conv branch).

PEFT adaptation mirrors the SSM case: a learned initial recurrent state per
recurrent layer (``adapters['state0']``) is the prompt module; LoRA applies
to the in/out projections. The RG-LRU scan dispatches through kernels/ops.py.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops as kops
from repro.models.ssm import _conv1d_causal, _ragged_conv_tail
from repro.sharding.rules import ParamSpec, shard


def rglru_spec(cfg: ModelConfig) -> dict:
    d, w = cfg.d_model, cfg.lru_width
    dc = cfg.hybrid.conv_width
    dt = jnp.dtype(cfg.dtype)
    return {
        "in_x": ParamSpec((d, w), dt, ("fsdp", "lru"), init="scaled"),
        "in_y": ParamSpec((d, w), dt, ("fsdp", "lru"), init="scaled"),
        "conv_w": ParamSpec((dc, w), dt, ("conv", "lru"), init="scaled"),
        "conv_b": ParamSpec((w,), dt, ("lru",), init="zeros"),
        "w_r": ParamSpec((w, w), dt, ("lru", None), init="scaled"),
        "w_i": ParamSpec((w, w), dt, ("lru", None), init="scaled"),
        "a_param": ParamSpec((w,), jnp.float32, ("lru",), init="ones"),
        "out": ParamSpec((w, d), dt, ("lru", "fsdp"), init="scaled"),
    }


def rglru_state0_spec(cfg: ModelConfig, layers: int) -> ParamSpec:
    return ParamSpec((layers, cfg.lru_width), jnp.float32, (None, "lru"),
                     init="zeros")


def rglru_seq(params: dict, adapters: Optional[dict], x: jax.Array,
              cfg: ModelConfig, *, make_cache: bool = False,
              lengths: Optional[jax.Array] = None):
    """Full-sequence recurrent block. x: (B, S, d).

    ``lengths`` (B,) marks ragged right-padded rows: padded columns get
    ``r_gate = -1e9`` so ``a_t = exp(sigmoid(-1e9)·log_a) = 1`` exactly
    and the input branch ``sqrt(1 - a_t²)·… = 0`` — the recurrence is the
    identity there and ``hT`` is bitwise the state after row b's last
    valid token. The conv cache tail is gathered per row."""
    B, S, _ = x.shape
    xb = x @ params["in_x"]
    yb = jax.nn.gelu(x @ params["in_y"])
    xb = shard(xb, "batch", "attn_seq", "lru")
    xc = _conv1d_causal(xb, params["conv_w"], params["conv_b"])
    r_gate = xc @ params["w_r"]
    i_gate = xc @ params["w_i"]
    if lengths is not None:
        valid = jnp.arange(S)[None, :, None] < lengths[:, None, None]
        r_gate = jnp.where(valid, r_gate, jnp.asarray(-1e9, r_gate.dtype))
    h0 = None
    if adapters is not None and "state0" in adapters:
        s0 = adapters["state0"]
        # (W,) shared prompt, or (B, W) per-row (multi-tenant gather).
        # An UNgathered (n_slots, W) bank leaf with n_slots == B would
        # pass this guard undetected — serving stacked bank params without
        # adapter_ids is the caller's contract to uphold (the engine
        # enforces it at submit time).
        if s0.ndim == 2 and s0.shape[0] != B:
            raise ValueError(
                f"state0 {s0.shape} is neither a shared (W,) prompt nor a "
                f"per-row (B={B}, W) gather — stacked bank leaves must be "
                "gathered by adapter_ids before reaching the layer")
        h0 = s0 if s0.ndim == 2 else \
            jnp.broadcast_to(s0[None], (B, cfg.lru_width))
    hs, hT = kops.rglru(xc, r_gate, i_gate, params["a_param"], h0)
    out = (hs * yb) @ params["out"]
    out = shard(out, "batch", "seq", "d_model")
    cache = None
    if make_cache:
        K = cfg.hybrid.conv_width
        if lengths is None:
            conv_tail = xb[:, -(K - 1):] if S >= K - 1 else jnp.pad(
                xb, ((0, 0), (K - 1 - S, 0), (0, 0)))
        else:
            conv_tail = _ragged_conv_tail(xb, lengths, K)
        cache = {"h": hT, "conv": conv_tail}
    return out, cache


def rglru_decode(params: dict, adapters: Optional[dict], x: jax.Array,
                 cache: dict, cfg: ModelConfig):
    """Single-token step. cache: {'h': (B, W), 'conv': (B, K-1, W)}."""
    xb = x @ params["in_x"]                                # (B, 1, W)
    yb = jax.nn.gelu(x @ params["in_y"])
    conv_in = jnp.concatenate([cache["conv"], xb], axis=1)
    w = params["conv_w"]
    xc = jnp.einsum("bkd,kd->bd", conv_in.astype(jnp.float32),
                    w.astype(jnp.float32)) + params["conv_b"].astype(jnp.float32)
    xc = xc.astype(x.dtype)
    r_gate = xc @ params["w_r"]
    i_gate = xc @ params["w_i"]
    y, h = kops.rglru_step(xc, r_gate, i_gate, params["a_param"], cache["h"])
    out = (y[:, None] * yb) @ params["out"]
    return out, {"h": h, "conv": conv_in[:, 1:]}


def rglru_verify(params: dict, adapters: Optional[dict], x: jax.Array,
                 cache: dict, cfg: ModelConfig):
    """T chained single-token steps (bitwise ``rglru_decode`` math) emitting
    per-step state snapshots for speculative rollback. x: (B, T, d).
    Returns (y (B, T, d), snaps {'h': (B, T, W), 'conv': (B, T, K-1, W)})."""
    def step(c, xt):
        y, c = rglru_decode(params, adapters, xt, c, cfg)
        return c, (y, c)

    xs = jnp.swapaxes(x, 0, 1)[:, :, None]                 # (T, B, 1, d)
    _, (ys, snaps) = jax.lax.scan(step, cache, xs)
    y = jnp.swapaxes(ys[:, :, 0], 0, 1)                    # (B, T, d)
    return y, jax.tree.map(lambda s: jnp.swapaxes(s, 0, 1), snaps)


def rglru_cache_spec(cfg: ModelConfig, batch: int, layers: int) -> dict:
    w, K = cfg.lru_width, cfg.hybrid.conv_width
    return {
        "h": ParamSpec((layers, batch, w), jnp.float32,
                       (None, "batch", "lru"), init="zeros"),
        "conv": ParamSpec((layers, batch, K - 1, w), jnp.dtype(cfg.dtype),
                          (None, "batch", "conv", "lru"), init="zeros"),
    }
