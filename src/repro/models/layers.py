"""Common layers: norms, rotary embeddings, gated MLP, token embedding.

All modules follow the repo convention: ``<mod>_spec(cfg) -> ParamSpec tree``
and a pure ``<mod>(params, x, ...)`` apply function. Math accumulates in f32,
weights stay in the config dtype (bf16 by default).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.sharding.rules import ParamSpec, shard


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_spec(d: int) -> dict:
    return {"scale": ParamSpec((d,), jnp.float32, ("d_model",), init="ones")}


def rmsnorm(params: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * params["scale"]
    return out.astype(x.dtype)


def layernorm_spec(d: int) -> dict:
    return {"scale": ParamSpec((d,), jnp.float32, ("d_model",), init="ones"),
            "bias": ParamSpec((d,), jnp.float32, ("d_model",), init="zeros")}


def layernorm(params: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * params["scale"] + params["bias"]
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Apply rotary embedding. x: (..., S, H, D); positions: broadcastable (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freq = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freq          # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]                                # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU)
# ---------------------------------------------------------------------------

def mlp_spec(d: int, ff: int, dtype=jnp.bfloat16) -> dict:
    return {
        "gate": ParamSpec((d, ff), dtype, ("fsdp", "d_ff"), init="scaled"),
        "up": ParamSpec((d, ff), dtype, ("fsdp", "d_ff"), init="scaled"),
        "down": ParamSpec((ff, d), dtype, ("d_ff", "fsdp"), init="scaled"),
    }


def mlp(params: dict, x: jax.Array) -> jax.Array:
    h = jax.nn.silu(x @ params["gate"]) * (x @ params["up"])
    h = shard(h, *(("batch",) + ("attn_seq",) * (h.ndim - 2) + ("act_ff",))[-h.ndim:])
    return h @ params["down"]


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------

def embed_spec(vocab: int, d: int, dtype=jnp.bfloat16) -> dict:
    return {"table": ParamSpec((vocab, d), dtype, ("vocab", "fsdp"))}


def embed(params: dict, tokens: jax.Array) -> jax.Array:
    return jnp.take(params["table"], tokens, axis=0)


def unembed(params: dict, x: jax.Array) -> jax.Array:
    """Logits in f32 (tied or dedicated table of shape (vocab, d))."""
    return jnp.einsum("...d,vd->...v", x.astype(jnp.float32),
                      params["table"].astype(jnp.float32))


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
