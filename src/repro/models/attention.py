"""GQA attention with prefix-KV prompts, LoRA, sliding window, and KV caching.

The prefix-KV prompt module is the causal-LM analogue of the paper's
per-layer prompt modules (VPT-deep, §III-A/Fig 1): each layer owns ``n_p``
learned key/value slots, visible to every query, carrying no positional
encoding (position < 0 in the shared masking semantics).

Modes:
- train/prefill: full-sequence blocked flash attention (kernels/ops.py);
  prefill additionally returns the layer KV cache (rolling window buffer for
  the sliding variant).
- decode: single-token flash-decode attention against the cache
  (kernels/ops.py::flash_decode — split-KV Pallas kernel on TPU, blocked
  XLA online-softmax elsewhere); the cache is updated in place at ``pos``
  (or slot ``pos % window`` for sliding).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops as kops
from repro.models.layers import rope
from repro.sharding.rules import ParamSpec, shard


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def attn_spec(cfg: ModelConfig) -> dict:
    d, hd = cfg.d_model, cfg.head_dim_
    nh, nkv = cfg.n_heads, cfg.n_kv_heads
    dt = jnp.dtype(cfg.dtype)
    s = {
        "wq": ParamSpec((d, nh * hd), dt, ("fsdp", "heads"), init="scaled"),
        "wk": ParamSpec((d, nkv * hd), dt, ("fsdp", "kv_heads"), init="scaled"),
        "wv": ParamSpec((d, nkv * hd), dt, ("fsdp", "kv_heads"), init="scaled"),
        "wo": ParamSpec((nh * hd, d), dt, ("heads", "fsdp"), init="scaled"),
    }
    if cfg.qkv_bias:
        s["bq"] = ParamSpec((nh * hd,), dt, ("heads",), init="zeros")
        s["bk"] = ParamSpec((nkv * hd,), dt, ("kv_heads",), init="zeros")
        s["bv"] = ParamSpec((nkv * hd,), dt, ("kv_heads",), init="zeros")
    return s


def _proj(x, w, bias, lora, scale, adapter_ids=None):
    """Projection with optional LoRA branch (kernel-dispatched).

    Both training and inference traverse ops.lora_matmul: its custom VJP
    keeps the fused kernel usable under ``jax.grad`` (adapter grads only —
    the frozen ``dW`` is never formed), so the HFSL fine-tuning round and
    the decode path share one projection fast path.

    Multi-tenant serving passes ``adapter_ids`` (one slot id per batch row)
    with ``lora`` leaves carrying a leading ``n_slots`` dim (the
    AdapterBank layout); the projection then dispatches to the batched
    multi-LoRA kernel so one wave mixes adapters from different domains.
    """
    if lora is not None:
        shp = x.shape
        if adapter_ids is not None:
            return kops.lora_bgmv(x, w, lora["a"], lora["b"], adapter_ids,
                                  scale, bias)
        y = kops.lora_matmul(x.reshape(-1, shp[-1]), w, lora["a"], lora["b"],
                             scale, bias)
        return y.reshape(*shp[:-1], w.shape[-1])
    return kops.lora_matmul(x, w, bias=bias)


def _qkv(params, adapters, x, cfg: ModelConfig, kv_x=None, adapter_ids=None):
    """Compute q, k, v with LoRA; reshape to (B, S, H, D)."""
    nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    lora = (adapters or {}).get("lora", {})
    lscale = cfg.peft.lora_alpha / max(cfg.peft.lora_rank, 1)
    kv_in = x if kv_x is None else kv_x
    q = _proj(x, params["wq"], params.get("bq"), lora.get("q"), lscale,
              adapter_ids)
    k = _proj(kv_in, params["wk"], params.get("bk"), lora.get("k"), lscale,
              adapter_ids)
    v = _proj(kv_in, params["wv"], params.get("bv"), lora.get("v"), lscale,
              adapter_ids)
    B, S = x.shape[:2]
    Skv = kv_in.shape[1]
    return (q.reshape(B, S, nh, hd), k.reshape(B, Skv, nkv, hd),
            v.reshape(B, Skv, nkv, hd))


def _with_prefix(k, v, adapters, B, adapter_ids=None):
    """Prepend per-layer prefix-KV slots (broadcast over batch; with
    ``adapter_ids`` each row gathers its own domain's slots from the
    stacked (n_slots, n_p, Hkv, D) bank)."""
    pfx = (adapters or {}).get("prefix")
    if pfx is None:
        return k, v, 0
    if adapter_ids is not None:
        pk = jnp.take(pfx["k"], adapter_ids, axis=0).astype(k.dtype)
        pv = jnp.take(pfx["v"], adapter_ids, axis=0).astype(v.dtype)
    else:
        pk = jnp.broadcast_to(pfx["k"][None],
                              (B, *pfx["k"].shape)).astype(k.dtype)
        pv = jnp.broadcast_to(pfx["v"][None],
                              (B, *pfx["v"].shape)).astype(v.dtype)
    n_p = pk.shape[1]
    return jnp.concatenate([pk, k], 1), jnp.concatenate([pv, v], 1), n_p


# ---------------------------------------------------------------------------
# Full-sequence (train / prefill)
# ---------------------------------------------------------------------------

def attention_seq(params: dict, adapters: Optional[dict], x: jax.Array,
                  cfg: ModelConfig, *, positions: jax.Array,
                  causal: bool = True, window: int = 0,
                  kv_x: Optional[jax.Array] = None,
                  kv_positions: Optional[jax.Array] = None,
                  use_rope: bool = True,
                  make_cache: bool = False,
                  cache_len: Optional[int] = None,
                  adapter_ids: Optional[jax.Array] = None,
                  lengths: Optional[jax.Array] = None):
    """Returns (out (B,S,d_model), cache or None).

    ``lengths`` (B,) marks ragged right-padded rows: row b's valid tokens
    occupy columns ``[0, lengths[b])``. Because padding sits on the RIGHT
    and masking is causal, valid rows never see padded columns, so the
    full-sequence output for valid tokens is exact without per-row q
    positions. Raggedness only matters for the cache: padded columns'
    K/V land in the buffer, so the per-row cache ``pos`` leaf (B, L)
    carries the ``+1e9`` sentinel beyond each row's length — decode-side
    length-aware masking then keeps them invisible forever.
    """
    B, S = x.shape[:2]
    q, k, v = _qkv(params, adapters, x, cfg, kv_x, adapter_ids)
    kv_positions = positions if kv_positions is None else kv_positions
    if kv_x is None and use_rope:                          # self-attention: RoPE
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, kv_positions, cfg.rope_theta)
    q = shard(q, "batch", "attn_seq", "heads", "head_dim")
    k = shard(k, "batch", "attn_seq", "kv_heads", "head_dim")
    v = shard(v, "batch", "attn_seq", "kv_heads", "head_dim")

    kp, vp, n_p = _with_prefix(k, v, adapters, B, adapter_ids)
    kv_pos = jnp.concatenate(
        [jnp.full((n_p,), -1, jnp.int32), kv_positions.astype(jnp.int32)]) \
        if n_p else kv_positions.astype(jnp.int32)

    out = kops.flash_attention(
        q, kp, vp, q_pos=positions.astype(jnp.int32), kv_pos=kv_pos,
        window=window, causal=causal)
    out = out.reshape(B, S, -1)
    y = _proj(out, params["wo"], None,
              (adapters or {}).get("lora", {}).get("o"),
              cfg.peft.lora_alpha / max(cfg.peft.lora_rank, 1), adapter_ids)
    y = shard(y, "batch", "seq", "d_model")

    cache = None
    if make_cache:
        lens = jnp.full((B,), S, jnp.int32) if lengths is None \
            else lengths.astype(jnp.int32)
        if window and window > 0:                          # rolling buffer, W slots
            W = window
            # slot s holds the largest position p ≡ s (mod W) with
            # p <= len_b - 1 (the per-row rolling-buffer layout decode's
            # ``pos % W`` writes continue); p < 0 means the slot is empty.
            s_idx = jnp.arange(W, dtype=jnp.int32)
            p = s_idx[None, :] + W * ((lens[:, None] - 1 - s_idx[None, :])
                                      // W)                # (B, W)
            valid = p >= 0
            gidx = jnp.clip(p, 0, S - 1)[:, :, None, None]
            cache_k = jnp.where(valid[:, :, None, None],
                                jnp.take_along_axis(k, gidx, axis=1),
                                jnp.zeros((), k.dtype))
            cache_v = jnp.where(valid[:, :, None, None],
                                jnp.take_along_axis(v, gidx, axis=1),
                                jnp.zeros((), v.dtype))
            # +1e9 sentinel: empty slots must be *invisible* (negative would
            # mark them as always-visible prefix slots in the mask rules)
            cpos = jnp.where(valid, p, 10 ** 9)
            cache = {"k": cache_k, "v": cache_v, "pos": cpos}
        else:
            L = max(cache_len or S, S)
            pad = L - S
            base = jnp.pad(positions.astype(jnp.int32), (0, pad),
                           constant_values=10 ** 9)        # (L,)
            cpos = jnp.where(jnp.arange(L)[None, :] < lens[:, None],
                             base[None, :], 10 ** 9)       # (B, L)
            cache = {
                "k": jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))),
                "v": jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))),
                "pos": cpos,
            }
    return y, cache


# ---------------------------------------------------------------------------
# Decode (single token against cache)
# ---------------------------------------------------------------------------

def attention_decode(params: dict, adapters: Optional[dict], x: jax.Array,
                     cache: dict, cfg: ModelConfig, *, pos: jax.Array,
                     window: int = 0, cross: bool = False,
                     use_rope: bool = True,
                     adapter_ids: Optional[jax.Array] = None,
                     active: Optional[jax.Array] = None):
    """x: (B, 1, d). cache: {'k','v','pos'} (+ static for cross). Returns
    (out, new_cache). ``adapter_ids`` selects each row's adapter from
    stacked (n_slots, ...) adapter leaves (multi-tenant serving).

    ``pos`` is a scalar or per-row (B,) position: each row writes its own
    cache slot ``pos[b]`` (``pos[b] % window`` for sliding), so one wave
    mixes rows at different sequence positions (ragged continuous
    batching). ``active`` (B,) bool retires rows in place: an inactive
    row's cache write is routed out of bounds and dropped, freezing its
    cache while the wave keeps decoding other rows.

    A PAGED cache (``{'k','v'}`` block pools (n_blocks, bs, Hkv, D) +
    ``'table'`` (B, max_blocks)) is detected by its ``table`` leaf: the
    slot scatter becomes a block-table-indirected write
    ``pos -> (table[b, pos // bs], pos % bs)`` and attention dispatches
    to :func:`ops.flash_decode_paged`. Distinct live rows always write
    distinct blocks (the allocator never shares a row's TAIL block), so
    the batched scatter stays race-free; inactive and pad rows route to
    the ``n_blocks`` sentinel and are dropped."""
    B = x.shape[0]
    nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    lora = (adapters or {}).get("lora", {})
    lscale = cfg.peft.lora_alpha / max(cfg.peft.lora_rank, 1)
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))

    q = _proj(x, params["wq"], params.get("bq"), lora.get("q"), lscale,
              adapter_ids)
    q = q.reshape(B, 1, nh, hd)

    if cross:
        k, v = cache["k"], cache["v"]
        kv_pos = cache["pos"]
        new_cache = cache
    else:
        if use_rope:
            q = rope(q, pos[:, None], cfg.rope_theta)
        k1 = _proj(x, params["wk"], params.get("bk"), lora.get("k"), lscale,
                   adapter_ids)
        v1 = _proj(x, params["wv"], params.get("bv"), lora.get("v"), lscale,
                   adapter_ids)
        k1 = k1.reshape(B, 1, nkv, hd)
        if use_rope:
            k1 = rope(k1, pos[:, None], cfg.rope_theta)
        v1 = v1.reshape(B, 1, nkv, hd)
        if "table" in cache:             # paged: block-table indirected write
            table = cache["table"]
            nb, bs = cache["k"].shape[0], cache["k"].shape[1]
            blk = jnp.take_along_axis(table, (pos // bs)[:, None],
                                      axis=1)[:, 0]
            if active is not None:       # retired rows: write out of bounds
                blk = jnp.where(active, blk, nb)
            off = pos % bs
            k = cache["k"].at[blk, off].set(
                k1[:, 0].astype(cache["k"].dtype), mode="drop")
            v = cache["v"].at[blk, off].set(
                v1[:, 0].astype(cache["v"].dtype), mode="drop")
            new_cache = {"k": k, "v": v, "table": table}
            kv_pos = None                # implicit: slot index == position
        else:
            T = cache["k"].shape[1]
            slot = (pos % window) if window and window > 0 else pos
            if active is not None:       # retired rows: write out of bounds
                slot = jnp.where(active, slot, T)
            rows = jnp.arange(B)
            k = cache["k"].at[rows, slot].set(
                k1[:, 0].astype(cache["k"].dtype), mode="drop")
            v = cache["v"].at[rows, slot].set(
                v1[:, 0].astype(cache["v"].dtype), mode="drop")
            kv_pos = cache["pos"].at[rows, slot].set(pos, mode="drop")
            new_cache = {"k": k, "v": v, "pos": kv_pos}

    if "table" not in cache:
        k = shard(k, "batch", "kv_seq", "kv_heads", "head_dim")
        v = shard(v, "batch", "kv_seq", "kv_heads", "head_dim")
    else:
        k = shard(k, "kv_blocks", None, "kv_heads", "head_dim")
        v = shard(v, "kv_blocks", None, "kv_heads", "head_dim")

    # Single-token attention is kernel-dispatched: the XLA path keeps the
    # separate prefix bank + online-softmax merge (§Perf d2 — concatenating
    # prefix slots onto the seq-sharded cache forces a per-layer all-gather),
    # the Pallas path is the split-KV flash-decode kernel
    # (kernels/flash_decode.py) with length-aware sentinel masking.
    pfx = (adapters or {}).get("prefix") if not cross else None
    pfx_k = pfx_v = None
    if pfx is not None:
        if adapter_ids is not None:                # per-row domain prefix
            pfx_k = jnp.take(pfx["k"], adapter_ids, axis=0)
            pfx_v = jnp.take(pfx["v"], adapter_ids, axis=0)
        else:
            pfx_k, pfx_v = pfx["k"], pfx["v"]
    if "table" in cache:
        o = kops.flash_decode_paged(
            q[:, 0], k, v, cache["table"], q_pos=pos.astype(jnp.int32),
            prefix_k=pfx_k, prefix_v=pfx_v)
    else:
        o = kops.flash_decode(
            q[:, 0], k, v, q_pos=pos.astype(jnp.int32),
            kv_pos=kv_pos.astype(jnp.int32),
            prefix_k=pfx_k, prefix_v=pfx_v,
            window=0 if cross else window, causal=not cross)
    o = o.reshape(B, 1, nh * hd).astype(x.dtype)
    y = _proj(o, params["wo"], None, lora.get("o"), lscale, adapter_ids)
    return y, new_cache


def chunk_slots(qpos: jax.Array, window: int, S: int,
                active: Optional[jax.Array] = None) -> jax.Array:
    """Per-row cache slots a verify chunk writes (and rollback restores).

    qpos: (B, T) absolute positions. Sliding-window caches write slot
    ``pos % window``, full caches slot ``pos``; inactive rows are routed
    out of bounds (``S``) so their scatters are dropped."""
    slot = (qpos % window) if window and window > 0 else qpos
    if active is not None:
        slot = jnp.where(active[:, None], slot, S)
    return slot


def attention_verify(params: dict, adapters: Optional[dict], x: jax.Array,
                     cache: dict, cfg: ModelConfig, *, pos: jax.Array,
                     window: int = 0, use_rope: bool = True,
                     adapter_ids: Optional[jax.Array] = None,
                     active: Optional[jax.Array] = None):
    """Speculative verify: a length-T token chunk per row against the LIVE
    cache. x: (B, T, d) — row b's chunk occupies positions
    ``pos[b] .. pos[b]+T-1``. Returns (out (B, T, d), new_cache).

    The chunk's K/V are scattered into the cache first (per-row slots,
    exactly the footprint of T consecutive ``attention_decode`` writes),
    then every chunk query attends the updated cache under the shared
    masking semantics (kernels/ref.py): prefix slots (pos < 0) always
    visible, empty slots (+1e9 sentinel) never, sliding window per query
    position. T is tiny (draft_k + 1), so the attention itself is plain
    jnp GQA — ``flash_decode`` takes one query per row and
    ``flash_attention``'s q_pos is per-block, not per-row; a real-TPU
    verify kernel is a recorded ROADMAP follow-up.

    Rejected draft positions leave K/V writes behind: callers must restore
    the overwritten slots (core/spec_decode.py::rollback_caches) before
    the next chunk. Inactive rows' writes are dropped out of bounds, so
    retired rows' caches stay frozen through a speculative wave."""
    B, T = x.shape[:2]
    nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    lora = (adapters or {}).get("lora", {})
    lscale = cfg.peft.lora_alpha / max(cfg.peft.lora_rank, 1)
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    qpos = pos[:, None] + jnp.arange(T, dtype=jnp.int32)[None, :]   # (B, T)

    q = _proj(x, params["wq"], params.get("bq"), lora.get("q"), lscale,
              adapter_ids).reshape(B, T, nh, hd)
    k1 = _proj(x, params["wk"], params.get("bk"), lora.get("k"), lscale,
               adapter_ids).reshape(B, T, nkv, hd)
    v1 = _proj(x, params["wv"], params.get("bv"), lora.get("v"), lscale,
               adapter_ids).reshape(B, T, nkv, hd)
    if use_rope:
        q = rope(q, qpos, cfg.rope_theta)
        k1 = rope(k1, qpos, cfg.rope_theta)

    S = cache["k"].shape[1]
    slot = chunk_slots(qpos, window, S, active)
    rows = jnp.arange(B)[:, None]
    k = cache["k"].at[rows, slot].set(k1.astype(cache["k"].dtype),
                                      mode="drop")
    v = cache["v"].at[rows, slot].set(v1.astype(cache["v"].dtype),
                                      mode="drop")
    kv_pos = cache["pos"].at[rows, slot].set(qpos, mode="drop")
    new_cache = {"k": k, "v": v, "pos": kv_pos}

    k = shard(k, "batch", "kv_seq", "kv_heads", "head_dim")
    v = shard(v, "batch", "kv_seq", "kv_heads", "head_dim")
    kp, vp, n_p = _with_prefix(k, v, adapters, B, adapter_ids)
    if n_p:
        kv_pos = jnp.concatenate(
            [jnp.full((B, n_p), -1, jnp.int32), kv_pos], axis=1)

    vis = kv_pos[:, None, :] <= qpos[:, :, None]            # causal (B, T, S)
    if window and window > 0:
        vis &= (qpos[:, :, None] - kv_pos[:, None, :]) < window
    vis |= kv_pos[:, None, :] < 0                           # prefix slots
    g = nh // nkv
    qf = q.reshape(B, T, nkv, g, hd).astype(jnp.float32)
    scores = jnp.einsum("btngd,bsnd->bngts", qf,
                        kp.astype(jnp.float32)) * (hd ** -0.5)
    scores = jnp.where(vis[:, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bngts,bsnd->btngd", probs, vp.astype(jnp.float32))
    o = o.reshape(B, T, nh * hd).astype(x.dtype)
    y = _proj(o, params["wo"], None, lora.get("o"), lscale, adapter_ids)
    return y, new_cache


def attention_chunk_paged(params: dict, adapters: Optional[dict],
                          x: jax.Array, cache: dict, cfg: ModelConfig, *,
                          start: jax.Array, valid: jax.Array,
                          adapter_ids: Optional[jax.Array] = None):
    """Chunked continuation prefill against a PAGED cache (prefix sharing).

    A prefix-cache hit row skips re-prefilling its shared blocks: only
    the private SUFFIX runs through the stack, as a length-W chunk per
    row. x: (B, W, d) — row b's chunk occupies absolute positions
    ``start[b] .. start[b]+W-1``; ``valid`` (B, W) masks real suffix
    tokens (right padding). The chunk's K/V scatter into the row's
    private blocks through the table (invalid positions route to the
    ``n_blocks`` sentinel and drop), then every chunk query attends the
    updated pool gathered through the table — shared prefix blocks are
    READ here but never written, which is the copy-on-write guarantee.
    W is a suffix (typically < block_size tokens past the shared
    prefix), so the attention is plain jnp GQA like
    :func:`attention_verify`. Returns (out (B, W, d), new_cache)."""
    B, W = x.shape[:2]
    nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    lora = (adapters or {}).get("lora", {})
    lscale = cfg.peft.lora_alpha / max(cfg.peft.lora_rank, 1)
    start = jnp.broadcast_to(jnp.asarray(start, jnp.int32), (B,))
    qpos = start[:, None] + jnp.arange(W, dtype=jnp.int32)[None, :]  # (B, W)

    q = _proj(x, params["wq"], params.get("bq"), lora.get("q"), lscale,
              adapter_ids).reshape(B, W, nh, hd)
    k1 = _proj(x, params["wk"], params.get("bk"), lora.get("k"), lscale,
               adapter_ids).reshape(B, W, nkv, hd)
    v1 = _proj(x, params["wv"], params.get("bv"), lora.get("v"), lscale,
               adapter_ids).reshape(B, W, nkv, hd)
    q = rope(q, qpos, cfg.rope_theta)
    k1 = rope(k1, qpos, cfg.rope_theta)

    table = cache["table"]
    nb, bs = cache["k"].shape[0], cache["k"].shape[1]
    blk = jnp.take_along_axis(table, jnp.clip(qpos // bs, 0,
                                              table.shape[1] - 1), axis=1)
    blk = jnp.where(valid, blk, nb)               # pad tokens: dropped
    off = qpos % bs
    pool_k = cache["k"].at[blk, off].set(k1.astype(cache["k"].dtype),
                                         mode="drop")
    pool_v = cache["v"].at[blk, off].set(v1.astype(cache["v"].dtype),
                                         mode="drop")
    new_cache = {"k": pool_k, "v": pool_v, "table": table}

    tbl = jnp.clip(table, 0, nb - 1)
    kg = pool_k[tbl].reshape(B, -1, nkv, hd)      # (B, cap, Hkv, D)
    vg = pool_v[tbl].reshape(B, -1, nkv, hd)
    kv_pos = jnp.broadcast_to(
        jnp.arange(kg.shape[1], dtype=jnp.int32)[None], (B, kg.shape[1]))
    kp, vp, n_p = _with_prefix(kg, vg, adapters, B, adapter_ids)
    if n_p:
        kv_pos = jnp.concatenate(
            [jnp.full((B, n_p), -1, jnp.int32), kv_pos], axis=1)

    vis = kv_pos[:, None, :] <= qpos[:, :, None]  # causal (B, W, cap)
    vis |= kv_pos[:, None, :] < 0                 # prefix slots
    g = nh // nkv
    qf = q.reshape(B, W, nkv, g, hd).astype(jnp.float32)
    scores = jnp.einsum("btngd,bsnd->bngts", qf,
                        kp.astype(jnp.float32)) * (hd ** -0.5)
    scores = jnp.where(vis[:, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bngts,bsnd->btngd", probs, vp.astype(jnp.float32))
    o = o.reshape(B, W, nh * hd).astype(x.dtype)
    y = _proj(o, params["wo"], None, lora.get("o"), lscale, adapter_ids)
    return y, new_cache


def cache_spec(cfg: ModelConfig, batch: int, seq_len: int, *,
               window: int = 0, layers: Optional[int] = None,
               paged: Optional[tuple] = None) -> dict:
    """ParamSpec tree for a (stacked-over-layers) KV cache.

    The sliding-window cache is a rolling buffer of exactly ``window``
    slots — what the prefill path actually builds — regardless of how
    ``seq_len`` compares to the window. ``pos`` is per-row (B, S): each
    batch row tracks its own written slots (ragged serving).

    ``paged=(n_blocks, block_size)`` describes the PAGED layout instead
    (full-window layers only): a layer-stacked device block pool
    ``(L, n_blocks, bs, Hkv, D)`` shared by every row — sharded over
    ``kv_blocks`` (the data axis) instead of per-row ``kv_seq`` — plus
    per-row block tables ``(L, B, ceil(seq_len/bs))``. There is no
    ``pos`` plane: a table slot ``j`` holds positions ``[j*bs,(j+1)*bs)``
    by construction, so visibility is purely causal."""
    L = layers if layers is not None else cfg.n_layers
    nkv, hd = cfg.n_kv_heads, cfg.head_dim_
    S = window if window and window > 0 else seq_len
    dt = jnp.dtype(cfg.dtype)
    if paged is not None and not (window and window > 0):
        nb, bs = paged
        maxb = -(-seq_len // bs)
        return {
            "k": ParamSpec((L, nb, bs, nkv, hd), dt,
                           (None, "kv_blocks", None, "kv_heads", "head_dim"),
                           init="zeros"),
            "v": ParamSpec((L, nb, bs, nkv, hd), dt,
                           (None, "kv_blocks", None, "kv_heads", "head_dim"),
                           init="zeros"),
            "table": ParamSpec((L, batch, maxb), jnp.int32,
                               (None, "batch", None), init="zeros"),
        }
    return {
        "k": ParamSpec((L, batch, S, nkv, hd), dt,
                       (None, "batch", "kv_seq", "kv_heads", "head_dim"),
                       init="zeros"),
        "v": ParamSpec((L, batch, S, nkv, hd), dt,
                       (None, "batch", "kv_seq", "kv_heads", "head_dim"),
                       init="zeros"),
        "pos": ParamSpec((L, batch, S), jnp.int32, (None, "batch", "kv_seq"),
                         init="zeros"),
    }
