"""Whisper-style encoder-decoder backbone.

Modality carve-out (spec): the mel-spectrogram + conv feature extractor is a
STUB — `input_specs()` supplies precomputed frame embeddings of shape
(B, n_frames, d_model). This module implements the transformer that consumes
them: a non-causal encoder stack and a causal decoder stack with per-layer
cross attention. Learned absolute positional embeddings (whisper uses
sinusoidal/learned, not RoPE).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models.layers import layernorm, layernorm_spec, mlp, mlp_spec
from repro.models.transformer import (_stack, sublayer_adapter_spec)
from repro.sharding.rules import ParamSpec, shard


def enc_layer_spec(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    return {"ln1": layernorm_spec(d), "attn": attn_mod.attn_spec(cfg),
            "ln2": layernorm_spec(d), "mlp": mlp_spec(d, cfg.d_ff, jnp.dtype(cfg.dtype))}


def dec_layer_spec(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    return {"ln1": layernorm_spec(d), "self": attn_mod.attn_spec(cfg),
            "ln2": layernorm_spec(d), "cross": attn_mod.attn_spec(cfg),
            "ln3": layernorm_spec(d), "mlp": mlp_spec(d, cfg.d_ff, jnp.dtype(cfg.dtype))}


def encdec_stack_spec(cfg: ModelConfig) -> dict:
    a = cfg.audio
    d = cfg.d_model
    return {
        "enc_pos": ParamSpec((a.n_audio_frames, d), jnp.dtype(cfg.dtype),
                             ("frames", "fsdp")),
        # whisper's native decoder context is 448; sized to the largest
        # assigned prefill shape so the distribution config lowers
        # (semantic mismatch noted in DESIGN.md §6)
        "dec_pos": ParamSpec((32768, d), jnp.dtype(cfg.dtype), (None, "fsdp")),
        "enc": _stack(enc_layer_spec(cfg), a.n_enc_layers),
        "dec": _stack(dec_layer_spec(cfg), cfg.n_layers),
        "enc_ln": layernorm_spec(d),
    }


def encdec_adapter_spec(cfg: ModelConfig) -> dict:
    return {
        "enc": _stack(sublayer_adapter_spec(cfg, "attn"), cfg.audio.n_enc_layers),
        "dec": _stack(sublayer_adapter_spec(cfg, "attn"), cfg.n_layers),
    }


def encode(params: dict, adapters: dict, frames: jax.Array, cfg: ModelConfig,
           remat: bool = False) -> jax.Array:
    """frames: (B, F, d_model) stub embeddings -> encoder states (B, F, d)."""
    F = frames.shape[1]
    x = frames + params["enc_pos"][:F][None].astype(frames.dtype)
    pos = jnp.arange(F, dtype=jnp.int32)

    def body(x, layer):
        lp, la = layer
        h, _ = attn_mod.attention_seq(lp["attn"], la, layernorm(lp["ln1"], x),
                                      cfg, positions=pos, causal=False,
                                      use_rope=False)
        x = x + h
        x = x + mlp(lp["mlp"], layernorm(lp["ln2"], x))
        return x, None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, (params["enc"], adapters.get("enc", {})))
    return layernorm(params["enc_ln"], x)


def _dec_positions(S: int):
    return jnp.arange(S, dtype=jnp.int32)


def decode_seq(params: dict, adapters: dict, tok_emb: jax.Array,
               enc_out: jax.Array, cfg: ModelConfig, *,
               make_cache: bool = False, remat: bool = False,
               cache_len=None, lengths=None):
    """Teacher-forced decoder pass. tok_emb: (B, S, d). Returns (x, caches).

    ``lengths`` (B,) marks ragged right-padded decoder prompts: the self
    cache gets per-row sentinel positions beyond each row's length (the
    cross cache is static per request and unaffected)."""
    B, S, _ = tok_emb.shape
    F = enc_out.shape[1]
    x = tok_emb + params["dec_pos"][:S][None].astype(tok_emb.dtype)
    pos = _dec_positions(S)
    enc_pos = jnp.arange(F, dtype=jnp.int32)

    def body(x, layer):
        lp, la = layer
        h, self_cache = attn_mod.attention_seq(
            lp["self"], la, layernorm(lp["ln1"], x), cfg, positions=pos,
            causal=True, use_rope=False, make_cache=make_cache,
            cache_len=cache_len, lengths=lengths)
        x = x + h
        h, _ = attn_mod.attention_seq(
            lp["cross"], None, layernorm(lp["ln2"], x), cfg, positions=pos,
            kv_x=enc_out, kv_positions=enc_pos, causal=False, use_rope=False)
        x = x + h
        x = x + mlp(lp["mlp"], layernorm(lp["ln3"], x))
        cache = None
        if make_cache:
            # cross-attention KV is static per request: cache it per layer
            # (pos is replicated per row so every cache leaf is
            # batch-addressable — the engine's in-wave refill merges caches
            # row-wise)
            from repro.models.attention import _qkv
            _, ck, cv = _qkv(lp["cross"], None, enc_out, cfg, enc_out)
            cache = {"self": self_cache,
                     "cross": {"k": ck, "v": cv,
                               "pos": jnp.broadcast_to(enc_pos, (B, F))}}
        return x, cache

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, caches = jax.lax.scan(body, x, (params["dec"], adapters.get("dec", {})))
    return x, (caches if make_cache else None)


def decode_step(params: dict, adapters: dict, tok_emb: jax.Array,
                caches: dict, cfg: ModelConfig, *, pos: jax.Array,
                active=None):
    """One decoder token. tok_emb: (B, 1, d). ``pos`` scalar or (B,)."""
    B = tok_emb.shape[0]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    x = tok_emb + jnp.take(params["dec_pos"], pos,
                           axis=0)[:, None].astype(tok_emb.dtype)

    def body(x, layer):
        lp, la, lc = layer
        h, self_cache = attn_mod.attention_decode(
            lp["self"], la, layernorm(lp["ln1"], x), lc["self"], cfg, pos=pos,
            use_rope=False, active=active)
        x = x + h
        h, _ = attn_mod.attention_decode(
            lp["cross"], None, layernorm(lp["ln2"], x), lc["cross"], cfg,
            pos=pos, cross=True)
        x = x + h
        x = x + mlp(lp["mlp"], layernorm(lp["ln3"], x))
        return x, {"self": self_cache, "cross": lc["cross"]}

    x, new_caches = jax.lax.scan(
        body, x, (params["dec"], adapters.get("dec", {}), caches))
    return x, new_caches


def encdec_cache_spec(cfg: ModelConfig, batch: int, seq_len: int) -> dict:
    L = cfg.n_layers
    F = cfg.audio.n_audio_frames
    return {
        "self": attn_mod.cache_spec(cfg, batch, seq_len, layers=L),
        "cross": {
            "k": ParamSpec((L, batch, F, cfg.n_kv_heads, cfg.head_dim_),
                           jnp.dtype(cfg.dtype),
                           (None, "batch", "frames", "kv_heads", "head_dim"),
                           init="zeros"),
            "v": ParamSpec((L, batch, F, cfg.n_kv_heads, cfg.head_dim_),
                           jnp.dtype(cfg.dtype),
                           (None, "batch", "frames", "kv_heads", "head_dim"),
                           init="zeros"),
            "pos": ParamSpec((L, batch, F), jnp.int32,
                             (None, "batch", "frames"), init="zeros"),
        },
    }
