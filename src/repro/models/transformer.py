"""Decoder-stack assembly for all assigned families.

A model is a list of *scan groups*. Each group is a repeating pattern of
sub-layers (``kinds``) whose parameters are stacked along a leading dim and
executed with ``jax.lax.scan`` — this keeps the HLO one-pattern-sized, which
is what makes 512-way GSPMD compiles of 61..64-layer models tractable
(DESIGN.md §7). Dense/MoE/SSM models are a single group; recurrentgemma is
a scanned (rglru, rglru, attn) group plus an unrolled tail group.

Sub-layer kinds: ``attn`` | ``moe`` (attention + MoE FFN) | ``ssm`` |
``rglru`` (recurrent + gated-MLP sandwich, Griffin-style).

PEFT adapters mirror the group structure and are scanned alongside the
parameters; see core/peft.py for the trainable-subtree mechanics.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import mlp, mlp_spec, rmsnorm, rmsnorm_spec
from repro.models.moe import moe_apply, moe_spec
from repro.sharding.rules import ParamSpec, shard


# ---------------------------------------------------------------------------
# Group layout per config
# ---------------------------------------------------------------------------

def groups_for(cfg: ModelConfig) -> list[tuple[str, tuple[str, ...], int]]:
    """[(group_name, kinds, n_repeat)] — static model structure."""
    if cfg.family == "ssm":
        return [("g0", ("ssm",), cfg.n_layers)]
    if cfg.family == "moe":
        return [("g0", ("moe",), cfg.n_layers)]
    if cfg.family == "hybrid":
        pat = tuple(cfg.hybrid.pattern)
        tail = tuple(cfg.hybrid.tail)
        n = (cfg.n_layers - len(tail)) // len(pat)
        out = [("g0", pat, n)]
        if tail:
            out.append(("tail", tail, 1))
        return out
    # dense / vlm / (audio decoder handled in encdec.py)
    return [("g0", ("attn",), cfg.n_layers)]


def attn_window(cfg: ModelConfig, kind: str) -> int:
    if cfg.family == "hybrid":
        return cfg.hybrid.window
    if cfg.attn_variant == "sliding":
        return cfg.sliding_window
    return 0


# ---------------------------------------------------------------------------
# Per-sublayer specs
# ---------------------------------------------------------------------------

def sublayer_spec(cfg: ModelConfig, kind: str) -> dict:
    d = cfg.d_model
    if kind == "ssm":
        return {"ln1": rmsnorm_spec(d), "mix": ssm_mod.ssm_spec(cfg)}
    if kind == "rglru":
        return {"ln1": rmsnorm_spec(d), "mix": rglru_mod.rglru_spec(cfg),
                "ln2": rmsnorm_spec(d), "mlp": mlp_spec(d, cfg.d_ff, jnp.dtype(cfg.dtype))}
    if kind == "moe":
        return {"ln1": rmsnorm_spec(d), "attn": attn_mod.attn_spec(cfg),
                "ln2": rmsnorm_spec(d), "moe": moe_spec(cfg)}
    if kind != "attn":
        raise ValueError(f"unknown sublayer kind {kind!r}: expected "
                         "'attn', 'ssm', 'rglru', or 'moe'")
    return {"ln1": rmsnorm_spec(d), "attn": attn_mod.attn_spec(cfg),
            "ln2": rmsnorm_spec(d), "mlp": mlp_spec(d, cfg.d_ff, jnp.dtype(cfg.dtype))}


def sublayer_adapter_spec(cfg: ModelConfig, kind: str) -> dict:
    """PEFT adapter spec for one sub-layer (DESIGN.md §5)."""
    p = cfg.peft
    d, nh, nkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    out: dict = {}
    if kind in ("attn", "moe"):
        if p.n_prefix > 0:
            out["prefix"] = {
                "k": ParamSpec((p.n_prefix, nkv, hd), jnp.dtype(cfg.dtype),
                               ("prefix", "kv_heads", "head_dim")),
                "v": ParamSpec((p.n_prefix, nkv, hd), jnp.dtype(cfg.dtype),
                               ("prefix", "kv_heads", "head_dim")),
            }
        if p.lora_rank > 0:
            lora = {}
            dims = {"q": nh * hd, "k": nkv * hd, "v": nkv * hd, "o": nh * hd}
            for t in p.lora_targets:
                n_out = dims[t] if t != "o" else d
                n_in = d if t != "o" else nh * hd
                lora[t] = {
                    "a": ParamSpec((n_in, p.lora_rank), jnp.dtype(cfg.dtype),
                                   ("fsdp", "lora_rank"), init="scaled"),
                    "b": ParamSpec((p.lora_rank, n_out), jnp.dtype(cfg.dtype),
                                   ("lora_rank", None), init="zeros"),
                }
            out["lora"] = lora
    elif kind == "ssm" and p.state_prompt:
        out["state0"] = ParamSpec((cfg.d_inner, cfg.ssm.d_state), jnp.float32,
                                  ("d_inner", "state"), init="zeros")
    elif kind == "rglru" and p.state_prompt:
        out["state0"] = ParamSpec((cfg.lru_width,), jnp.float32, ("lru",),
                                  init="zeros")
    return out


def _stack(tree, n: int):
    """Add a leading stacking dim of size n to every ParamSpec."""
    def f(s: ParamSpec) -> ParamSpec:
        axes = (None, *s.axes) if s.axes else (None,) * (len(s.shape) + 1)
        return ParamSpec((n, *s.shape), s.dtype, axes, init=s.init, scale=s.scale)
    return jax.tree.map(f, tree, is_leaf=lambda x: isinstance(x, ParamSpec))


def stack_spec(cfg: ModelConfig) -> dict:
    """Backbone layer-stack spec: {group: {sub_i: stacked spec}}."""
    out = {}
    for name, kinds, n in groups_for(cfg):
        grp = {f"s{i}": sublayer_spec(cfg, k) for i, k in enumerate(kinds)}
        out[name] = _stack(grp, n)
    return out


def adapter_stack_spec(cfg: ModelConfig) -> dict:
    out = {}
    for name, kinds, n in groups_for(cfg):
        grp = {f"s{i}": sublayer_adapter_spec(cfg, k) for i, k in enumerate(kinds)}
        out[name] = _stack(grp, n)
    return out


def cache_group_spec(cfg: ModelConfig, batch: int, seq_len: int, *,
                     paged=None) -> dict:
    """Decode-cache spec mirroring the group structure.

    ``paged=(n_blocks, block_size)`` switches the ELIGIBLE sub-layers
    (full-window attention/moe — see :func:`paged_subs`) to the paged
    block-pool layout; sliding-window and recurrent sub-layers keep
    their dense per-row layout either way."""
    out = {}
    for name, kinds, n in groups_for(cfg):
        grp = {}
        for i, k in enumerate(kinds):
            if k in ("attn", "moe"):
                w = attn_window(cfg, k)
                grp[f"s{i}"] = attn_mod.cache_spec(cfg, batch, seq_len,
                                                   window=w, layers=n,
                                                   paged=paged)
            elif k == "ssm":
                grp[f"s{i}"] = ssm_mod.ssm_cache_spec(cfg, batch, layers=n)
            elif k == "rglru":
                grp[f"s{i}"] = rglru_mod.rglru_cache_spec(cfg, batch, layers=n)
        out[name] = grp
    return out


def paged_subs(cfg: ModelConfig) -> list[tuple[str, str]]:
    """[(group, sub_key)] of sub-layers eligible for the paged KV layout:
    full-window (window == 0) attention/moe. Sliding-window layers keep
    their W-slot rolling buffer (already block-sized) and recurrent
    layers have O(1) state — a config with no eligible sub-layers still
    serves through the paged engine mode, it just allocates no blocks."""
    out = []
    for name, kinds, _ in groups_for(cfg):
        for i, k in enumerate(kinds):
            if k in ("attn", "moe") and not attn_window(cfg, k):
                out.append((name, f"s{i}"))
    return out


# ---------------------------------------------------------------------------
# Sub-layer application
# ---------------------------------------------------------------------------

def _select_state0(a: dict, adapter_ids):
    """Gather each row's state prompt from a stacked (n_slots, ...) bank."""
    if adapter_ids is None or not a or "state0" not in a:
        return a
    return {**a, "state0": jnp.take(a["state0"], adapter_ids, axis=0)}


def _apply_seq(kind: str, p: dict, a: dict, x, cfg: ModelConfig, *,
               positions, make_cache: bool, cache_len=None,
               adapter_ids=None, lengths=None):
    """Full-sequence sub-layer. Returns (x, cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    cache = None
    if kind == "ssm":
        h, cache = ssm_mod.ssm_seq(p["mix"], _select_state0(a, adapter_ids),
                                   rmsnorm(p["ln1"], x), cfg,
                                   make_cache=make_cache, lengths=lengths)
        return x + h, cache, aux
    if kind == "rglru":
        h, cache = rglru_mod.rglru_seq(p["mix"], _select_state0(a, adapter_ids),
                                       rmsnorm(p["ln1"], x), cfg,
                                       make_cache=make_cache, lengths=lengths)
        x = x + h
        x = x + mlp(p["mlp"], rmsnorm(p["ln2"], x))
        return x, cache, aux
    # attention-based
    w = attn_window(cfg, kind)
    h, cache = attn_mod.attention_seq(p["attn"], a, rmsnorm(p["ln1"], x), cfg,
                                      positions=positions, window=w,
                                      make_cache=make_cache,
                                      cache_len=cache_len,
                                      adapter_ids=adapter_ids,
                                      lengths=lengths)
    x = x + h
    if kind == "moe":
        h2, aux = moe_apply(p["moe"], rmsnorm(p["ln2"], x), cfg)
    else:
        h2 = mlp(p["mlp"], rmsnorm(p["ln2"], x))
    return x + h2, cache, aux


def _freeze_inactive(new_cache: dict, old_cache: dict, active):
    """Per-row cache select: retired rows keep their old (frozen) state."""
    if active is None:
        return new_cache
    return jax.tree.map(
        lambda n, o: jnp.where(active.reshape((-1,) + (1,) * (n.ndim - 1)),
                               n, o), new_cache, old_cache)


def _apply_decode(kind: str, p: dict, a: dict, x, cache, cfg: ModelConfig, *,
                  pos, adapter_ids=None, active=None):
    if kind == "ssm":
        h, new = ssm_mod.ssm_decode(p["mix"], a, rmsnorm(p["ln1"], x), cache,
                                    cfg)
        return x + h, _freeze_inactive(new, cache, active)
    if kind == "rglru":
        h, new = rglru_mod.rglru_decode(p["mix"], a, rmsnorm(p["ln1"], x),
                                        cache, cfg)
        x = x + h
        return x + mlp(p["mlp"], rmsnorm(p["ln2"], x)), \
            _freeze_inactive(new, cache, active)
    w = attn_window(cfg, kind)
    h, cache = attn_mod.attention_decode(p["attn"], a, rmsnorm(p["ln1"], x),
                                         cache, cfg, pos=pos, window=w,
                                         adapter_ids=adapter_ids,
                                         active=active)
    x = x + h
    if kind == "moe":
        h2, _ = moe_apply(p["moe"], rmsnorm(p["ln2"], x), cfg)
    else:
        h2 = mlp(p["mlp"], rmsnorm(p["ln2"], x))
    return x + h2, cache


# ---------------------------------------------------------------------------
# Stack forward
# ---------------------------------------------------------------------------

def stack_seq(params: dict, adapters: dict, x: jax.Array, cfg: ModelConfig, *,
              positions: jax.Array, make_cache: bool = False,
              remat: bool = False, cache_len=None, adapter_ids=None,
              lengths=None):
    """Run all groups over a full sequence.

    With ``adapter_ids`` (multi-tenant serving) adapter leaves carry an
    ``n_slots`` dim after the scanned layer dim — ``(L, n_slots, ...)``,
    the AdapterBank serving layout — so every layer slice hands the whole
    slot stack to the batched multi-LoRA projections.

    ``lengths`` (B,) serves ragged right-padded rows: attention caches get
    per-row sentinel positions beyond each row's length, and the
    recurrent sub-layers (ssm/rglru) freeze their state identity-exactly
    over padded columns — so the caches a ragged prefill builds are
    bitwise the caches each row would build alone.

    Returns (x, caches | None, aux_sum)."""
    caches: dict = {}
    aux_total = jnp.zeros((), jnp.float32)

    for name, kinds, n in groups_for(cfg):
        gp, ga = params[name], adapters.get(name, {})

        def body(carry, layer):
            x, aux = carry
            lp, la = layer
            lcaches = {}
            for i, k in enumerate(kinds):
                x, c, a_ = _apply_seq(k, lp[f"s{i}"], la.get(f"s{i}", {}), x,
                                      cfg, positions=positions,
                                      make_cache=make_cache,
                                      cache_len=cache_len,
                                      adapter_ids=adapter_ids,
                                      lengths=lengths)
                aux = aux + a_
                if c is not None:
                    lcaches[f"s{i}"] = c
            return (x, aux), lcaches

        if remat:
            body = jax.checkpoint(body, prevent_cse=False)
        (x, aux_total), gcache = jax.lax.scan(
            body, (x, aux_total), (gp, ga if ga else _empty_like(gp, n)))
        caches[name] = gcache
    return x, (caches if make_cache else None), aux_total


def stack_decode(params: dict, adapters: dict, x: jax.Array,
                 caches: dict, cfg: ModelConfig, *, pos: jax.Array,
                 adapter_ids=None, active=None):
    """Single-token step through all groups. Returns (x, new_caches).

    ``pos`` may be per-row (B,) (ragged serving); ``active`` (B,) bool
    freezes retired rows' caches while the rest of the wave decodes."""
    new_caches: dict = {}
    for name, kinds, n in groups_for(cfg):
        gp, ga = params[name], adapters.get(name, {})
        gc = caches[name]

        def body(x, layer):
            lp, la, lc = layer
            new_lc = {}
            for i, k in enumerate(kinds):
                key = f"s{i}"
                x, c = _apply_decode(k, lp[key], la.get(key, {}), x,
                                     lc[key], cfg, pos=pos,
                                     adapter_ids=adapter_ids,
                                     active=active)
                new_lc[key] = c
            return x, new_lc

        x, new_gc = jax.lax.scan(
            body, x, (gp, ga if ga else _empty_like(gp, n), gc))
        new_caches[name] = new_gc
    return x, new_caches


def rec_cache_part(caches: dict) -> dict:
    """The recurrent ({'h','conv'}) sub-trees of a decode-cache tree — the
    part speculative decoding snapshots per step for rollback (attention
    caches, which carry a 'pos' or 'table' leaf, roll back by slot
    restore instead)."""
    return {g: {s: c for s, c in grp.items()
                if "pos" not in c and "table" not in c}
            for g, grp in caches.items()}


def stack_chunk(params: dict, adapters: dict, x: jax.Array, caches: dict,
                cfg: ModelConfig, *, start: jax.Array, valid: jax.Array,
                adapter_ids=None):
    """Length-W suffix chunk through a FULLY PAGED stack (prefix sharing).

    A prefix-cache hit row re-prefills only its private suffix: x is the
    embedded (B, W, d) suffix, row b at absolute positions
    ``start[b]..start[b]+W-1`` with ``valid`` (B, W) masking real tokens.
    Every sub-layer must be a full-window attention/moe layer holding a
    paged cache (prefix sharing is gated to such configs at the engine).
    Returns (x, new_caches)."""
    new_caches: dict = {}
    for name, kinds, n in groups_for(cfg):
        gp, ga = params[name], adapters.get(name, {})
        gc = caches[name]

        def body(x, layer):
            lp, la, lc = layer
            new_lc = {}
            for i, k in enumerate(kinds):
                key = f"s{i}"
                if k not in ("attn", "moe") or "table" not in lc[key]:
                    raise NotImplementedError(
                        "stack_chunk requires a fully paged attention stack")
                p_, a_ = lp[key], la.get(key, {})
                h, c = attn_mod.attention_chunk_paged(
                    p_["attn"], a_, rmsnorm(p_["ln1"], x), lc[key], cfg,
                    start=start, valid=valid, adapter_ids=adapter_ids)
                x = x + h
                if k == "moe":
                    h2, _ = moe_apply(p_["moe"], rmsnorm(p_["ln2"], x), cfg)
                else:
                    h2 = mlp(p_["mlp"], rmsnorm(p_["ln2"], x))
                x = x + h2
                new_lc[key] = c
            return x, new_lc

        x, new_gc = jax.lax.scan(
            body, x, (gp, ga if ga else _empty_like(gp, n), gc))
        new_caches[name] = new_gc
    return x, new_caches


def stack_verify(params: dict, adapters: dict, x: jax.Array, caches: dict,
                 cfg: ModelConfig, *, pos: jax.Array, adapter_ids=None,
                 active=None):
    """Length-T chunk step through all groups (speculative verify).

    Like ``stack_decode`` but processes a whole draft chunk per row in one
    pass: attention sub-layers scatter the chunk's K/V then attend the
    updated cache (attention_verify — ONE cache read for T tokens, the
    speculative win); recurrent sub-layers chain T exact decode steps and
    emit per-step state snapshots. Returns (x, new_caches, rec_snaps):
    ``rec_snaps`` mirrors :func:`rec_cache_part` with a per-step axis at
    dim 2 ((L, B, T, ...)); ``new_caches`` assumes FULL acceptance —
    core/spec_decode.py::rollback_caches restores each row to its accepted
    length (and freezes inactive rows' recurrent state, which this pass
    advances unconditionally)."""
    new_caches: dict = {}
    snaps: dict = {}
    for name, kinds, n in groups_for(cfg):
        gp, ga = params[name], adapters.get(name, {})
        gc = caches[name]

        def body(x, layer):
            lp, la, lc = layer
            new_lc, snap_lc = {}, {}
            for i, k in enumerate(kinds):
                key = f"s{i}"
                p_, a_ = lp[key], la.get(key, {})
                if k == "ssm":
                    h, s = ssm_mod.ssm_verify(p_["mix"], a_,
                                              rmsnorm(p_["ln1"], x),
                                              lc[key], cfg)
                    x = x + h
                elif k == "rglru":
                    h, s = rglru_mod.rglru_verify(p_["mix"], a_,
                                                  rmsnorm(p_["ln1"], x),
                                                  lc[key], cfg)
                    x = x + h
                    x = x + mlp(p_["mlp"], rmsnorm(p_["ln2"], x))
                else:
                    w = attn_window(cfg, k)
                    h, c = attn_mod.attention_verify(
                        p_["attn"], a_, rmsnorm(p_["ln1"], x), lc[key], cfg,
                        pos=pos, window=w, adapter_ids=adapter_ids,
                        active=active)
                    x = x + h
                    if k == "moe":
                        h2, _ = moe_apply(p_["moe"], rmsnorm(p_["ln2"], x),
                                          cfg)
                    else:
                        h2 = mlp(p_["mlp"], rmsnorm(p_["ln2"], x))
                    x = x + h2
                    new_lc[key], snap_lc[key] = c, {}
                    continue
                new_lc[key] = jax.tree.map(lambda t: t[:, -1], s)
                snap_lc[key] = s
            return x, (new_lc, snap_lc)

        x, (new_gc, snap_gc) = jax.lax.scan(
            body, x, (gp, ga if ga else _empty_like(gp, n), gc))
        new_caches[name] = new_gc
        snaps[name] = snap_gc
    return x, new_caches, snaps


def _empty_like(gp, n: int):
    """Zero-leaf pytree scannable alongside params when no adapters exist."""
    return {}
