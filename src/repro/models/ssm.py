"""Mamba-1 SSM block (falcon-mamba family).

The paper's per-layer prompt module has no attention analogue here
(DESIGN.md §5): the PEFT adaptation is a *learned initial SSM state* per
layer (``adapters['state0']``) plus LoRA on the in/out projections. The
selective scan itself dispatches through kernels/ops.py (Pallas on TPU).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops as kops
from repro.sharding.rules import ParamSpec, shard


def ssm_spec(cfg: ModelConfig) -> dict:
    d, di = cfg.d_model, cfg.d_inner
    ds, dc, dr = cfg.ssm.d_state, cfg.ssm.d_conv, cfg.dt_rank
    dt = jnp.dtype(cfg.dtype)
    return {
        "in_proj": ParamSpec((d, 2 * di), dt, ("fsdp", "d_inner"), init="scaled"),
        "conv_w": ParamSpec((dc, di), dt, ("conv", "d_inner"), init="scaled"),
        "conv_b": ParamSpec((di,), dt, ("d_inner",), init="zeros"),
        "x_proj": ParamSpec((di, dr + 2 * ds), dt, ("d_inner", None), init="scaled"),
        "dt_proj_w": ParamSpec((dr, di), dt, (None, "d_inner"), init="scaled"),
        "dt_proj_b": ParamSpec((di,), jnp.float32, ("d_inner",), init="ones"),
        "A_log": ParamSpec((di, ds), jnp.float32, ("d_inner", "state"), init="ones"),
        "D": ParamSpec((di,), jnp.float32, ("d_inner",), init="ones"),
        "out_proj": ParamSpec((di, d), dt, ("d_inner", "fsdp"), init="scaled"),
    }


def state0_spec(cfg: ModelConfig, layers: int) -> ParamSpec:
    """PEFT state prompt: learned initial state per layer."""
    return ParamSpec((layers, cfg.d_inner, cfg.ssm.d_state), jnp.float32,
                     (None, "d_inner", "state"), init="zeros")


def _conv1d_causal(x: jax.Array, w: jax.Array, b: jax.Array,
                   init: Optional[jax.Array] = None) -> jax.Array:
    """Depthwise causal conv. x: (B, S, Di); w: (K, Di). init: (B, K-1, Di)."""
    K = w.shape[0]
    pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype) if init is None else init
    xp = jnp.concatenate([pad.astype(x.dtype), x], axis=1)
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(K):                                    # K=4: unrolled taps
        out = out + xp[:, i:i + x.shape[1]].astype(jnp.float32) \
            * w[i].astype(jnp.float32)
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def _ssm_inputs(params, x, cfg: ModelConfig):
    dr, ds = cfg.dt_rank, cfg.ssm.d_state
    xdbc = x @ params["x_proj"]
    dt_r, Bm, C = jnp.split(xdbc, [dr, dr + ds], axis=-1)
    dt = jax.nn.softplus(dt_r @ params["dt_proj_w"]
                         + params["dt_proj_b"].astype(dt_r.dtype))
    A = -jnp.exp(params["A_log"])
    return dt, A, Bm, C


def ssm_seq(params: dict, adapters: Optional[dict], x: jax.Array,
            cfg: ModelConfig, *, make_cache: bool = False,
            lengths: Optional[jax.Array] = None):
    """Full-sequence Mamba block. x: (B, S, d). Returns (y, cache or None).

    ``lengths`` (B,) marks ragged right-padded rows: padded columns get
    ``dt = 0`` so the recurrence is the exact identity there
    (``h = exp(0·A)·h + 0``) — the carried state ``hT`` is bitwise the
    state after row b's last VALID token, whatever the padded length.
    The conv cache tail is gathered per row from the last valid columns.
    """
    B, S, _ = x.shape
    di = cfg.d_inner
    xz = x @ params["in_proj"]
    xin, z = jnp.split(xz, 2, axis=-1)
    xin = shard(xin, "batch", "attn_seq", "d_inner")
    xc = jax.nn.silu(_conv1d_causal(xin, params["conv_w"], params["conv_b"]))
    dt, A, Bm, C = _ssm_inputs(params, xc, cfg)
    if lengths is not None:
        valid = jnp.arange(S)[None, :] < lengths[:, None]      # (B, S)
        dt = jnp.where(valid[..., None], dt, jnp.zeros((), dt.dtype))
    h0 = None
    if adapters is not None and "state0" in adapters:
        s0 = adapters["state0"]
        # (Di, N) shared prompt, or (B, Di, N) per-row (multi-tenant
        # gather). An UNgathered (n_slots, Di, N) bank leaf with
        # n_slots == B would pass this guard undetected — serving stacked
        # bank params without adapter_ids is the caller's contract to
        # uphold (the engine enforces it at submit time).
        if s0.ndim == 3 and s0.shape[0] != B:
            raise ValueError(
                f"state0 {s0.shape} is neither a shared (Di, N) prompt nor "
                f"a per-row (B={B}, Di, N) gather — stacked bank leaves "
                "must be gathered by adapter_ids before reaching the layer")
        h0 = s0 if s0.ndim == 3 else \
            jnp.broadcast_to(s0[None], (B, di, cfg.ssm.d_state))
    y, hT = kops.selective_scan(xc, dt, A, Bm, C, params["D"], h0)
    y = y * jax.nn.silu(z)
    out = y @ params["out_proj"]
    out = shard(out, "batch", "seq", "d_model")
    cache = None
    if make_cache:
        K = cfg.ssm.d_conv
        if lengths is None:
            conv_tail = xin[:, -(K - 1):] if S >= K - 1 else jnp.pad(
                xin, ((0, 0), (K - 1 - S, 0), (0, 0)))
        else:
            conv_tail = _ragged_conv_tail(xin, lengths, K)
        cache = {"h": hT, "conv": conv_tail}
    return out, cache


def _ragged_conv_tail(xin: jax.Array, lengths: jax.Array, K: int) -> jax.Array:
    """Per-row last K-1 VALID columns (zeros where the row is shorter).

    xin: (B, S, D); lengths: (B,). Returns (B, K-1, D) — the causal-conv
    state a solo (unpadded) run of row b would have cached."""
    S = xin.shape[1]
    idx = lengths[:, None] - (K - 1) + jnp.arange(K - 1)[None]     # (B, K-1)
    tail = jnp.take_along_axis(xin, jnp.clip(idx, 0, S - 1)[..., None],
                               axis=1)
    return jnp.where((idx >= 0)[..., None], tail, jnp.zeros((), xin.dtype))


def ssm_decode(params: dict, adapters: Optional[dict], x: jax.Array,
               cache: dict, cfg: ModelConfig):
    """Single-token step. x: (B, 1, d); cache: {'h': (B,Di,N), 'conv': (B,K-1,Di)}."""
    B = x.shape[0]
    xz = x @ params["in_proj"]
    xin, z = jnp.split(xz, 2, axis=-1)                    # (B, 1, Di)
    conv_in = jnp.concatenate([cache["conv"], xin], axis=1)
    w = params["conv_w"]
    xc = jnp.einsum("bkd,kd->bd", conv_in.astype(jnp.float32),
                    w.astype(jnp.float32)) + params["conv_b"].astype(jnp.float32)
    xc = jax.nn.silu(xc).astype(x.dtype)[:, None]          # (B, 1, Di)
    dt, A, Bm, C = _ssm_inputs(params, xc, cfg)
    y, h = kops.selective_scan_step(xc[:, 0], dt[:, 0], A, Bm[:, 0], C[:, 0],
                                    params["D"], cache["h"])
    y = (y[:, None] * jax.nn.silu(z))
    out = y @ params["out_proj"]
    return out, {"h": h, "conv": conv_in[:, 1:]}


def ssm_verify(params: dict, adapters: Optional[dict], x: jax.Array,
               cache: dict, cfg: ModelConfig):
    """T chained single-token steps (bitwise ``ssm_decode`` math) emitting a
    per-step state snapshot for speculative rollback.

    x: (B, T, d). Returns (y (B, T, d), snaps {'h': (B, T, Di, N),
    'conv': (B, T, K-1, Di)}) — ``snaps[:, t]`` is the cache after
    processing chunk offset t; the would-be full-acceptance cache is
    ``snaps[:, -1]``."""
    def step(c, xt):
        y, c = ssm_decode(params, adapters, xt, c, cfg)
        return c, (y, c)

    xs = jnp.swapaxes(x, 0, 1)[:, :, None]                 # (T, B, 1, d)
    _, (ys, snaps) = jax.lax.scan(step, cache, xs)
    y = jnp.swapaxes(ys[:, :, 0], 0, 1)                    # (B, T, d)
    return y, jax.tree.map(lambda s: jnp.swapaxes(s, 0, 1), snaps)


def ssm_cache_spec(cfg: ModelConfig, batch: int, layers: Optional[int] = None) -> dict:
    L = layers if layers is not None else cfg.n_layers
    di, ds, K = cfg.d_inner, cfg.ssm.d_state, cfg.ssm.d_conv
    dt = jnp.dtype(cfg.dtype)
    return {
        "h": ParamSpec((L, batch, di, ds), jnp.float32,
                       (None, "batch", "d_inner", "state"), init="zeros"),
        "conv": ParamSpec((L, batch, K - 1, di), dt,
                          (None, "batch", "conv", "d_inner"), init="zeros"),
    }
