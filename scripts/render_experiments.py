"""Render EXPERIMENTS.md roofline tables from results/dryrun_*.json."""
import json
import os
import sys

SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def fmt_s(x):
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.0f}us"
    if x < 1:
        return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"


def table(path: str) -> str:
    rows = json.load(open(path))
    by = {(r["arch"], r["shape"]): r for r in rows}
    archs = sorted({r["arch"] for r in rows})
    out = ["| arch | shape | compute | memory | collective | bottleneck | "
           "FLOPs/dev | bytes/dev | coll B/dev | useful | compile |",
           "|---|---|---|---|---|---|---|---|---|---|---|"]
    for a in archs:
        for s in SHAPES:
            r = by.get((a, s))
            if r is None:
                continue
            if r["status"] == "skipped":
                out.append(f"| {a} | {s} | — | — | — | N/A (skip) "
                           f"| — | — | — | — | — |")
                continue
            if r["status"] != "ok":
                out.append(f"| {a} | {s} | — | — | — | ERROR | — | — | — | — | — |")
                continue
            rf = r["roofline"]
            out.append(
                f"| {a} | {s} | {fmt_s(rf['compute_s'])} | "
                f"{fmt_s(rf['memory_s'])} | {fmt_s(rf['collective_s'])} | "
                f"**{rf['bottleneck']}** | {rf['flops_per_device']:.2e} | "
                f"{rf['bytes_per_device']:.2e} | "
                f"{rf['collective_bytes_per_device']:.2e} | "
                f"{rf['useful_ratio']:.3f} | {r['compile_s']:.0f}s |")
    return "\n".join(out)


def memtable(path: str) -> str:
    rows = json.load(open(path))
    out = ["| arch | shape | args/dev | out/dev | temp/dev | peak/dev |",
           "|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("status") != "ok":
            continue
        m = r.get("memory_analysis", {})
        gb = lambda k: f"{m.get(k, 0)/2**30:.2f}GB"
        out.append(f"| {r['arch']} | {r['shape']} | "
                   f"{gb('argument_size_in_bytes')} | {gb('output_size_in_bytes')} | "
                   f"{gb('temp_size_in_bytes')} | {gb('peak_memory_in_bytes')} |")
    return "\n".join(out)


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "roofline"
    path = sys.argv[2]
    print(table(path) if which == "roofline" else memtable(path))
