#!/usr/bin/env bash
# tracelint: the static-analysis gate for the serving/training hot paths.
#
#   bash scripts/lint.sh [paths...]        # exit 1 on any new finding
#
# Pure-AST (no jax import), lints the whole tree in ~2s. Findings print
# as `file:line CODE message`; suppression baseline lives at
# scripts/lint_baseline.txt (shipped empty — see README "Static
# analysis" for the rules R1-R6 and the `# tracelint:` grammar).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m repro.analysis "$@"
