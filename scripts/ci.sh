#!/usr/bin/env bash
# One-command verify recipe: dev deps + tier-1 tests + kernel + mesh smokes.
#
#   bash scripts/ci.sh
#
# Mirrors what the ROADMAP calls tier-1 (`python -m pytest -x -q`) and adds
# a fast interpret-mode Pallas smoke (flash attention + flash decode — incl.
# the ragged per-row-position serving layout + multi-LoRA adapter_ids —
# + trainable LoRA matmul fwd/bwd) so kernel regressions surface even when
# the suite is filtered.
set -euo pipefail
cd "$(dirname "$0")/.."

python -m pip install -q -r requirements-dev.txt \
    || echo "[ci] pip install failed (offline?); using preinstalled deps"

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

# tracelint gate first: pure-AST, ~2s, catches hot-path regressions
# (cache-key drift, host syncs, wall clocks, unregistered kernels)
# before the suite spends minutes reproducing them dynamically
python -m repro.analysis
echo "[ci] tracelint gate OK (R1-R6 clean against an empty baseline)"

python -m pytest -x -q

python - <<'PY'
import jax, jax.numpy as jnp, numpy as np
from repro.kernels import ops, ref

key = jax.random.PRNGKey(0)
B, S, T, Hq, Hkv, D = 1, 16, 24, 4, 2, 32
ks = jax.random.split(key, 3)
q = jax.random.normal(ks[0], (B, S, Hq, D))
k = jax.random.normal(ks[1], (B, T, Hkv, D))
v = jax.random.normal(ks[2], (B, T, Hkv, D))
qp, kp = jnp.arange(S), jnp.arange(T) - (T - S)
want = ref.attention(q, k, v, q_pos=qp, kv_pos=kp)
got = ops.flash_attention(q, k, v, q_pos=qp, kv_pos=kp, block_q=8,
                          block_kv=8, backend="interpret")
np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-3)

want = ref.decode_attention(q[:, -1], k, v, q_pos=S - 1, kv_pos=kp)
got = ops.flash_decode(q[:, -1], k, v, q_pos=S - 1, kv_pos=kp,
                       backend="interpret")
np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-3)

# ragged mixed-length serving layout: per-row q positions against a cache
# whose rows are written to DIFFERENT depths (+1e9 sentinel beyond each
# row's length) — the engine's continuous-batching decode shape
q2, k2, v2 = (jnp.tile(t, (2,) + (1,) * (t.ndim - 1))
              for t in (q[:, -1], k, v))                # 2-row wave
written = jnp.asarray([10, 18])                         # per-row cache fill
kp_rag = jnp.where(jnp.arange(T)[None, :] < written[:, None],
                   jnp.arange(T)[None, :], 10 ** 9)     # (2, T)
qp_rag = written - 1                                    # (2,)
want = ref.decode_attention(q2, k2, v2, q_pos=qp_rag, kv_pos=kp_rag)
got = ops.flash_decode(q2, k2, v2, q_pos=qp_rag, kv_pos=kp_rag,
                       backend="interpret")
np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-3)

# trainable LoRA matmul: fused forward + custom-VJP adapter grads
M_, K_, N_, r_ = 16, 32, 24, 4
ks = jax.random.split(key, 5)
x = jax.random.normal(ks[0], (M_, K_))
w = jax.random.normal(ks[1], (K_, N_)) * 0.05
a = jax.random.normal(ks[2], (K_, r_)) * 0.05
b = jax.random.normal(ks[3], (r_, N_)) * 0.05
dy = jax.random.normal(ks[4], (M_, N_))
want = ref.lora_matmul(x, w, a, b, 2.0)
got = ops.lora_matmul(x, w, a, b, 2.0, backend="interpret")
np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                           atol=1e-3, rtol=1e-3)
f = lambda x_, a_, b_: jnp.vdot(
    ops.lora_matmul(x_, w, a_, b_, 2.0, backend="interpret"), dy)
dx, da, db = jax.grad(f, argnums=(0, 1, 2))(x, a, b)
rdx, rda, rdb = ref.lora_matmul_bwd(x, w, a, b, 2.0, dy)
for g, r in ((dx, rdx), (da, rda), (db, rdb)):
    np.testing.assert_allclose(np.asarray(g), np.asarray(r),
                               atol=1e-3, rtol=1e-3)

# batched multi-LoRA (multi-tenant serving): rows (BGMV, masked-accumulation)
# and sequence (scalar-prefetched gather) fwd vs the gather oracle
n_slots = 3
ks = jax.random.split(key, 3)
a_s = jax.random.normal(ks[0], (n_slots, K_, r_)) * 0.05
b_s = jax.random.normal(ks[1], (n_slots, r_, N_)) * 0.05
ids = jax.random.randint(ks[2], (M_,), 0, n_slots, dtype=jnp.int32)
want = ref.lora_bgmv(x, w, a_s, b_s, ids, 2.0)
got = ops.lora_bgmv(x, w, a_s, b_s, ids, 2.0, backend="interpret")
np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                           atol=1e-3, rtol=1e-3)
xs = x.reshape(4, M_ // 4, K_)
want = ref.lora_bgmv(xs, w, a_s, b_s, ids[:4], 2.0)
got = ops.lora_bgmv(xs, w, a_s, b_s, ids[:4], 2.0, backend="interpret")
np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                           atol=1e-3, rtol=1e-3)
print("[ci] interpret-mode kernel smoke OK "
      "(attn + decode + ragged per-row decode + lora fwd/bwd "
      "+ multi-lora gathered fwd)")
PY

# Chaos smoke: a FaultPlan-driven integrated round (dropout + NaN-poisoned
# cluster updates) must complete with a finite serving bank, and a forced
# bad publish must be refused at the bank door with last-known-good
# rollback restoring the slot bitwise (the full sweep: tests/test_faults.py,
# `pytest -m chaos`).
python - <<'PY'
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import get_config
from repro.core.faults import FaultPlan
from repro.core.integrated import IntegratedRuntime
from repro.data.synthetic import ClassificationTask

cfg = get_config("vit-edge").reduced().with_(dtype="float32", vocab_size=64)
cfg = cfg.with_(peft=dataclasses.replace(cfg.peft, head_dim_out=5))
tasks = {n: ClassificationTask(5, 64, 16, seed=i)
         for i, n in enumerate(["nlp", "cv"])}
plan = FaultPlan(seed=3, dropout=0.4, grad_nan=0.4)
rt = IntegratedRuntime(cfg, tasks, n_clusters=4, steps_per_upgrade=4,
                       batch=4, sync_every=2, serve_batch=8, serve_gen=2,
                       serve_slots=4, seed=0, faults=plan)
recs = rt.run(["nlp", "cv", "nlp"], policy=lambda r, lv: r % 2 if r < 2 else 2)
assert len(recs) == 3, recs
ups = [r for r in recs if r.action == "upgrade"]
assert sum(r.cost.dropped_clusters + r.cost.skipped_updates
           for r in ups) > 0, "chaos plan never fired"
for x in jax.tree.leaves(rt.bank.stacked):
    assert np.isfinite(np.asarray(x, np.float32)).all(), "bank went non-finite"

good = rt.bank.snapshot("nlp")
try:
    rt.bank.publish("nlp", jax.tree.map(lambda x: x * jnp.nan, good))
    raise SystemExit("poisoned publish was accepted")
except ValueError:
    pass
rt.bank.publish("nlp", jax.tree.map(lambda x: x + 1.0, good))
rt.bank.rollback("nlp")
for g, w in zip(jax.tree.leaves(rt.bank.snapshot("nlp")),
                jax.tree.leaves(good)):
    np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
print("[ci] chaos smoke OK (masked round completed finite; "
      "bad publish refused; LKG rollback bitwise)")
PY

# Speculative-serving smoke: a spec engine drain (tiny recurrent drafter,
# batched verify, exact-match acceptance + rollback) must be token-for-token
# identical to plain generate_scan, with the plain baseline's decode
# attention running through the interpret-mode Pallas flash-decode path —
# so parity here covers kernel decode vs pure-jnp verify agreement too
# (the full sweep: tests/test_spec_decode.py).
python - <<'PY'
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import get_config
from repro.core.spec_decode import SpecDecoder, spec_generate
from repro.kernels import ops
from repro.launch.engine import DecodeEngine
from repro.models import model as M

cfg = get_config("vit-edge").reduced().with_(dtype="float32", vocab_size=64)
params = M.init(cfg, jax.random.PRNGKey(0))
spec = SpecDecoder.init(cfg, jax.random.PRNGKey(7), k=3)
prompts = np.asarray(jax.random.randint(
    jax.random.PRNGKey(1), (3, 10), 1, cfg.vocab_size, dtype=jnp.int32))
with ops.backend("interpret"):
    ref = np.asarray(M.generate_scan(params, cfg, jnp.asarray(prompts),
                                     gen=7))
out, stats = spec_generate(params, cfg, spec, prompts, gen=7)
np.testing.assert_array_equal(np.asarray(out), ref)
eng = DecodeEngine(cfg, slots=2, spec=spec)
served, st = eng.serve(params, prompts, gen=7)
np.testing.assert_array_equal(served, ref)
assert st.drafted > 0 and st.acceptance_rate == st.accepted / st.drafted
print("[ci] speculative smoke OK (spec_generate + spec engine drain "
      f"token-identical to greedy scan; acceptance {st.acceptance_rate:.2f})")
PY

# Host-device mesh smoke: benchmarks/shard_bench.py spawns a forced
# 4-host-device ('data','model') mesh subprocess, hard-asserts that the
# sharded engine drain is token-identical and the sharded HFSL round is
# loss-identical to the unsharded path, and checks the AdapterBank slot /
# BatchBank cluster placements (the full sweep, incl. the hot-publish
# train-to-serve loop, lives in tests/test_mesh_sharding.py).
python -m benchmarks.shard_bench
echo "[ci] host-device mesh smoke OK (sharded drain + sharded HFSL round parity)"

# Telemetry smoke: trace one mixed-domain drain + one HFSL upgrade round
# end-to-end and check the exported Chrome trace parses and contains the
# request-lifecycle, segment, round-dispatch, and bank-publish spans the
# observability layer promises (the full sweep: tests/test_telemetry.py).
python - <<'PY'
import dataclasses, json, tempfile, os
import numpy as np
from repro.configs.base import get_config
from repro.core import telemetry
from repro.core.integrated import IntegratedRuntime
from repro.data.synthetic import ClassificationTask

cfg = get_config("vit-edge").reduced().with_(dtype="float32", vocab_size=64)
cfg = cfg.with_(peft=dataclasses.replace(cfg.peft, head_dim_out=5))
tasks = {n: ClassificationTask(5, 64, 16, seed=i)
         for i, n in enumerate(["nlp", "cv"])}
tel = telemetry.enable()
rt = IntegratedRuntime(cfg, tasks, n_clusters=2, steps_per_upgrade=2,
                       batch=4, sync_every=2, serve_batch=8, serve_gen=2,
                       serve_slots=4, seed=0)
rt.upgrade("nlp")
rt.produce(["nlp", "cv"])
telemetry.disable()

path = os.path.join(tempfile.mkdtemp(), "trace.json")
n = tel.export_trace(path)
doc = json.load(open(path))
names = {e["name"] for e in doc["traceEvents"] if e.get("ph") == "X"}
for want in ("engine.prefill", "engine.segment", "engine.request",
             "engine.drain", "hfsl.round_dispatch", "bank.publish",
             "integrated.upgrade", "integrated.produce"):
    assert want in names, f"trace missing span {want!r} (got {sorted(names)})"
assert n == sum(1 for e in doc["traceEvents"] if e.get("ph") == "X")
assert tel.counters["engine.retired"] == 8
assert tel.hist_summary("engine.ttft_s")["count"] == 8
print(f"[ci] telemetry smoke OK ({n} spans; traced upgrade+produce round, "
      "Perfetto JSON parses with lifecycle/segment/round/publish spans)")
PY

# Paged-KV smoke: the interpret-mode block-table kernel must match the
# paged oracle bit-for-bit against the xla gather path's visible set, and
# a prefix-sharing paged drain must serve token-identically to dense
# serving while prefilling each shared block exactly once (the full
# sweep: tests/test_ragged.py paged suite + tests/test_kernels.py).
python - <<'PY'
import jax, jax.numpy as jnp, numpy as np
from repro.kernels import ops, ref

key = jax.random.PRNGKey(3)
B, maxb, bs, Hq, Hkv, D, nb = 2, 4, 8, 4, 2, 32, 16
ks = jax.random.split(key, 3)
q = jax.random.normal(ks[0], (B, Hq, D))
k_pool = jax.random.normal(ks[1], (nb, bs, Hkv, D))
v_pool = jax.random.normal(ks[2], (nb, bs, Hkv, D))
rng = np.random.default_rng(0)
table = jnp.asarray(np.stack([rng.choice(nb, maxb, replace=False)
                              for _ in range(B)]).astype(np.int32))
q_pos = jnp.asarray([maxb * bs - 1, maxb * bs - 9], jnp.int32)
want = ref.paged_decode_attention(q, k_pool, v_pool, table, q_pos=q_pos)
for backend in ("xla", "interpret"):
    got = ops.flash_decode_paged(q, k_pool, v_pool, table, q_pos=q_pos,
                                 backend=backend)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-3)
# fp32 bit-parity paged-vs-dense on the engine's xla path: same visible
# values + same accumulation order == bitwise-equal decode outputs
k = k_pool[table].reshape(B, maxb * bs, Hkv, D)
v = v_pool[table].reshape(B, maxb * bs, Hkv, D)
dense = ops.flash_decode(q, k, v, q_pos=q_pos,
                         kv_pos=jnp.arange(maxb * bs, dtype=jnp.int32),
                         window=0, causal=True, backend="xla")
paged = ops.flash_decode_paged(q, k_pool, v_pool, table, q_pos=q_pos,
                               backend="xla")
np.testing.assert_array_equal(np.asarray(paged), np.asarray(dense))
print("[ci] paged flash-decode smoke OK (block-table kernel vs oracle; "
      "fp32 bit-parity paged-vs-dense)")
PY

python - <<'PY'
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import get_config
from repro.core.paged import PagedSpec
from repro.launch.engine import DecodeEngine
from repro.models import model as M

cfg = get_config("qwen2-7b").reduced().with_(dtype="float32", vocab_size=64)
params = M.init(cfg, jax.random.PRNGKey(7))
bs, gen = 4, 3
rng = np.random.default_rng(1)
prefix = rng.integers(0, 64, 2 * bs).astype(np.int32)   # 2 full shared blocks
rows = [np.concatenate([prefix, rng.integers(0, 64, 3).astype(np.int32)])
        for _ in range(3)]
eng = DecodeEngine(cfg, slots=4,
                   paged=PagedSpec(n_blocks=32, block_size=bs,
                                   share_prefix=True))
uids = [eng.submit(r, gen) for r in rows]
comps, stats = eng.run(params)
assert stats.prefix_hits == 2, stats.prefix_hits
naive = sum(-(-(len(r) + gen) // bs) for r in rows)
assert eng._alloc.allocated == naive - 4       # shared blocks prefilled once
by_uid = {c.uid: c.tokens for c in comps}
for uid, r in zip(uids, rows):
    want = np.asarray(M.generate_scan(params, cfg, jnp.asarray(r[None]),
                                      gen=gen))[0]
    np.testing.assert_array_equal(by_uid[uid], want)
assert eng._alloc.used_blocks == 0
eng._alloc.check()
print("[ci] paged prefix-sharing smoke OK (2 prefix hits, shared blocks "
      "prefilled once, drain token-identical to solo serving)")
PY
