"""Batched decode engine: packing, recycling, parity, and runtime wiring."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.launch.engine import DecodeEngine
from repro.models import model as M


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("vit-edge").reduced().with_(dtype="float32",
                                                 vocab_size=64)
    params = M.init(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_engine_matches_direct_generation(setup):
    """Wave packing + slot padding must not change any request's tokens."""
    cfg, params = setup
    prompts = np.asarray(jax.random.randint(
        jax.random.PRNGKey(1), (6, 16), 0, cfg.vocab_size, dtype=jnp.int32))
    direct = np.asarray(M.generate_scan(params, cfg, jnp.asarray(prompts),
                                        gen=5))
    engine = DecodeEngine(cfg, slots=4)
    served, stats = engine.serve(params, prompts, gen=5)
    np.testing.assert_array_equal(served, direct)
    assert stats.requests == 6
    assert stats.waves == 2                     # 4 slots + 2 recycled
    assert stats.tokens == 30
    assert stats.tok_per_s > 0 and stats.wall_s > 0


def test_engine_length_buckets_and_budgets(setup):
    """Mixed prompt lengths + per-request budgets share ONE ragged drain:
    each request is served exactly its own budget."""
    cfg, params = setup
    key = jax.random.PRNGKey(2)
    engine = DecodeEngine(cfg, slots=3)
    short = np.asarray(jax.random.randint(key, (2, 8), 0, cfg.vocab_size))
    long = np.asarray(jax.random.randint(key, (2, 12), 0, cfg.vocab_size))
    uids = [engine.submit(short[0], 3), engine.submit(long[0], 6),
            engine.submit(short[1], 5), engine.submit(long[1], 2)]
    comps, stats = engine.run(params)
    assert sorted(c.uid for c in comps) == sorted(uids)
    budgets = {uids[0]: 3, uids[1]: 6, uids[2]: 5, uids[3]: 2}
    for c in comps:
        assert c.tokens.shape == (budgets[c.uid],)
    assert stats.tokens == sum(budgets.values())
    assert engine.pending() == 0
    assert all(not s.active for s in engine.slot_table)   # all recycled


def test_engine_extras_stay_bound_to_requests():
    """Packing and in-wave refill move requests between slots; each request
    must still be conditioned on ITS OWN vision row (not its
    submission-order slot's)."""
    cfg = get_config("llava-next-mistral-7b").reduced().with_(dtype="float32")
    params = M.init(cfg, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(4)
    n_vis, d = cfg.vlm.n_vis_tokens, cfg.d_model
    vis = np.asarray(jax.random.normal(key, (4, n_vis, d))) * 0.1
    short = np.asarray(jax.random.randint(key, (2, 8), 0, cfg.vocab_size))
    long = np.asarray(jax.random.randint(key, (2, 12), 0, cfg.vocab_size))

    engine = DecodeEngine(cfg, slots=2)
    uids = []                                  # interleave the two lengths
    for i, toks in enumerate([short[0], long[0], short[1], long[1]]):
        uids.append(engine.submit(toks, 4,
                                  extras={"vision_embeds": vis[i]}))
    comps, _ = engine.run(params)
    by_uid = {c.uid: c.tokens for c in comps}

    for i, toks in enumerate([short[0], long[0], short[1], long[1]]):
        want = M.generate_scan(
            params, cfg, jnp.asarray(toks[None]), gen=4,
            extra_batch={"vision_embeds": jnp.asarray(vis[i][None])})
        np.testing.assert_array_equal(by_uid[uids[i]], np.asarray(want[0]))


def test_engine_rejects_mismatched_extras(setup):
    cfg, params = setup
    engine = DecodeEngine(cfg, slots=2)
    engine.submit(np.zeros(8, np.int32), 2, extras={"a": np.zeros(3)})
    engine.submit(np.zeros(8, np.int32), 2)
    with pytest.raises(ValueError, match="extras keys"):
        engine.run(params)


def test_engine_slot_table_tracks_positions(setup):
    """During packing the slot table carries uid/prompt-length/target."""
    cfg, params = setup
    engine = DecodeEngine(cfg, slots=2)
    engine.submit(np.zeros(10, np.int32), 4)
    packed = engine._fill_slots()
    assert len(packed) == 1
    idx, req = packed[0]
    slot = engine.slot_table[idx]
    assert slot.active and slot.prompt_len == 10 and slot.target == 4
    engine._queue.appendleft(req)               # restore for a clean drain
    slot.recycle()
    comps, _ = engine.run(params)
    assert len(comps) == 1


def test_integrated_produce_uses_engine():
    """produce() serves through the engine and books tok/s in RoundCost."""
    cfg = get_config("vit-edge").reduced().with_(dtype="float32",
                                                 vocab_size=64)
    cfg = cfg.with_(peft=dataclasses.replace(cfg.peft, head_dim_out=5))
    from repro.core.integrated import IntegratedRuntime
    from repro.data.synthetic import ClassificationTask
    tasks = {"nlp": ClassificationTask(5, 64, 24, class_strength=0.6)}
    rt = IntegratedRuntime(cfg, tasks, n_clusters=2, steps_per_upgrade=2,
                           serve_batch=8, serve_gen=3, serve_slots=4, seed=0)
    profit, cost = rt.produce("nlp")
    assert 0.0 <= profit <= rt.profit_scale
    assert cost.tokens == 8 * 3
    assert cost.latency_s > 0 and cost.tok_per_s > 0
    assert cost.compute_flops > 0
