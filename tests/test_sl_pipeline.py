"""SL pipeline tests.

The pipelined-vs-monolithic equivalence needs >1 device, so it runs in a
subprocess with forced host devices (the main test process keeps 1 device).
"""
import os
import subprocess
import sys
import textwrap

import pytest

from repro.configs.base import get_config
from repro.core.sl_pipeline import SLTrace, simulate_sl

ROOT = os.path.join(os.path.dirname(__file__), "..")


def test_simulate_sl_accounting():
    cfg = get_config("vit-edge")
    tr = simulate_sl(cfg, batch=8, seq=32, n_clients=4, training=True)
    assert tr.hops == 3
    act = 8 * 32 * cfg.d_model * 2          # bf16
    assert tr.smashed_bytes == act * 3
    assert tr.gradient_bytes == tr.smashed_bytes
    inf = simulate_sl(cfg, batch=8, seq=32, n_clients=4, training=False)
    assert inf.gradient_bytes == 0
    assert sum(inf.per_client_flops) < sum(tr.per_client_flops)


class TestValidation:
    """Malformed pipeline inputs raise real ValueErrors (not bare asserts
    that disappear under ``python -O``)."""

    def test_multi_group_stack_rejected(self):
        import jax.numpy as jnp
        from repro.core.sl_pipeline import split_for_stages
        cfg = get_config("vit-edge")
        params = {"backbone": {"layers": {"g0": {"w": jnp.zeros((4, 2))},
                                          "g1": {"w": jnp.zeros((4, 2))}}},
                  "adapters": {"stack": {}}}
        with pytest.raises(ValueError, match="single-group"):
            split_for_stages(params, cfg, 2)

    def test_indivisible_layers_rejected(self):
        import jax.numpy as jnp
        from repro.core.sl_pipeline import split_for_stages
        cfg = get_config("vit-edge")
        params = {"backbone": {"layers": {"g0": {"w": jnp.zeros((3, 2))}}},
                  "adapters": {"stack": {}}}
        with pytest.raises(ValueError, match="not divisible by n_stages"):
            split_for_stages(params, cfg, 2)

    def test_indivisible_microbatches_rejected(self):
        import jax
        import jax.numpy as jnp
        from repro.core.sl_pipeline import pipeline_classify
        cfg = get_config("vit-edge")
        mesh = jax.make_mesh((1,), ("stage",))
        toks = jnp.zeros((5, 8), jnp.int32)     # B=5 not divisible by M=4
        with pytest.raises(ValueError, match="n_microbatches"):
            pipeline_classify({}, {}, toks, cfg, mesh, n_microbatches=4)


@pytest.mark.slow
def test_pipeline_matches_monolithic_subprocess():
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import sys; sys.path.insert(0, "src")
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import get_config
        from repro.core.sl_pipeline import pipeline_classify, split_for_stages
        from repro.models import model as M

        cfg = get_config("vit-edge").reduced().with_(n_layers=4, dtype="float32")
        cfg = cfg.with_(peft=dataclasses.replace(cfg.peft, head_dim_out=5))
        params = M.init(cfg, jax.random.PRNGKey(0))
        mesh = jax.make_mesh((4,), ("stage",))
        st = split_for_stages(params, cfg, 4)
        toks = jax.random.randint(jax.random.PRNGKey(1), (16, 24), 0,
                                  cfg.vocab_size)
        got = pipeline_classify(params, st, toks, cfg, mesh, n_microbatches=4)
        want = M.classify(params, {"tokens": toks}, cfg)
        err = float(np.abs(np.asarray(got) - np.asarray(want)).max())
        assert err < 1e-4, err
        # SL fine-tuning: grads flow through the ppermute chain
        from repro.models.layers import cross_entropy
        labels = jnp.zeros((16,), jnp.int32)
        def loss(stages, head):
            p = {"backbone": params["backbone"],
                 "adapters": {**params["adapters"], "head": head}}
            lg = pipeline_classify(p, stages, toks, cfg, mesh,
                                   n_microbatches=4)
            return cross_entropy(lg, labels)
        g_st, g_head = jax.grad(loss, argnums=(0, 1))(
            st, params["adapters"]["head"])
        gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(g_st))
        assert np.isfinite(gn) and gn > 0, gn
        print("PIPELINE_OK", err)
    """)
    r = subprocess.run([sys.executable, "-c", script], cwd=ROOT,
                       capture_output=True, text=True, timeout=900)
    assert "PIPELINE_OK" in r.stdout, r.stdout + r.stderr
