"""Fused fine-tuning round engine tests: scan-vs-loop parity, in-scan
FedAvg semantics, BatchBank, LoRA merge under the serving paths."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core import hfsl, peft
from repro.data.noniid import partition_by_classes
from repro.data.pipeline import BatchBank, cluster_batches
from repro.data.synthetic import ClassificationTask, LMStream
from repro.models import model as M
from repro.optim.optimizers import adamw, sgd

KEY = jax.random.PRNGKey(0)
N, K, BATCH, SEQ = 3, 6, 4, 16


def small_cfg():
    cfg = get_config("vit-edge").reduced().with_(dtype="float32",
                                                 vocab_size=64)
    return cfg.with_(peft=dataclasses.replace(cfg.peft, head_dim_out=5))


def classify_bank(cfg, seed=0):
    task = ClassificationTask(5, cfg.vocab_size, SEQ, seed=seed)
    data = task.dataset(40 * N, seed=seed + 1)
    parts = partition_by_classes(data["label"], N, 3, seed=seed)
    return BatchBank.pack(data, parts, BATCH, seed=seed)


def lm_bank(cfg, seed=0):
    streams = [LMStream(cfg.vocab_size, BATCH, SEQ, seed=seed + i)
               for i in range(N)]
    its = [iter(s) for s in streams]

    def gen():
        while True:
            bs = [next(i) for i in its]
            yield {k: jnp.stack([b[k] for b in bs]) for k in bs[0]}

    return BatchBank.from_iterator(gen(), K)


def run_loop(cfg, opt, loss_fn, state, bank, steps, **kw):
    step = jax.jit(hfsl.make_hfsl_step(cfg, opt, loss_fn, **kw))
    losses = []
    for i in range(steps):
        batch = jax.tree.map(lambda x: x[i % bank.steps], bank.arrays)
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    return state, losses


def assert_trees_close(a, b, **tol):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32), **tol)


class TestRoundParity:
    # classify (the integrated runtime's loss) stays tier-1; the LM sweep
    # is `slow` — the LM loss path also rides the microbatch/remat tests
    @pytest.mark.parametrize("kind", [
        "classify", pytest.param("lm", marks=pytest.mark.slow)])
    def test_round_matches_k_legacy_steps(self, kind):
        cfg = small_cfg()
        opt = adamw(5e-3)
        state = hfsl.init_hfsl_state(KEY, cfg, N, opt, M.init)
        if kind == "classify":
            bank, loss_fn = classify_bank(cfg), M.classify_loss
        else:
            bank, loss_fn = lm_bank(cfg), M.lm_loss
        s_loop, losses = run_loop(cfg, opt, loss_fn, state, bank, K,
                                  sync_every=3)
        rnd = hfsl.make_hfsl_round(cfg, opt, loss_fn, steps=K, sync_every=3)
        s_scan, ms = rnd(state, bank.arrays, 0)
        assert int(s_scan["step"]) == K
        assert_trees_close(s_loop["adapters_c"], s_scan["adapters_c"],
                           atol=1e-6, rtol=1e-6)
        assert_trees_close(s_loop["opt"], s_scan["opt"],
                           atol=1e-6, rtol=1e-6)
        np.testing.assert_allclose(losses, np.asarray(ms["loss"]), atol=1e-6)

    def test_round_continues_across_calls(self):
        """Two rounds with carried step/offset == one long legacy run — the
        FedAvg phase must persist across round boundaries (the old
        integrated.py bug reset it)."""
        cfg = small_cfg()
        opt = sgd(0.1)
        state = hfsl.init_hfsl_state(KEY, cfg, N, opt, M.init)
        bank = classify_bank(cfg)
        s_loop, _ = run_loop(cfg, opt, M.classify_loss, state, bank, 2 * K,
                             sync_every=4)
        rnd = hfsl.make_hfsl_round(cfg, opt, M.classify_loss, steps=K,
                                   sync_every=4)
        s1, _ = rnd(state, bank.arrays, 0)
        s2, _ = rnd(s1, bank.arrays, K % bank.steps)
        assert int(s2["step"]) == 2 * K
        assert_trees_close(s_loop["adapters_c"], s2["adapters_c"],
                           atol=1e-6, rtol=1e-6)

    def test_microbatch_accumulation_matches_full_batch(self):
        cfg = small_cfg()
        opt = adamw(5e-3)
        state = hfsl.init_hfsl_state(KEY, cfg, N, opt, M.init)
        bank = classify_bank(cfg)
        full = hfsl.make_hfsl_round(cfg, opt, M.classify_loss, steps=K,
                                    sync_every=3)
        accum = hfsl.make_hfsl_round(cfg, opt, M.classify_loss, steps=K,
                                     sync_every=3, microbatches=2)
        s_full, m_full = full(state, bank.arrays, 0)
        s_acc, m_acc = accum(state, bank.arrays, 0)
        # mean-of-means == full-batch mean up to f32 reassociation
        assert_trees_close(s_full["adapters_c"], s_acc["adapters_c"],
                           atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(m_full["loss"]),
                                   np.asarray(m_acc["loss"]), atol=1e-5)

    def test_remat_round_matches_plain(self):
        cfg = small_cfg()
        opt = sgd(0.1)
        state = hfsl.init_hfsl_state(KEY, cfg, N, opt, M.init)
        bank = lm_bank(cfg)
        plain = hfsl.make_hfsl_round(cfg, opt, M.lm_loss, steps=2)
        remat = hfsl.make_hfsl_round(cfg, opt, M.lm_loss, steps=2, remat=True)
        s_p, _ = plain(state, bank.arrays, 0)
        s_r, _ = remat(state, bank.arrays, 0)
        assert_trees_close(s_p["adapters_c"], s_r["adapters_c"],
                           atol=1e-5, rtol=1e-5)


class TestSyncSemantics:
    """FedAvg fires exactly at sync_every multiples of the step counter;
    cluster replicas diverge strictly between syncs — both engines."""

    def _spread(self, state):
        w = state["adapters_c"]["head"]["w"]
        return float(jnp.max(jnp.std(w.astype(jnp.float32), axis=0)))

    def _check_pattern(self, spreads, sync_every):
        for s, spread in spreads.items():           # s is the 1-based step
            if s % sync_every == 0:
                assert spread < 1e-6, (s, spread)
            else:
                assert spread > 1e-7, (s, spread)

    def test_legacy_loop_sync_pattern(self):
        cfg = small_cfg()
        opt = sgd(0.1)
        state = hfsl.init_hfsl_state(KEY, cfg, N, opt, M.init)
        bank = classify_bank(cfg)
        step = jax.jit(hfsl.make_hfsl_step(cfg, opt, M.classify_loss,
                                           sync_every=3))
        spreads = {}
        for i in range(K):
            batch = jax.tree.map(lambda x: x[i % bank.steps], bank.arrays)
            state, _ = step(state, batch)
            spreads[i + 1] = self._spread(state)
        self._check_pattern(spreads, 3)

    def test_scanned_round_sync_pattern(self):
        cfg = small_cfg()
        opt = sgd(0.1)
        state = hfsl.init_hfsl_state(KEY, cfg, N, opt, M.init)
        bank = classify_bank(cfg)
        rnd = hfsl.make_hfsl_round(cfg, opt, M.classify_loss, steps=1,
                                   sync_every=3)
        spreads = {}
        for i in range(K):
            state, _ = rnd(state, bank.arrays, i % bank.steps)
            spreads[int(state["step"])] = self._spread(state)
        self._check_pattern(spreads, 3)


class TestBatchBank:
    def test_pack_matches_iterator(self):
        cfg = small_cfg()
        task = ClassificationTask(5, cfg.vocab_size, SEQ, seed=0)
        data = task.dataset(40 * N, seed=1)
        parts = partition_by_classes(data["label"], N, 3, seed=0)
        bank = BatchBank.pack(data, parts, BATCH, seed=0)
        it = cluster_batches(data, parts, BATCH, seed=0)
        for i in range(min(bank.steps, 3)):
            row = next(it)
            for k in row:
                np.testing.assert_array_equal(np.asarray(bank.arrays[k][i]),
                                              np.asarray(row[k]))
        assert bank.n_clusters == N

    def test_advance_wraps(self):
        cfg = small_cfg()
        bank = classify_bank(cfg)
        E = bank.steps
        assert bank.advance(E - 1) == 0
        assert bank.advance(2) == E - 1
        assert bank.offset == 1

    def test_pack_rejects_empty_cluster(self):
        data = {"tokens": np.zeros((8, 4), np.int32),
                "label": np.zeros((8,), np.int32)}
        parts = [np.arange(6), np.arange(6, 8)]     # cluster 1 < batch size
        with pytest.raises(ValueError):
            BatchBank.pack(data, parts, 4)


class TestLoRAMergeServing:
    """merge_lora_into_backbone parity on the *serving* paths (the forward
    parity lives in test_core.py): merged backbone must generate the same
    tokens and classify identically, including through the kernel-dispatched
    fused projection."""

    def _lora_params(self, cfg):
        params = M.init(cfg, KEY)
        stack = params["adapters"]["stack"]
        for g in stack.values():
            for s in g.values():
                for ab in s.get("lora", {}).values():
                    ab["b"] = jax.random.normal(KEY, ab["b"].shape,
                                                ab["b"].dtype) * 0.02
        return params

    def test_merge_preserves_generate_scan(self):
        cfg = small_cfg()
        params = self._lora_params(cfg)
        prompts = jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size,
                                     dtype=jnp.int32)
        before = M.generate_scan(params, cfg, prompts, gen=6)
        merged = peft.merge_lora_into_backbone(params, cfg)
        after = M.generate_scan(merged, cfg, prompts, gen=6)
        np.testing.assert_array_equal(np.asarray(before), np.asarray(after))

    def test_merge_preserves_classify_interpret_backend(self):
        from repro.kernels import ops
        cfg = small_cfg()
        params = self._lora_params(cfg)
        batch = {"tokens": jnp.ones((2, 8), jnp.int32)}
        with ops.backend("interpret"):
            before = M.classify(params, batch, cfg)
            merged = peft.merge_lora_into_backbone(params, cfg)
            after = M.classify(merged, batch, cfg)
        np.testing.assert_allclose(np.asarray(before), np.asarray(after),
                                   atol=2e-4, rtol=2e-4)
