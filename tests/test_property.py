"""Hypothesis property tests on system invariants."""
import dataclasses

import pytest

hypothesis = pytest.importorskip("hypothesis")

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.core import hfsl, scheduler
from repro.data.noniid import dirichlet_partition, partition_by_classes
from repro.kernels import ops, ref
from repro.models.moe import capacity
from repro.configs.base import MoEConfig, get_config
from repro.sharding.rules import fit_spec
from jax.sharding import AbstractMesh, PartitionSpec as P

SETTINGS = dict(deadline=None, max_examples=20,
                suppress_health_check=[hypothesis.HealthCheck.too_slow])


# ---------------------------------------------------------------------------
# Attention masking semantics
# ---------------------------------------------------------------------------

@given(S=st.integers(2, 24), n_p=st.integers(0, 6),
       window=st.integers(0, 16))
@settings(**SETTINGS)
def test_visibility_mask_invariants(S, n_p, window):
    q_pos = jnp.arange(S)
    kv_pos = jnp.arange(S + n_p) - n_p
    vis = np.asarray(ref.visibility_mask(q_pos, kv_pos, window))
    # prefix slots always visible
    assert vis[:, :n_p].all()
    # causality: no future positions
    for i in range(S):
        for j in range(S):
            if j > i:
                assert not vis[i, n_p + j]
    # window: nothing older than window
    if window > 0:
        for i in range(S):
            for j in range(S):
                if j <= i and (i - j) >= window:
                    assert not vis[i, n_p + j]
    # every row attends to at least its own position (or a prefix slot)
    assert vis.any(axis=1).all()


@given(B=st.integers(1, 2), S=st.sampled_from([8, 24]),
       H=st.sampled_from([1, 2, 4]), kv_ratio=st.sampled_from([1, 2]),
       window=st.sampled_from([0, 8]))
@settings(**SETTINGS)
def test_flash_equals_reference(B, S, H, kv_ratio, window):
    Hkv = max(1, H // kv_ratio)
    H = Hkv * kv_ratio
    D = 16
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(S * H + B), 3)
    q = jax.random.normal(k1, (B, S, H, D))
    k = jax.random.normal(k2, (B, S, Hkv, D))
    v = jax.random.normal(k3, (B, S, Hkv, D))
    pos = jnp.arange(S)
    want = ref.attention(q, k, v, q_pos=pos, kv_pos=pos, window=window)
    got = ops.flash_attention(q, k, v, q_pos=pos, kv_pos=pos, window=window,
                              block_q=8, block_kv=8, backend="xla")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# Selective scan: linearity in x (fixed gates) and state composition
# ---------------------------------------------------------------------------

@given(seed=st.integers(0, 100))
@settings(**SETTINGS)
def test_selective_scan_linear_in_x(seed):
    B, S, Di, N = 1, 8, 16, 4
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    dt = jax.nn.softplus(jax.random.normal(ks[0], (B, S, Di))) * 0.2
    A = -jnp.exp(jax.random.normal(ks[1], (Di, N)) * 0.3)
    Bm = jax.random.normal(ks[2], (B, S, N)) * 0.5
    C = jax.random.normal(ks[3], (B, S, N)) * 0.5
    D = jnp.zeros((Di,))
    x1 = jax.random.normal(ks[4], (B, S, Di))
    x2 = jax.random.normal(ks[5], (B, S, Di))
    y1, _ = ref.selective_scan(x1, dt, A, Bm, C, D)
    y2, _ = ref.selective_scan(x2, dt, A, Bm, C, D)
    y12, _ = ref.selective_scan(x1 + x2, dt, A, Bm, C, D)
    np.testing.assert_allclose(np.asarray(y12), np.asarray(y1 + y2),
                               atol=1e-4, rtol=1e-3)


@given(seed=st.integers(0, 100), split=st.integers(1, 7))
@settings(**SETTINGS)
def test_selective_scan_composes_over_time(seed, split):
    """scan(x) == scan(x[t:], h0=scan(x[:t]).h) — the decode invariant."""
    B, S, Di, N = 1, 8, 16, 4
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (B, S, Di)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, Di))) * 0.2
    A = -jnp.exp(jax.random.normal(ks[2], (Di, N)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, N)) * 0.5
    C = jax.random.normal(ks[4], (B, S, N)) * 0.5
    D = jnp.ones((Di,))
    y_all, h_all = ref.selective_scan(x, dt, A, Bm, C, D)
    _, h1 = ref.selective_scan(x[:, :split], dt[:, :split], A, Bm[:, :split],
                               C[:, :split], D)
    y2, h2 = ref.selective_scan(x[:, split:], dt[:, split:], A, Bm[:, split:],
                                C[:, split:], D, h0=h1)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_all),
                               atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y_all[:, split:]),
                               atol=1e-4, rtol=1e-3)


# ---------------------------------------------------------------------------
# HFSL FedAvg algebra
# ---------------------------------------------------------------------------

@given(n=st.integers(2, 6), seed=st.integers(0, 50))
@settings(**SETTINGS)
def test_fedavg_permutation_invariant(n, seed):
    k = jax.random.PRNGKey(seed)
    tree = {"a": jax.random.normal(k, (n, 4, 3)),
            "b": jax.random.normal(k, (n, 2))}
    perm = jax.random.permutation(k, n)
    avg1 = hfsl.fedavg(tree)
    avg2 = hfsl.fedavg(jax.tree.map(lambda x: x[perm], tree))
    for l1, l2 in zip(jax.tree.leaves(avg1), jax.tree.leaves(avg2)):
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-5)


# ---------------------------------------------------------------------------
# Scheduler: DP optimality
# ---------------------------------------------------------------------------

@given(demand=st.lists(st.integers(0, 2), min_size=3, max_size=8),
       seed=st.integers(0, 20))
@settings(**SETTINGS)
def test_mlcp_beats_any_random_policy(demand, seed):
    env = scheduler.SchedulerEnv(demand=tuple(demand))
    best = scheduler.total_profit(
        scheduler.run_policy(env, scheduler.mlcp_policy(env)))
    rand = scheduler.total_profit(
        scheduler.run_policy(env, scheduler.rs_policy(env, seed)))
    greedy = scheduler.total_profit(
        scheduler.run_policy(env, scheduler.msip_policy(env)))
    assert best >= rand and best >= greedy


# ---------------------------------------------------------------------------
# Data partitioners
# ---------------------------------------------------------------------------

@given(n_clients=st.integers(1, 6), cpc=st.integers(1, 5),
       seed=st.integers(0, 10))
@settings(**SETTINGS)
def test_class_partition_disjoint_and_class_limited(n_clients, cpc, seed):
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 5, size=300)
    parts = partition_by_classes(labels, n_clients, cpc, seed=seed)
    seen = set()
    for p in parts:
        assert len(set(p.tolist()) & seen) == 0       # disjoint
        seen |= set(p.tolist())
        if len(p):
            assert len(np.unique(labels[p])) <= cpc   # class-limited


@given(alpha=st.floats(0.05, 10.0), seed=st.integers(0, 10))
@settings(**SETTINGS)
def test_dirichlet_partition_covers_everything(alpha, seed):
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 4, size=200)
    parts = dirichlet_partition(labels, 4, alpha, seed=seed)
    allidx = np.concatenate(parts)
    assert len(allidx) == 200 and len(np.unique(allidx)) == 200


# ---------------------------------------------------------------------------
# MoE capacity + sharding fit
# ---------------------------------------------------------------------------

@given(T=st.integers(8, 4096))
@settings(**SETTINGS)
def test_capacity_bounds(T):
    cfg = get_config("granite-moe-1b-a400m")
    c = capacity(T, cfg)
    m = cfg.moe
    assert c * m.n_experts >= T * m.top_k        # cf>=1 => no forced drops
    assert c % 8 == 0


@given(dims=st.lists(st.sampled_from([1, 2, 7, 8, 16, 24, 32, 40, 128]),
                     min_size=1, max_size=4))
@settings(**SETTINGS)
def test_fit_spec_always_divides(dims):
    mesh = AbstractMesh((2, 16, 16), ("pod", "data", "model"))
    cand = [None, "model", ("pod", "data"), "data"]
    spec = P(*(cand[i % len(cand)] for i in range(len(dims))))
    fitted = fit_spec(spec, tuple(dims), mesh)
    for i, entry in enumerate(fitted):
        if entry is None:
            continue
        axes = (entry,) if isinstance(entry, str) else entry
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        assert dims[i] % n == 0
    flat = [a for e in fitted if e
            for a in ((e,) if isinstance(e, str) else e)]
    assert len(flat) == len(set(flat))           # no duplicate mesh axes


# ---------------------------------------------------------------------------
# Paged KV block allocator (core/paged.py)
# ---------------------------------------------------------------------------

@given(data=st.data(), n_blocks=st.integers(4, 32))
@settings(**SETTINGS)
def test_block_allocator_invariants(data, n_blocks):
    """Any interleaving of alloc/acquire/free conserves the pool: free +
    used == n_blocks always, refcounts never go negative, alloc never
    hands out a live block twice, and the books balance (check())."""
    from repro.core.paged import BlockAllocator
    alloc = BlockAllocator(n_blocks, 4)
    live: list[list[int]] = []
    ops_n = data.draw(st.integers(1, 60))
    for _ in range(ops_n):
        op = data.draw(st.integers(0, 2))
        if op == 0:
            got = alloc.alloc(data.draw(st.integers(1, n_blocks)))
            if got is not None:
                # freshly allocated blocks are exclusively ours (rc == 1)
                assert all(alloc.refcount[b] == 1 for b in got)
                live.append(got)
        elif op == 1 and live:
            alloc.free(live.pop(data.draw(st.integers(0, len(live) - 1))))
        elif op == 2 and live:
            ids = live[data.draw(st.integers(0, len(live) - 1))]
            bid = ids[data.draw(st.integers(0, len(ids) - 1))]
            alloc.acquire(bid)
            alloc.free([bid])
        assert all(rc >= 0 for rc in alloc.refcount)
        assert alloc.free_blocks + alloc.used_blocks == n_blocks
        assert alloc.used_blocks == len({b for ids in live for b in ids})
        alloc.check()
    for ids in live:
        alloc.free(ids)
    assert alloc.used_blocks == 0
    alloc.check()


@given(st.lists(st.integers(0, 7), min_size=1, max_size=40),
       st.integers(1, 3))
@settings(**SETTINGS)
def test_block_allocator_prefix_match_is_exact(tokens, bs_pow):
    """register + match_prefix round-trip: a registered prompt's full
    blocks always match themselves, any extension matches the registered
    prefix, and a first-block mismatch matches nothing."""
    from repro.core.paged import BlockAllocator, block_hashes
    bs = 2 ** bs_pow
    alloc = BlockAllocator(32, bs)
    n_full = len(tokens) // bs
    ids = alloc.alloc(max(n_full, 1))
    assert ids is not None
    alloc.register(tokens, ids)
    got_ids, got_n = alloc.match_prefix(list(tokens) + [1, 2, 3])
    assert got_n == n_full and got_ids == ids[:n_full]
    if n_full:
        flipped = [tokens[0] ^ 1] + list(tokens[1:])
        assert alloc.match_prefix(flipped)[1] == 0
        assert len(block_hashes(tokens, bs)) == n_full
    alloc.free(ids)
    alloc.check()


@given(st.integers(2, 16))
@settings(**SETTINGS)
def test_block_allocator_double_free_raises(n_blocks):
    from repro.core.paged import BlockAllocator
    alloc = BlockAllocator(n_blocks, 4)
    ids = alloc.alloc(n_blocks // 2 or 1)
    alloc.free(ids)
    with pytest.raises(RuntimeError):
        alloc.free(ids)
