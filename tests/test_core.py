"""Core-module unit tests: PEFT, HFSL, relay, scheduler, comm."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core import comm, hfsl, peft, relay, scheduler
from repro.core.sl_pipeline import simulate_sl
from repro.models import model as M
from repro.optim.optimizers import adamw, apply_updates, sgd

KEY = jax.random.PRNGKey(0)


def small_cfg():
    cfg = get_config("vit-edge").reduced().with_(dtype="float32")
    return cfg.with_(peft=dataclasses.replace(cfg.peft, head_dim_out=5))


# ---------------------------------------------------------------------------
# PEFT
# ---------------------------------------------------------------------------

class TestPEFT:
    def test_trainable_fraction_is_small(self):
        cfg = get_config("qwen2-7b")          # full-size spec, no init needed
        from repro.sharding.rules import param_bytes
        from repro.models.model import adapter_spec, backbone_spec
        a = param_bytes(adapter_spec(cfg))
        b = param_bytes(backbone_spec(cfg))
        assert a / (a + b) < 0.01             # the paper's "<1%" claim

    def test_grads_only_on_adapters(self):
        cfg = small_cfg()
        params = M.init(cfg, KEY)
        batch = {"tokens": jnp.ones((2, 8), jnp.int32),
                 "label": jnp.zeros((2,), jnp.int32)}
        vg = peft.peft_value_and_grad(M.classify_loss)
        (loss, aux), grads = vg(params, batch, cfg)
        assert set(grads) == {"adapters"}
        assert np.isfinite(float(loss))

    def test_full_ft_mode(self):
        cfg = small_cfg()
        params = M.init(cfg, KEY)
        batch = {"tokens": jnp.ones((2, 8), jnp.int32),
                 "label": jnp.zeros((2,), jnp.int32)}
        vg = peft.peft_value_and_grad(M.classify_loss, trainable="all")
        (_, _), grads = vg(params, batch, cfg)
        assert set(grads) == {"adapters", "backbone"}

    def test_lora_merge_preserves_forward(self):
        cfg = small_cfg()
        params = M.init(cfg, KEY)
        # give LoRA b nonzero values so the merge is non-trivial
        stack = params["adapters"]["stack"]
        for g in stack.values():
            for s in g.values():
                for ab in s.get("lora", {}).values():
                    ab["b"] = jax.random.normal(KEY, ab["b"].shape,
                                                ab["b"].dtype) * 0.02
        batch = {"tokens": jnp.ones((2, 8), jnp.int32)}
        before = M.forward(params, batch, cfg, mode="eval", remat=False)["logits"]
        merged = peft.merge_lora_into_backbone(params, cfg)
        after = M.forward(merged, batch, cfg, mode="eval", remat=False)["logits"]
        np.testing.assert_allclose(np.asarray(before), np.asarray(after),
                                   atol=2e-4, rtol=2e-4)


# ---------------------------------------------------------------------------
# HFSL
# ---------------------------------------------------------------------------

class TestHFSL:
    def _state(self, n=3):
        cfg = small_cfg()
        opt = sgd(0.1)
        return cfg, opt, hfsl.init_hfsl_state(KEY, cfg, n, opt, M.init)

    def test_fedavg_is_mean_and_idempotent(self):
        _, _, state = self._state()
        a = jax.tree.map(
            lambda x: x + jnp.arange(3, dtype=x.dtype).reshape(
                3, *([1] * (x.ndim - 1))), state["adapters_c"])
        avg = hfsl.fedavg(a)
        for leaf, orig in zip(jax.tree.leaves(avg), jax.tree.leaves(a)):
            np.testing.assert_allclose(
                np.asarray(leaf[0], np.float32),
                np.asarray(jnp.mean(orig.astype(jnp.float32), 0)), rtol=1e-5)
        avg2 = hfsl.fedavg(avg)
        for l1, l2 in zip(jax.tree.leaves(avg), jax.tree.leaves(avg2)):
            np.testing.assert_allclose(np.asarray(l1, np.float32),
                                       np.asarray(l2, np.float32), rtol=1e-5)

    def test_sync_every_controls_divergence(self):
        cfg, opt, state = self._state()
        batch = {
            "tokens": jax.random.randint(KEY, (3, 4, 8), 0, cfg.vocab_size),
            "label": jnp.asarray([[0] * 4, [1] * 4, [2] * 4], jnp.int32),
        }
        nosync = hfsl.make_hfsl_step(cfg, opt, M.classify_loss, sync_every=10)
        s1, _ = nosync(state, batch)
        replicas = s1["adapters_c"]["head"]["w"]
        spread = float(jnp.max(jnp.std(replicas.astype(jnp.float32), axis=0)))
        assert spread > 0.0                      # clusters diverged
        sync = hfsl.make_hfsl_step(cfg, opt, M.classify_loss, always_sync=True)
        s2, _ = sync(state, batch)
        replicas = s2["adapters_c"]["head"]["w"]
        spread = float(jnp.max(jnp.std(replicas.astype(jnp.float32), axis=0)))
        assert spread < 1e-6                     # FedAvg re-synchronized

    def test_single_cluster_degenerates_to_sl(self):
        """Paper §III-C.1: one cluster => HFSL == plain (split) training."""
        cfg = small_cfg()
        opt = sgd(0.1)
        state = hfsl.init_hfsl_state(KEY, cfg, 1, opt, M.init)
        batch = {"tokens": jax.random.randint(KEY, (1, 4, 8), 0, cfg.vocab_size),
                 "label": jnp.zeros((1, 4), jnp.int32)}
        step = hfsl.make_hfsl_step(cfg, opt, M.classify_loss, always_sync=True)
        s1, m = step(state, batch)
        # reference: plain PEFT step on the same data
        params = {"backbone": state["backbone"],
                  "adapters": jax.tree.map(lambda x: x[0], state["adapters_c"])}
        vg = peft.peft_value_and_grad(M.classify_loss)
        (_, _), grads = vg(params, {k: v[0] for k, v in batch.items()}, cfg)
        manual = apply_updates(
            params["adapters"],
            jax.tree.map(lambda g: -0.1 * g, grads["adapters"]))
        got = jax.tree.map(lambda x: x[0], s1["adapters_c"])
        for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(manual)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       atol=1e-5, rtol=1e-4)

    def test_sync_bytes_positive(self):
        _, _, state = self._state()
        assert hfsl.sync_bytes(state["adapters_c"]) > 0


# ---------------------------------------------------------------------------
# Knowledge relay
# ---------------------------------------------------------------------------

class TestRelay:
    def test_bidirectional_flow_and_ledger(self):
        cfg = small_cfg()
        adapters = M.init(cfg, KEY)["adapters"]
        r = relay.KnowledgeRelay(adapters, ["nlp", "cv"])
        r.cloud_deliver("nlp")
        base = peft.tree_bytes(adapters)
        assert r.ledger.cloud_to_edge == base
        # clusters return updated adapters -> edge aggregates
        ups = [jax.tree.map(lambda x: x + i, adapters) for i in (1.0, 3.0)]
        agg = r.edge_absorb("nlp", ups)
        np.testing.assert_allclose(
            np.asarray(jax.tree.leaves(agg)[0], np.float32),
            np.asarray(jax.tree.leaves(adapters)[0].astype(jnp.float32) + 2.0),
            rtol=1e-5)
        # domain-across flow back to the cloud
        r.cloud_aggregate()
        assert r.cloud_version == 1
        assert r.ledger.edge_to_cloud == 2 * base
        assert r.ledger.total() > 0 and r.cost.latency_s > 0

    def test_data_free_property(self):
        """Only adapter-shaped pytrees cross tiers: the ledger equals
        adapter bytes exactly (no activations/labels accounted)."""
        cfg = small_cfg()
        adapters = M.init(cfg, KEY)["adapters"]
        r = relay.KnowledgeRelay(adapters, ["d"])
        r.edge_deliver("d", n_clusters=4)
        assert r.ledger.edge_to_end == 4 * peft.tree_bytes(adapters)


# ---------------------------------------------------------------------------
# Scheduler (paper Table V / Fig 8)
# ---------------------------------------------------------------------------

class TestScheduler:
    def test_table_v_exact(self):
        env = scheduler.paper_env()
        mlcp = scheduler.run_policy(env, scheduler.mlcp_policy(env))
        msip = scheduler.run_policy(env, scheduler.msip_policy(env))
        assert scheduler.total_profit(mlcp) == 650
        assert scheduler.total_profit(msip) == 500
        # MLCP's published action trace: produce A, upgrade c twice, 7x C@100
        acts = [(r.action, r.profit) for r in mlcp]
        assert acts[0] == ("produce", 50)
        assert acts[1] == ("upgrade", -50) and acts[2] == ("upgrade", -50)
        assert all(a == ("produce", 100) for a in acts[3:])

    def test_mlcp_dominates(self):
        rng = np.random.default_rng(0)
        for _ in range(10):
            demand = tuple(rng.integers(0, 3, size=10).tolist())
            env = scheduler.SchedulerEnv(demand=demand)
            m = scheduler.total_profit(
                scheduler.run_policy(env, scheduler.mlcp_policy(env)))
            g = scheduler.total_profit(
                scheduler.run_policy(env, scheduler.msip_policy(env)))
            r = scheduler.total_profit(
                scheduler.run_policy(env, scheduler.rs_policy(env, 1)))
            assert m >= g >= r or m >= g          # DP is optimal

    def test_value_iteration_policy_runs(self):
        env = scheduler.paper_env()
        pol = scheduler.mlcp_value_iteration(env, [0.2, 0.1, 0.7])
        rec = scheduler.run_policy(env, pol)
        assert len(rec) == env.horizon


# ---------------------------------------------------------------------------
# Comm cost model
# ---------------------------------------------------------------------------

class TestComm:
    def test_sl_round_cost_scales_with_clients(self):
        cfg = get_config("vit-edge")
        cm = comm.CostModel()
        t2 = simulate_sl(cfg, 8, 32, 2, training=True)
        t8 = simulate_sl(cfg, 8, 32, 8, training=True)
        c2 = comm.sl_round_cost(t2, cm)
        c8 = comm.sl_round_cost(t8, cm)
        assert c8.comm_bytes > c2.comm_bytes          # more D2D hops
        assert abs(c8.compute_flops - c2.compute_flops) / c2.compute_flops < 0.1

    def test_inference_cheaper_than_training(self):
        cfg = get_config("vit-edge")
        cm = comm.CostModel()
        tr = comm.sl_round_cost(simulate_sl(cfg, 8, 32, 4, training=True), cm)
        inf = comm.sl_round_cost(simulate_sl(cfg, 8, 32, 4, training=False), cm)
        assert inf.latency_s < tr.latency_s
        assert inf.comm_bytes < tr.comm_bytes
        assert inf.energy_j < tr.energy_j

    def test_round_cost_add_covers_every_field(self):
        """RoundCost.__add__ must combine EVERY field, present and future:
        this test enumerates dataclasses.fields so a field appended to the
        dataclass but dropped by addition fails here immediately."""
        flds = dataclasses.fields(comm.RoundCost)
        a = comm.RoundCost(**{f.name: i + 1 for i, f in enumerate(flds)})
        b = comm.RoundCost(**{f.name: 10 * (i + 1)
                              for i, f in enumerate(flds)})
        c = a + b
        for i, f in enumerate(flds):
            got = getattr(c, f.name)
            if f.name in comm.RoundCost._MAX_FIELDS:
                # peak metrics max-reduce across rounds (memory high-water)
                assert got == 10 * (i + 1), f.name
            else:
                assert got == 11 * (i + 1), f.name
        # max-reduction is order-independent
        assert (b + a).memory_bytes == c.memory_bytes
