"""Serving-path invariants: prefill + decode == teacher-forced full forward;
scan generation == legacy per-token loop; flash-decode kernel == dense
cache-attention oracle."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.kernels import ops, ref
from repro.models import model as M

KEY = jax.random.PRNGKey(1)
B, S = 2, 12

ARCHS = ["qwen2-7b", "falcon-mamba-7b", "recurrentgemma-2b",
         "granite-moe-1b-a400m", "llava-next-mistral-7b", "whisper-small"]


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch):
    cfg = get_config(arch).reduced().with_(dtype="float32")
    if cfg.family == "moe":   # disable token dropping for exactness
        cfg = cfg.with_(moe=dataclasses.replace(cfg.moe, capacity_factor=-1.0))
    params = M.init(cfg, KEY)
    toks = jax.random.randint(KEY, (B, S + 1), 0, cfg.vocab_size)
    batch = {"tokens": toks[:, :S]}
    full = {"tokens": toks}
    n_vis = 0
    if cfg.family == "vlm":
        vis = jax.random.normal(KEY, (B, cfg.vlm.n_vis_tokens, cfg.d_model)) * 0.1
        batch["vision_embeds"] = vis
        full["vision_embeds"] = vis
        n_vis = cfg.vlm.n_vis_tokens
    if cfg.family == "audio":
        fr = jax.random.normal(KEY, (B, cfg.audio.n_audio_frames, cfg.d_model)) * 0.1
        batch["frames"] = fr
        full["frames"] = fr

    logits_pf, caches = M.prefill(params, batch, cfg, max_len=S + n_vis + 8)
    logits_dec, _ = M.decode_step(params, toks[:, S:S + 1], caches,
                                  jnp.asarray(S + n_vis, jnp.int32), cfg)
    ref = M.forward(params, full, cfg, mode="eval", remat=False)["logits"]
    np.testing.assert_allclose(np.asarray(logits_pf),
                               np.asarray(ref[:, -2:-1]), atol=3e-4, rtol=3e-4)
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(ref[:, -1:]), atol=3e-4, rtol=3e-4)


def test_multi_step_decode_matches_forward():
    """Five sequential decode steps stay consistent (cache reuse)."""
    cfg = get_config("qwen2-7b").reduced().with_(dtype="float32")
    params = M.init(cfg, KEY)
    T = 5
    toks = jax.random.randint(KEY, (B, S + T), 0, cfg.vocab_size)
    _, caches = M.prefill(params, {"tokens": toks[:, :S]}, cfg,
                          max_len=S + T + 1)
    ref = M.forward(params, {"tokens": toks}, cfg, mode="eval",
                    remat=False)["logits"]
    for t in range(T):
        logits, caches = M.decode_step(params, toks[:, S + t:S + t + 1],
                                       caches, jnp.asarray(S + t, jnp.int32),
                                       cfg)
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(ref[:, S + t:S + t + 1]),
                                   atol=3e-4, rtol=3e-4)


def test_sliding_window_decode_rolls_over():
    """Decode past the window: rolling cache matches full forward."""
    cfg = get_config("llava-next-mistral-7b").reduced().with_(
        dtype="float32", sliding_window=8)
    cfg = cfg.with_(vlm=dataclasses.replace(cfg.vlm, n_vis_tokens=4))
    params = M.init(cfg, KEY)
    T = 6                                  # S=12 > window=8, then 6 more
    toks = jax.random.randint(KEY, (B, S + T), 0, cfg.vocab_size)
    vis = jax.random.normal(KEY, (B, 4, cfg.d_model)) * 0.1
    _, caches = M.prefill(params, {"tokens": toks[:, :S],
                                   "vision_embeds": vis}, cfg)
    ref = M.forward(params, {"tokens": toks, "vision_embeds": vis}, cfg,
                    mode="eval", remat=False)["logits"]
    n_vis = 4
    for t in range(T):
        logits, caches = M.decode_step(params, toks[:, S + t:S + t + 1],
                                       caches,
                                       jnp.asarray(S + t + n_vis, jnp.int32),
                                       cfg)
        # full-forward logits carry the vision prefix: text token S+t sits
        # at index n_vis + S + t
        np.testing.assert_allclose(
            np.asarray(logits),
            np.asarray(ref[:, n_vis + S + t:n_vis + S + t + 1]),
            atol=3e-4, rtol=3e-4)


# ---------------------------------------------------------------------------
# Single-dispatch scan generation vs the legacy per-token loop
# ---------------------------------------------------------------------------

def _gen_setup(arch, seed=5):
    cfg = get_config(arch).reduced().with_(dtype="float32")
    params = M.init(cfg, KEY)
    key = jax.random.PRNGKey(seed)
    prompts = jax.random.randint(key, (B, S), 0, cfg.vocab_size,
                                 dtype=jnp.int32)
    extra = None
    if cfg.family == "vlm":
        extra = {"vision_embeds":
                 jax.random.normal(key, (B, cfg.vlm.n_vis_tokens,
                                         cfg.d_model)) * 0.1}
    return cfg, params, prompts, extra


@pytest.mark.parametrize("arch", ["qwen2-7b", "llava-next-mistral-7b"])
def test_generate_scan_matches_loop_greedy(arch):
    from repro.launch.serve import generate_loop
    cfg, params, prompts, extra = _gen_setup(arch)
    want = generate_loop(params, cfg, prompts, gen=6, extra_batch=extra)
    got = M.generate_scan(params, cfg, prompts, gen=6, extra_batch=extra)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_generate_scan_matches_loop_sampled():
    """Same key => identical samples (per-step key splits line up)."""
    from repro.launch.serve import generate_loop
    cfg, params, prompts, _ = _gen_setup("qwen2-7b")
    key = jax.random.PRNGKey(11)
    want = generate_loop(params, cfg, prompts, gen=8, greedy=False, key=key)
    got = M.generate_scan(params, cfg, prompts, gen=8, greedy=False, key=key)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# Flash-decode kernel vs dense cache-attention oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("Hq,Hkv", [(4, 4), (4, 2), (4, 1)])   # MHA + GQA
@pytest.mark.parametrize("window", [0, 8])
@pytest.mark.parametrize("n_prefix", [0, 3])
@pytest.mark.parametrize("backend", ["xla", "interpret"])
def test_flash_decode_matches_dense_reference(Hq, Hkv, window, n_prefix,
                                              backend):
    """Sweep causal / sliding-window / prefix-KV / GQA; the cache has
    unwritten (+1e9 sentinel) slots that must never be read."""
    Bq, T, D, written = 2, 40, 32, 30
    ks = jax.random.split(jax.random.PRNGKey(Hq * 10 + window + n_prefix), 5)
    q = jax.random.normal(ks[0], (Bq, Hq, D))
    k = jax.random.normal(ks[1], (Bq, T, Hkv, D))
    v = jax.random.normal(ks[2], (Bq, T, Hkv, D))
    kv_pos = jnp.where(jnp.arange(T) < written, jnp.arange(T), 10 ** 9)
    q_pos = jnp.asarray([written - 1, written - 8])      # per-row positions
    pk = pv = None
    kcat, vcat, pcat = k, v, kv_pos
    if n_prefix:
        pk = jax.random.normal(ks[3], (n_prefix, Hkv, D))
        pv = jax.random.normal(ks[4], (n_prefix, Hkv, D))
        kcat = jnp.concatenate(
            [jnp.broadcast_to(pk[None], (Bq, n_prefix, Hkv, D)), k], axis=1)
        vcat = jnp.concatenate(
            [jnp.broadcast_to(pv[None], (Bq, n_prefix, Hkv, D)), v], axis=1)
        pcat = jnp.concatenate([jnp.full((n_prefix,), -1), kv_pos])
    want = ref.decode_attention(q, kcat, vcat, q_pos=q_pos, kv_pos=pcat,
                                window=window)
    got = ops.flash_decode(q, k, v, q_pos=q_pos, kv_pos=kv_pos,
                           prefix_k=pk, prefix_v=pv, window=window,
                           block_kv=16, backend=backend)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-3, rtol=1e-3)


def test_flash_decode_noncausal_cross():
    """Cross-attention decode (audio): every encoder slot visible."""
    Bq, T, H, D = 2, 24, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    q = jax.random.normal(ks[0], (Bq, H, D))
    k = jax.random.normal(ks[1], (Bq, T, H, D))
    v = jax.random.normal(ks[2], (Bq, T, H, D))
    kv_pos = jnp.arange(T)
    want = ref.decode_attention(q, k, v, q_pos=5, kv_pos=kv_pos,
                                causal=False)
    for backend in ("xla", "interpret"):
        got = ops.flash_decode(q, k, v, q_pos=5, kv_pos=kv_pos,
                               causal=False, block_kv=8, backend=backend)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-3, rtol=1e-3)
