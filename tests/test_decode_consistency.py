"""Serving-path invariant: prefill + decode == teacher-forced full forward."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.models import model as M

KEY = jax.random.PRNGKey(1)
B, S = 2, 12

ARCHS = ["qwen2-7b", "falcon-mamba-7b", "recurrentgemma-2b",
         "granite-moe-1b-a400m", "llava-next-mistral-7b", "whisper-small"]


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch):
    cfg = get_config(arch).reduced().with_(dtype="float32")
    if cfg.family == "moe":   # disable token dropping for exactness
        cfg = cfg.with_(moe=dataclasses.replace(cfg.moe, capacity_factor=-1.0))
    params = M.init(cfg, KEY)
    toks = jax.random.randint(KEY, (B, S + 1), 0, cfg.vocab_size)
    batch = {"tokens": toks[:, :S]}
    full = {"tokens": toks}
    n_vis = 0
    if cfg.family == "vlm":
        vis = jax.random.normal(KEY, (B, cfg.vlm.n_vis_tokens, cfg.d_model)) * 0.1
        batch["vision_embeds"] = vis
        full["vision_embeds"] = vis
        n_vis = cfg.vlm.n_vis_tokens
    if cfg.family == "audio":
        fr = jax.random.normal(KEY, (B, cfg.audio.n_audio_frames, cfg.d_model)) * 0.1
        batch["frames"] = fr
        full["frames"] = fr

    logits_pf, caches = M.prefill(params, batch, cfg, max_len=S + n_vis + 8)
    logits_dec, _ = M.decode_step(params, toks[:, S:S + 1], caches,
                                  jnp.asarray(S + n_vis, jnp.int32), cfg)
    ref = M.forward(params, full, cfg, mode="eval", remat=False)["logits"]
    np.testing.assert_allclose(np.asarray(logits_pf),
                               np.asarray(ref[:, -2:-1]), atol=3e-4, rtol=3e-4)
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(ref[:, -1:]), atol=3e-4, rtol=3e-4)


def test_multi_step_decode_matches_forward():
    """Five sequential decode steps stay consistent (cache reuse)."""
    cfg = get_config("qwen2-7b").reduced().with_(dtype="float32")
    params = M.init(cfg, KEY)
    T = 5
    toks = jax.random.randint(KEY, (B, S + T), 0, cfg.vocab_size)
    _, caches = M.prefill(params, {"tokens": toks[:, :S]}, cfg,
                          max_len=S + T + 1)
    ref = M.forward(params, {"tokens": toks}, cfg, mode="eval",
                    remat=False)["logits"]
    for t in range(T):
        logits, caches = M.decode_step(params, toks[:, S + t:S + t + 1],
                                       caches, jnp.asarray(S + t, jnp.int32),
                                       cfg)
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(ref[:, S + t:S + t + 1]),
                                   atol=3e-4, rtol=3e-4)


def test_sliding_window_decode_rolls_over():
    """Decode past the window: rolling cache matches full forward."""
    cfg = get_config("llava-next-mistral-7b").reduced().with_(
        dtype="float32", sliding_window=8)
    cfg = cfg.with_(vlm=dataclasses.replace(cfg.vlm, n_vis_tokens=4))
    params = M.init(cfg, KEY)
    T = 6                                  # S=12 > window=8, then 6 more
    toks = jax.random.randint(KEY, (B, S + T), 0, cfg.vocab_size)
    vis = jax.random.normal(KEY, (B, 4, cfg.d_model)) * 0.1
    _, caches = M.prefill(params, {"tokens": toks[:, :S],
                                   "vision_embeds": vis}, cfg)
    ref = M.forward(params, {"tokens": toks, "vision_embeds": vis}, cfg,
                    mode="eval", remat=False)["logits"]
    n_vis = 4
    for t in range(T):
        logits, caches = M.decode_step(params, toks[:, S + t:S + t + 1],
                                       caches,
                                       jnp.asarray(S + t + n_vis, jnp.int32),
                                       cfg)
        # full-forward logits carry the vision prefix: text token S+t sits
        # at index n_vis + S + t
        np.testing.assert_allclose(
            np.asarray(logits),
            np.asarray(ref[:, n_vis + S + t:n_vis + S + t + 1]),
            atol=3e-4, rtol=3e-4)
