"""Telemetry layer: histograms, spans, no-op discipline, engine lifecycle."""
import json
import math
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import telemetry
from repro.core.telemetry import (Histogram, Telemetry, _NOOP_SPAN,
                                  _GROWTH)
from repro.configs.base import get_config
from repro.launch.engine import DecodeEngine
from repro.models import model as M


# -- histogram --------------------------------------------------------------
@pytest.mark.parametrize("dist,args", [
    ("uniform", (1e-3, 1.0)),
    ("lognormal", (-5.0, 2.0)),
    ("exponential", (0.05,)),
])
def test_histogram_percentiles_match_numpy(dist, args):
    """Log-bucketed percentiles track exact numpy percentiles to within
    one geometric bucket step (~±15% relative error by construction)."""
    rng = np.random.default_rng(0)
    xs = getattr(rng, dist)(*args, size=20_000)
    xs = np.abs(xs) + 1e-9
    h = Histogram()
    for x in xs:
        h.record(float(x))
    assert h.n == len(xs)
    assert h.mean == pytest.approx(float(xs.mean()), rel=1e-6)
    assert h.vmin == pytest.approx(float(xs.min()))
    assert h.vmax == pytest.approx(float(xs.max()))
    for q in (50, 90, 95, 99):
        exact = float(np.percentile(xs, q))
        got = h.percentile(q)
        # one bucket step of relative slack either side
        assert exact / _GROWTH <= got <= exact * _GROWTH, \
            f"p{q}: exact={exact:.4g} hist={got:.4g}"


def test_histogram_multiplicity_and_clamping():
    h = Histogram()
    h.record(0.5, n=10)
    assert h.n == 10 and h.total == pytest.approx(5.0)
    # a single distinct value: every percentile collapses onto it exactly
    # (bucket midpoints are clamped into the observed [min, max])
    for q in (1, 50, 99, 100):
        assert h.percentile(q) == pytest.approx(0.5)
    h2 = Histogram()
    assert h2.percentile(99) == 0.0 and h2.summary()["count"] == 0


def test_histogram_summary_keys():
    h = Histogram()
    for v in (1e-4, 1e-3, 1e-2):
        h.record(v)
    s = h.summary()
    assert set(s) == {"count", "sum", "mean", "min", "max",
                      "p50", "p95", "p99"}
    assert s["min"] <= s["p50"] <= s["p95"] <= s["p99"] <= s["max"]


# -- spans ------------------------------------------------------------------
def test_span_nesting_depth_and_ordering():
    tel = Telemetry(enabled=True)
    with tel.span("outer", wave=1) as outer:
        with tel.span("inner"):
            time.sleep(0.002)
        outer.set(tokens=7)
    with tel.span("after"):
        pass
    names = [s.name for s in tel.spans]
    assert names == ["inner", "outer", "after"]   # exit order
    inner, outer, after = tel.spans
    assert inner.depth == 1 and outer.depth == 0 and after.depth == 0
    # the inner interval is enclosed by the outer one
    assert outer.t0 <= inner.t0
    assert inner.t0 + inner.dur <= outer.t0 + outer.dur + 1e-9
    assert outer.args == {"wave": 1, "tokens": 7}
    assert after.t0 >= outer.t0 + outer.dur - 1e-9


def test_record_span_external_interval():
    tel = Telemetry(enabled=True)
    t0 = time.perf_counter()
    t1 = t0 + 0.25
    tel.record_span("req", t0, t1, uid=3)
    (sp,) = tel.spans
    assert sp.dur == pytest.approx(0.25)
    assert sp.args == {"uid": 3}


def test_disabled_mode_is_a_true_noop():
    tel = Telemetry(enabled=False)
    # one shared context manager: no allocation per disabled span
    assert tel.span("a") is _NOOP_SPAN
    assert tel.span("b", x=1) is tel.span("c")
    with tel.span("a") as sp:
        sp.set(tokens=1)
    tel.count("c")
    tel.observe("h", 0.1)
    tel.gauge("g", 2.0)
    assert not tel.counters and not tel.hists
    assert not tel.gauges and not tel.spans
    assert tel.hist_summary("h") is None


def test_module_singleton_enable_disable():
    tel = telemetry.get()
    assert tel is telemetry.get()
    try:
        telemetry.enable()
        assert tel.enabled
        tel.count("x")
        assert tel.counters["x"] == 1
        telemetry.enable(fresh=True)               # reset on re-enable
        assert "x" not in tel.counters
    finally:
        telemetry.disable()
    assert not tel.enabled


# -- export -----------------------------------------------------------------
def test_trace_export_round_trip(tmp_path):
    tel = Telemetry(enabled=True)
    with tel.span("engine.segment", wave=np.int32(2), live=jnp.asarray(3)):
        time.sleep(0.001)
    tel.count("engine.tokens", 42)
    path = tmp_path / "trace.json"
    n = tel.export_trace(str(path))
    assert n == 1
    doc = json.loads(path.read_text())
    assert doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    seg = [e for e in evs if e["ph"] == "X"]
    assert len(seg) == 1 and seg[0]["name"] == "engine.segment"
    assert seg[0]["dur"] >= 1000                   # >= 1ms in microseconds
    assert seg[0]["cat"] == "engine"
    # numpy / jax scalars in span args must coerce to plain JSON numbers
    assert seg[0]["args"] == {"wave": 2.0, "live": 3.0}
    cnt = [e for e in evs if e["ph"] == "C"]
    assert cnt and cnt[0]["name"] == "engine.tokens"
    assert cnt[0]["args"]["value"] == 42


def test_metrics_export_and_snapshot(tmp_path):
    tel = Telemetry(enabled=True)
    tel.count("a", 2)
    tel.gauge("g", 1.5)
    tel.observe("lat", 0.01)
    path = tmp_path / "metrics.json"
    tel.export_metrics(str(path))
    snap = json.loads(path.read_text())
    assert snap["counters"] == {"a": 2}
    assert snap["gauges"] == {"g": 1.5}
    assert snap["histograms"]["lat"]["count"] == 1
    assert "a" in tel.report() and "lat" in tel.report()


# -- engine lifecycle -------------------------------------------------------
@pytest.fixture(scope="module")
def setup():
    cfg = get_config("vit-edge").reduced().with_(dtype="float32",
                                                 vocab_size=64)
    params = M.init(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_engine_lifecycle_metrics(setup):
    """A ragged drain books a coherent submit -> admit -> first-token ->
    retire lifecycle per request, and EngineStats summarizes it."""
    cfg, params = setup
    tel = Telemetry(enabled=True)
    key = jax.random.PRNGKey(3)
    engine = DecodeEngine(cfg, slots=3, tel=tel)
    short = np.asarray(jax.random.randint(key, (2, 8), 0, cfg.vocab_size))
    long = np.asarray(jax.random.randint(key, (2, 12), 0, cfg.vocab_size))
    budgets = [3, 6, 5, 2]
    for toks, g in zip([short[0], long[0], short[1], long[1]], budgets):
        engine.submit(toks, g)
    comps, stats = engine.run(params)
    assert len(comps) == 4
    for c in comps:
        assert c.queue_s >= 0
        assert c.ttft_s is not None and c.ttft_s >= c.queue_s
        assert c.latency_s >= c.ttft_s
        assert c.tok_s > 0
    # histogram summaries are always on (independent of telemetry state)
    assert stats.ttft_hist["count"] == 4
    assert stats.queue_hist["count"] == 4
    assert stats.tok_latency_hist["count"] == sum(budgets)
    assert stats.ttft_hist["p50"] <= stats.ttft_hist["p99"]
    # opt-in global spans: one lifecycle span per request, segments, drain
    by_name = {}
    for sp in tel.spans:
        by_name.setdefault(sp.name, []).append(sp)
    assert len(by_name["engine.request"]) == 4
    assert "engine.prefill" in by_name and "engine.segment" in by_name
    (drain,) = by_name["engine.drain"]
    assert drain.args["tokens"] == sum(budgets)
    assert tel.counters["engine.retired"] == 4


def test_engine_deadlines_survive_wall_clock_jump(setup, monkeypatch):
    """Deadline sweeps and latency ledgers anchor on time.perf_counter();
    a wall-clock step (NTP, suspend) must not spuriously retire requests
    or corrupt latencies."""
    cfg, params = setup
    jumped = time.time() + 3600.0
    monkeypatch.setattr(time, "time", lambda: jumped)
    engine = DecodeEngine(cfg, slots=2)
    prompts = np.asarray(jax.random.randint(
        jax.random.PRNGKey(4), (2, 8), 0, cfg.vocab_size, dtype=jnp.int32))
    for p in prompts:
        engine.submit(p, 3, deadline_s=300.0)    # generous monotonic budget
    comps, stats = engine.run(params)
    assert stats.timed_out == 0
    for c in comps:
        assert not c.timed_out
        assert c.tokens.shape == (3,)
        assert 0 <= c.latency_s < 300.0          # not an hour
        assert c.ttft_s is not None and 0 <= c.ttft_s <= c.latency_s
