"""Multi-tenant adapter serving: AdapterBank + mixed-domain engine waves.

The contract under test (ISSUE 3 acceptance): one DecodeEngine drain
serving requests from >= 3 domains in shared waves is token-for-token
equal to serving each domain alone with its merged params, and an
``AdapterBank.publish`` is visible to the very next wave (no stale reads).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.adapter_bank import AdapterBank
from repro.launch.engine import DecodeEngine
from repro.models import model as M

DOMAINS = ["nlp", "vision", "speech"]


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("vit-edge").reduced().with_(dtype="float32",
                                                 vocab_size=64)
    ks = jax.random.split(jax.random.PRNGKey(0), len(DOMAINS) + 1)
    adapters = {d: M.init(cfg, ks[i])["adapters"]
                for i, d in enumerate(DOMAINS)}
    backbone = M.init(cfg, ks[-1])["backbone"]
    return cfg, backbone, adapters


# ---------------------------------------------------------------------------
# Bank mechanics
# ---------------------------------------------------------------------------

def test_bank_publish_snapshot_roundtrip(setup):
    cfg, backbone, adapters = setup
    bank = AdapterBank.create(adapters)
    assert bank.n_slots == 3
    for d in DOMAINS:                       # create == publish of each input
        got, want = jax.tree.leaves(bank.snapshot(d)), \
            jax.tree.leaves(adapters[d])
        for g, w in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
    new = M.init(cfg, jax.random.PRNGKey(77))["adapters"]
    assert bank.version("vision") == 0
    bank.publish("vision", new)
    assert bank.version("vision") == 1
    for g, w in zip(jax.tree.leaves(bank.snapshot("vision")),
                    jax.tree.leaves(new)):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
    # other slots untouched
    for g, w in zip(jax.tree.leaves(bank.snapshot("nlp")),
                    jax.tree.leaves(adapters["nlp"])):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
    with pytest.raises(KeyError, match="no adapter slot"):
        bank.slot("unknown")


def test_bank_stacked_layout(setup):
    """'stack' leaves gain the slot dim AFTER the scanned layer dim (so the
    model's layer scan hands each layer the whole slot stack); other leaves
    are slot-leading."""
    cfg, _, adapters = setup
    bank = AdapterBank.create(adapters)
    one = jax.tree.leaves(adapters["nlp"]["stack"])[0]
    stacked = jax.tree.leaves(bank.stacked["stack"])[0]
    assert stacked.shape == (one.shape[0], 3, *one.shape[1:])
    head = bank.stacked["head"]["w"]
    assert head.shape == (3, *adapters["nlp"]["head"]["w"].shape)


# ---------------------------------------------------------------------------
# Mixed-domain engine waves
# ---------------------------------------------------------------------------

def test_mixed_domain_drain_matches_per_domain_serving(setup):
    """ONE drain, 3 domains interleaved across two prompt lengths, mixed
    max_new_tokens — token-for-token equal to per-domain engine drains."""
    cfg, backbone, adapters = setup
    bank = AdapterBank.create(adapters)
    key = jax.random.PRNGKey(5)
    short = np.asarray(jax.random.randint(key, (3, 8), 0, cfg.vocab_size))
    long = np.asarray(jax.random.randint(key, (3, 12), 0, cfg.vocab_size))
    reqs = [(short[0], "nlp", 4), (long[0], "vision", 3),
            (short[1], "speech", 5), (long[1], "nlp", 4),
            (short[2], "vision", 2), (long[2], "speech", 4)]

    engine = DecodeEngine(cfg, slots=4, bank=bank)
    uids = [engine.submit(t, g, domain=d) for t, d, g in reqs]
    comps, stats = engine.run(bank.serving_params(backbone))
    assert stats.requests == len(reqs)
    by_uid = {c.uid: c.tokens for c in comps}

    for uid, (toks, dom, gen) in zip(uids, reqs):
        single = DecodeEngine(cfg, slots=4)
        want, _ = single.serve(
            {"backbone": backbone, "adapters": adapters[dom]},
            toks[None], gen=gen)
        np.testing.assert_array_equal(by_uid[uid], want[0])


# the hybrid representative (attn + rglru state0 gathers) stays tier-1;
# the pure-ssm sweep is `slow` (same state-prompt gather path)
@pytest.mark.parametrize("arch", [
    pytest.param("falcon-mamba-7b", marks=pytest.mark.slow),
    "recurrentgemma-2b"])
def test_mixed_domain_parity_recurrent_families(arch):
    """State-prompt adapters (ssm/rglru state0) gather per-row too: mixed
    generation equals per-domain generation for SSM and hybrid stacks."""
    cfg = get_config(arch).reduced().with_(dtype="float32", vocab_size=64)
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    doms = {n: M.init(cfg, ks[i])["adapters"] for i, n in enumerate("abc")}
    backbone = M.init(cfg, ks[3])["backbone"]
    bank = AdapterBank.create(doms)
    prompts = jax.random.randint(ks[3], (3, 8), 0, cfg.vocab_size,
                                 dtype=jnp.int32)
    order = ["b", "c", "a"]
    mixed = np.asarray(M.generate_scan(
        bank.serving_params(backbone), cfg, prompts, gen=4,
        adapter_ids=bank.adapter_ids(order)))
    for i, d in enumerate(order):
        want = np.asarray(M.generate_scan(
            {"backbone": backbone, "adapters": doms[d]}, cfg,
            prompts[i:i + 1], gen=4))
        np.testing.assert_array_equal(mixed[i:i + 1], want)


def test_publish_serves_next_wave(setup):
    """A publish between drains must be served by the next wave — and must
    not disturb other tenants in the same wave."""
    cfg, backbone, adapters = setup
    bank = AdapterBank.create(adapters)
    engine = DecodeEngine(cfg, slots=2, bank=bank)
    prompts = np.asarray(jax.random.randint(
        jax.random.PRNGKey(9), (2, 10), 0, cfg.vocab_size))
    params = bank.serving_params(backbone)

    served0, _ = engine.serve(params, prompts, gen=4,
                              domains=["nlp", "vision"])
    new = M.init(cfg, jax.random.PRNGKey(123))["adapters"]
    bank.publish("vision", new)
    served1, _ = engine.serve(params, prompts, gen=4,
                              domains=["nlp", "vision"])
    want_new, _ = DecodeEngine(cfg, slots=2).serve(
        {"backbone": backbone, "adapters": new}, prompts[1:], gen=4)
    np.testing.assert_array_equal(served1[1], want_new[0])   # fresh read
    np.testing.assert_array_equal(served1[0], served0[0])    # nlp untouched


def test_engine_domain_validation(setup):
    cfg, backbone, adapters = setup
    with pytest.raises(ValueError, match="AdapterBank"):
        DecodeEngine(cfg, slots=2).submit(np.zeros(8, np.int32), 2,
                                          domain="nlp")
    bank = AdapterBank.create(adapters)
    engine = DecodeEngine(cfg, slots=2, bank=bank)
    with pytest.raises(ValueError, match="no adapter slot"):
        engine.submit(np.zeros(8, np.int32), 2, domain="nope")
    # all-or-none tenancy is enforced AT SUBMIT (the offending request is
    # rejected; already-queued requests are not poisoned)
    engine.submit(np.zeros(8, np.int32), 2, domain="nlp")
    with pytest.raises(ValueError, match="carry a domain"):
        engine.submit(np.zeros(8, np.int32), 2)              # tenant-less
    with pytest.raises(ValueError, match="carry a domain"):
        engine.submit(np.zeros(12, np.int32), 2)             # other length
    assert engine.pending() == 1                             # queue intact
    comps, _ = engine.run(bank.serving_params(backbone))
    assert len(comps) == 1
    # and symmetrically: tenant-less first, domain-carrying rejected
    engine.submit(np.zeros(8, np.int32), 2)
    with pytest.raises(ValueError, match="carry a domain"):
        engine.submit(np.zeros(8, np.int32), 2, domain="nlp")
    engine._queue.clear()
    # serve(domains=) must cover every prompt
    with pytest.raises(ValueError, match="per prompt"):
        engine.serve(bank.serving_params(backbone),
                     np.zeros((2, 8), np.int32), gen=2, domains=["nlp"])


# ---------------------------------------------------------------------------
# Integrated runtime: mixed-domain produce + upgrade hot-publish
# ---------------------------------------------------------------------------

def test_integrated_mixed_produce_and_hot_publish():
    from repro.core.integrated import IntegratedRuntime
    from repro.data.synthetic import ClassificationTask
    cfg = get_config("vit-edge").reduced().with_(dtype="float32",
                                                 vocab_size=64)
    cfg = cfg.with_(peft=dataclasses.replace(cfg.peft, head_dim_out=5))
    tasks = {n: ClassificationTask(5, 64, 24, class_strength=0.6, seed=s)
             for n, s in [("nlp", 0), ("cv", 7), ("sp", 13)]}
    rt = IntegratedRuntime(cfg, tasks, n_clusters=2, steps_per_upgrade=2,
                           serve_batch=9, serve_gen=3, serve_slots=4, seed=0)
    # mixed-domain round: >= 3 domains, ONE engine drain, full token ledger
    profit, cost = rt.produce(["nlp", "cv", "sp"])
    assert 0.0 <= profit <= rt.profit_scale
    assert cost.tokens == 9 * 3
    assert cost.tok_per_s > 0
    # upgrade hot-publishes into the bank (versioned, serve-ready)
    v0 = rt.bank.version("nlp")
    rt.upgrade("nlp")
    assert rt.bank.version("nlp") == v0 + 1
    # the bank slot IS the consensus of the trained state
    for g, w in zip(jax.tree.leaves(rt.bank.snapshot("nlp")),
                    jax.tree.leaves(rt._consensus_adapters("nlp"))):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   atol=1e-6, rtol=1e-6)


# ---------------------------------------------------------------------------
# Relay -> bank routing
# ---------------------------------------------------------------------------

def test_relay_routes_through_bank(setup):
    from repro.core import relay
    cfg, _, adapters = setup
    bank = AdapterBank.create(adapters)
    r = relay.KnowledgeRelay(adapters["nlp"], DOMAINS, bank=bank)
    # attach seeds serving from relay state (relay stays authoritative)
    for d in DOMAINS:
        for g, w in zip(jax.tree.leaves(bank.snapshot(d)),
                        jax.tree.leaves(r.edges[d])):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
    ups = [jax.tree.map(lambda x: x + 1.0, adapters["nlp"]),
           jax.tree.map(lambda x: x + 3.0, adapters["nlp"])]
    v0 = bank.version("vision")                # 1: the attach-time seed
    agg = r.edge_absorb("vision", ups)
    # relay versions stay the logical authority; the bank's counter is a
    # monotonic publish count (other writers may also publish)
    assert r.edge_versions["vision"] == 1
    assert bank.version("vision") == v0 + 1
    for g, w in zip(jax.tree.leaves(bank.snapshot("vision")),
                    jax.tree.leaves(agg)):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
    nb0 = r.ledger.total()
    r.cloud_deliver("speech")                  # deliver also publishes
    assert r.ledger.total() > nb0
    for g, w in zip(jax.tree.leaves(bank.snapshot("speech")),
                    jax.tree.leaves(r.cloud)):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
