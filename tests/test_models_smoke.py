"""Per-architecture smoke tests (deliverable f).

Every assigned architecture instantiates a REDUCED variant of its family
(2-3 layers, d_model<=256, <=4 experts) and runs one forward/train step on
CPU, asserting output shapes and no NaNs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import INPUT_SHAPES, get_config
from repro.models import model as M

ARCHS = [
    "falcon-mamba-7b", "kimi-k2-1t-a32b", "recurrentgemma-2b", "qwen2-7b",
    "llava-next-mistral-7b", "qwen1.5-32b", "qwen2.5-32b", "qwen2.5-14b",
    "granite-moe-1b-a400m", "whisper-small",
]

KEY = jax.random.PRNGKey(0)
B, S = 2, 16


def make_batch(cfg, with_labels=True):
    batch = {"tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["vision_embeds"] = jnp.zeros(
            (B, cfg.vlm.n_vis_tokens, cfg.d_model), jnp.dtype(cfg.dtype))
    if cfg.family == "audio":
        batch["frames"] = jnp.zeros(
            (B, cfg.audio.n_audio_frames, cfg.d_model), jnp.dtype(cfg.dtype))
    if with_labels:
        batch["labels"] = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_forward_shapes_and_finite(arch):
    cfg = get_config(arch).reduced()
    assert cfg.n_layers <= 3 and cfg.d_model <= 512
    if cfg.family == "moe":
        assert cfg.moe.n_experts <= 4
    params = M.init(cfg, KEY)
    out = M.forward(params, make_batch(cfg, False), cfg, mode="eval",
                    remat=False)
    S_total = S + (cfg.vlm.n_vis_tokens if cfg.family == "vlm" else 0)
    assert out["logits"].shape == (B, S_total, cfg.vocab_size)
    assert out["hidden"].shape == (B, S_total, cfg.d_model)
    assert not np.isnan(np.asarray(out["logits"], np.float32)).any()


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_train_step(arch):
    """One PEFT train step: loss finite, adapter grads finite & nonzero."""
    cfg = get_config(arch).reduced()
    params = M.init(cfg, KEY)
    batch = make_batch(cfg)

    def loss_fn(adapters):
        return M.lm_loss({"backbone": params["backbone"],
                          "adapters": adapters}, batch, cfg)[0]

    loss, grads = jax.value_and_grad(loss_fn)(params["adapters"])
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
             for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0.0


@pytest.mark.parametrize("arch", ["qwen2-7b", "falcon-mamba-7b",
                                  "recurrentgemma-2b", "whisper-small",
                                  "granite-moe-1b-a400m"])
def test_reduced_serve_step(arch):
    """Prefill + one decode step (the decode-shape code path) on CPU."""
    cfg = get_config(arch).reduced()
    params = M.init(cfg, KEY)
    batch = make_batch(cfg, with_labels=False)
    logits, caches = M.prefill(params, batch, cfg, max_len=S + 4)
    assert logits.shape == (B, 1, cfg.vocab_size)
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    logits2, caches = M.decode_step(params, tok, caches,
                                    jnp.asarray(S, jnp.int32), cfg)
    assert logits2.shape == (B, 1, cfg.vocab_size)
    assert not np.isnan(np.asarray(logits2, np.float32)).any()


def test_all_full_configs_registered_with_citations():
    for arch in ARCHS:
        cfg = get_config(arch)
        assert cfg.citation, arch
        assert cfg.param_count() > 0
