"""End-to-end behaviour tests for the GaisNet system.

The full paper loop on a reduced model: cloud pretraining -> edge delivery
-> HFSL fine-tuning across non-IID clusters -> FedAvg -> adapter-only
distribution -> serving. Assertions target the paper's qualitative claims.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import io as ckpt
from repro.configs.base import get_config
from repro.core import hfsl
from repro.core.peft import (peft_value_and_grad, trainable_fraction,
                             tree_bytes)
from repro.core.relay import KnowledgeRelay
from repro.data.noniid import partition_by_classes
from repro.data.pipeline import cluster_batches
from repro.data.synthetic import ClassificationTask
from repro.models import model as M
from repro.optim.optimizers import adamw, apply_updates


@pytest.fixture(scope="module")
def system():
    """Pretrained tiny FM + task (shared across tests; ~1 min)."""
    cfg = get_config("vit-edge").reduced().with_(dtype="float32",
                                                 vocab_size=64)
    cfg = cfg.with_(peft=dataclasses.replace(cfg.peft, head_dim_out=5))
    task = ClassificationTask(5, 64, 48, class_strength=0.6, seed=0)
    params = M.init(cfg, jax.random.PRNGKey(0))
    opt = adamw(3e-3)
    vg = peft_value_and_grad(M.lm_loss, trainable="all")
    state = opt.init(params)

    @jax.jit
    def step(p, s, b):
        (loss, _), grads = vg(p, b, cfg)
        updates, s = opt.update(grads, s, p)
        return apply_updates(p, updates), s, loss

    it = task.pretrain_stream(16)
    first = last = None
    for i in range(120):
        params, state, loss = step(params, state, next(it))
        if i == 0:
            first = float(loss)
    last = float(loss)
    return cfg, task, params, first, last


def test_pretraining_reduces_lm_loss(system):
    _, _, _, first, last = system
    assert last < first - 0.3, (first, last)


def test_trainable_fraction_below_one_percent(system):
    cfg, _, params, _, _ = system
    assert trainable_fraction(params) < 0.02      # reduced model; full: <1%


# 60 legacy one-dispatch-per-step HFSL steps (~40s): the convergence signal
# rides tier-1 via test_integrated::test_upgrade_improves_accuracy (fused
# round engine) and the FedAvg sync property via test_core::TestHFSL, so
# this exhaustive legacy-engine run is `slow`
@pytest.mark.slow
def test_hfsl_finetune_beats_chance_and_syncs(system):
    cfg, task, params, _, _ = system
    data = task.dataset(400, seed=1)
    parts = partition_by_classes(data["label"], 4, 5)
    it = cluster_batches(data, parts, 16)
    opt = adamw(5e-3)
    state = hfsl.init_hfsl_state(jax.random.PRNGKey(1), cfg, 4, opt,
                                 lambda c, k: params)
    step = jax.jit(hfsl.make_hfsl_step(cfg, opt, M.classify_loss,
                                       sync_every=5))
    for i in range(60):
        state, metrics = step(state, next(it))
    tuned = hfsl.consensus_params(state)
    evald = task.dataset(150, seed=2)
    logits = M.classify(tuned, {k: jnp.asarray(v) for k, v in evald.items()},
                        cfg)
    acc = float(jnp.mean((jnp.argmax(logits, -1) == evald["label"])))
    assert acc > 0.30, acc                         # chance = 0.20
    # a FedAvg output is replicated across clusters by construction
    synced = hfsl.fedavg(state["adapters_c"])
    for leaf in jax.tree.leaves(synced):
        np.testing.assert_allclose(np.asarray(leaf[0], np.float32),
                                   np.asarray(leaf[-1], np.float32),
                                   rtol=1e-6)


def test_relay_roundtrip_and_adapter_only_serving(system, tmp_path):
    cfg, task, params, _, _ = system
    relay = KnowledgeRelay(params["adapters"], ["domainA", "domainB"])
    relay.cloud_deliver("domainA")
    relay.edge_deliver("domainA", n_clusters=2)
    ups = [jax.tree.map(lambda x: x + 0.01, params["adapters"])
           for _ in range(2)]
    relay.edge_absorb("domainA", ups)
    relay.cloud_aggregate(["domainA"])
    assert relay.cloud_version == 1
    assert relay.ledger.total() > 0

    # parameter-efficient deployment: ship adapters only; the receiver holds
    # the synchronized frozen backbone (paper §III-B) + stale adapters
    p = str(tmp_path / "adapters")
    nb = ckpt.save_adapters(p, params)
    assert nb < tree_bytes(params["backbone"]) / 5
    stale = M.init(cfg, jax.random.PRNGKey(42))["adapters"]
    fresh = {"backbone": params["backbone"], "adapters": stale}
    restored = ckpt.load_adapters(p, fresh)
    batch = {"tokens": jnp.ones((2, 8), jnp.int32)}
    a = M.classify(params, batch, cfg)
    b = M.classify(restored, batch, cfg)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_serving_generation(system):
    cfg, _, params, _, _ = system
    from repro.launch.serve import generate
    prompts = jnp.ones((2, 8), jnp.int32)
    toks = generate(params, cfg, prompts, gen=4)
    assert toks.shape == (2, 4)
    assert ((0 <= np.asarray(toks)) & (np.asarray(toks) < cfg.vocab_size)).all()


def test_train_launcher_main_smoke(tmp_path):
    from repro.launch.train import main
    state = main(["--arch", "vit-edge", "--reduced", "--task", "classify",
                  "--clusters", "2", "--steps", "6", "--batch", "4",
                  "--seq", "16", "--log-every", "3",
                  "--ckpt", str(tmp_path / "ck")])
    assert (tmp_path / "ck.npz").exists()
    assert int(state["step"]) == 6
