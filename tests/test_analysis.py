"""tracelint: per-rule fixtures + the runtime compile sentinel.

Static half: every rule R1-R6 gets a good fixture (lints clean) and bad
fixtures asserting the exact code and line, including a simulated
``draft_k`` deletion applied to the REAL model.py source (the regression
the cache-key audit exists to catch) and a missing-oracle fake kernel
directory. Suppression (inline ignores, baseline round-trip, stale
entries) and the CLI exit codes are exercised end-to-end, plus the
shipped tree itself must lint clean.

Runtime half: ``compile_guard`` counts real XLA compilations, reports
zero on warm caches, raises ``CompileBudgetExceeded`` over budget, and
exports the telemetry counter.

Also here: regression tests for the R4 burn-down — the library asserts
tracelint flagged are now typed ValueErrors that survive ``python -O``.
"""
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import cli, kernel_contract
from repro.analysis.guards import (CompileBudgetExceeded, CompileLog,
                                   compile_guard)
from repro.core import telemetry

REPO = Path(__file__).resolve().parent.parent


def lint(src, *, library=True, path="src/repro/fixture.py"):
    return cli.lint_text(textwrap.dedent(src), path, library=library)


def codes(findings):
    return [f.code for f in findings]


# ---------------------------------------------------------------------------
# R1 — cache-key completeness
# ---------------------------------------------------------------------------

GOOD_FACTORY = """
    import functools
    import jax

    # tracelint: keys=cfg,cap,mesh
    @functools.lru_cache(maxsize=8)
    def _fused_fn(cfg, cap, mesh=None):
        def impl(params, batch):
            return params, batch, cfg, cap, mesh
        return jax.jit(impl)
"""


def test_r1_good_factory_is_clean():
    assert lint(GOOD_FACTORY) == []


def test_r1_missing_keys_annotation():
    fs = lint("""
        import functools
        import jax

        @functools.lru_cache(maxsize=8)
        def _fused_fn(cfg, cap):
            def impl(x):
                return x, cfg, cap
            return jax.jit(impl)
    """)
    assert codes(fs) == ["R1"]
    assert "missing its" in fs[0].message and "_fused_fn" in fs[0].message
    assert fs[0].line == 6                     # the def line


def test_r1_declared_key_missing_from_signature():
    """The draft_k regression shape: the annotation still declares the
    key but someone deleted the factory argument."""
    fs = lint("""
        import functools
        import jax

        # tracelint: keys=cfg,k
        @functools.lru_cache(maxsize=8)
        def _fused_fn(cfg):
            def impl(x):
                return x, cfg
            return jax.jit(impl)
    """)
    assert codes(fs) == ["R1"]
    assert "declared cache key 'k' is missing" in fs[0].message


def test_r1_spurious_factory_arg():
    fs = lint("""
        import functools
        import jax

        # tracelint: keys=cfg
        @functools.lru_cache(maxsize=8)
        def _fused_fn(cfg, debug_tag):
            def impl(x):
                return x, cfg
            return jax.jit(impl)
    """)
    assert codes(fs) == ["R1"]
    assert "'debug_tag'" in fs[0].message
    assert "not in the declared" in fs[0].message


def test_r1_closure_captured_trace_shaper():
    """A name the traced body loads that resolves to neither the cache
    key nor module scope shapes the trace without keying the cache."""
    fs = lint("""
        import functools
        import jax

        # tracelint: keys=cfg
        @functools.lru_cache(maxsize=8)
        def _fused_fn(cfg):
            def impl(x):
                return x[:steps], cfg
            return jax.jit(impl)
    """)
    assert codes(fs) == ["R1"]
    assert "'steps'" in fs[0].message and "closure-captured" in fs[0].message
    assert fs[0].line == 9                     # the load, not the def


def test_r1_nested_factory_exempt():
    """A nested lru_cache is recreated per enclosing call (the
    scheduler's DP-table pattern): closure capture there is scoped by
    construction and must NOT be flagged."""
    fs = lint("""
        import functools
        import jax

        def mlcp_policy(n):
            @functools.lru_cache(maxsize=None)
            def best(i):
                return i * n
            return best(0)
    """)
    assert fs == []


def test_r1_catches_draft_k_deletion_in_real_model_source():
    """Acceptance: delete ``k`` from model.py's _draft_fn factory and R1
    must fire — the stale keys= declaration AND the now-closure-captured
    ``k`` in the traced body are both reported."""
    src = (REPO / "src/repro/models/model.py").read_text()
    sig = "def _draft_fn(dcfg: ModelConfig, k: int, mesh=None):"
    assert sig in src                          # guard against drift
    bad = src.replace(sig, "def _draft_fn(dcfg: ModelConfig, mesh=None):")
    fs = [f for f in cli.lint_text(bad, "src/repro/models/model.py")
          if f.code == "R1"]
    msgs = " | ".join(f.message for f in fs)
    assert "declared cache key 'k' is missing" in msgs
    assert "closure-captured" in msgs
    # and the pristine source is clean
    assert cli.lint_text(src, "src/repro/models/model.py") == []


# ---------------------------------------------------------------------------
# R2 — host syncs in traced/hot scopes
# ---------------------------------------------------------------------------

def test_r2_item_in_jitted_body():
    fs = lint("""
        import jax

        @jax.jit
        def f(x):
            return x.item()
    """)
    assert codes(fs) == ["R2"]
    assert ".item()" in fs[0].message and fs[0].line == 6


def test_r2_np_asarray_in_scan_body():
    fs = lint("""
        import jax
        import numpy as np

        def outer(xs):
            def body(carry, x):
                v = np.asarray(x)
                return carry, v
            return jax.lax.scan(body, 0, xs)
    """)
    assert codes(fs) == ["R2"]
    assert "np.asarray" in fs[0].message and fs[0].line == 7


def test_r2_device_get_and_cast():
    fs = lint("""
        import jax

        @jax.jit
        def f(x):
            y = jax.device_get(x)
            return float(x) + y
    """)
    assert codes(fs) == ["R2", "R2"]
    assert "device_get" in fs[0].message
    assert "float()" in fs[1].message


def test_r2_literal_cast_not_flagged():
    fs = lint("""
        import jax

        @jax.jit
        def f(x):
            return x * float(2) * int(-3)
    """)
    assert fs == []


def test_r2_hot_path_flags_syncs_but_not_host_casts():
    """A `tracelint: hot` host loop: np.asarray is an unambiguous device
    sync (flagged); float()/int() is host bookkeeping (legal)."""
    fs = lint("""
        import numpy as np

        # tracelint: hot
        def drain(toks, n):
            a = np.asarray(toks)
            return int(n) + a.shape[0]
    """)
    assert codes(fs) == ["R2"]
    assert fs[0].line == 6


def test_r2_inline_ignore_suppresses():
    fs = lint("""
        import numpy as np

        # tracelint: hot
        def drain(toks):
            return np.asarray(toks)    # tracelint: ignore[R2] the one sync
    """)
    assert fs == []


def test_untraced_function_not_checked():
    """Plain host helpers may sync freely — no jit/scan/hot, no R2."""
    fs = lint("""
        import numpy as np

        def summarize(x):
            return np.asarray(x).mean(), x.item()
    """)
    assert fs == []


# ---------------------------------------------------------------------------
# R3 — trace-unsafe branching and wall clocks
# ---------------------------------------------------------------------------

def test_r3_branch_on_traced_value():
    fs = lint("""
        import jax

        def outer(xs):
            def body(carry, x):
                if x > 0:
                    carry = carry + 1
                return carry, x
            return jax.lax.scan(body, 0, xs)
    """)
    assert codes(fs) == ["R3"]
    assert "'x'" not in fs[0].message          # names are bare in the list
    assert "branch on traced value(s) x" in fs[0].message
    assert fs[0].line == 6


def test_r3_is_none_and_isinstance_guards_ok():
    fs = lint("""
        import jax

        @jax.jit
        def f(x, mask=None):
            if mask is None:
                return x
            if isinstance(x, tuple):
                return x[0]
            return x * mask
    """)
    assert fs == []


def test_r3_wall_clock_in_library():
    fs = lint("""
        import time

        def measure(fn):
            t0 = time.time()
            fn()
            return time.time() - t0
    """)
    assert codes(fs) == ["R3", "R3"]
    assert "perf_counter" in fs[0].message


def test_r3_wall_clock_ok_in_tests_and_with_ignore():
    src = """
        import time

        def measure(fn):
            t0 = time.time()
            fn()
            return time.time() - t0
    """
    assert lint(src, library=False, path="tests/fixture.py") == []
    fs = lint("""
        import time

        def epoch():
            return time.time()    # tracelint: ignore[R3] wall time IS the point
    """)
    assert fs == []


def test_r3_datetime_now_and_perf_counter():
    fs = lint("""
        import time
        from datetime import datetime

        def stamp():
            t = time.perf_counter()
            return datetime.now(), t
    """)
    assert codes(fs) == ["R3"]
    assert "datetime.now" in fs[0].message


# ---------------------------------------------------------------------------
# R4 — bare asserts in library code
# ---------------------------------------------------------------------------

def test_r4_bare_assert_library_only():
    src = """
        def check(x):
            assert x > 0, x
            return x
    """
    fs = lint(src)
    assert codes(fs) == ["R4"]
    assert fs[0].line == 3
    assert lint(src, library=False, path="tests/fixture.py") == []


# ---------------------------------------------------------------------------
# R5 — kernel triad contract (fake kernels dir)
# ---------------------------------------------------------------------------

OPS_GOOD = """
def _pick(b):
    return b or "xla"

def myop(x, backend=None):
    return _pick(backend)

def nopick(x, backend=None):
    return x

def nobackend(x):
    return x
"""

REF_GOOD = """
def myref(x):
    return x
"""


def _kernels_dir(tmp_path, kernel_src, *, ops=OPS_GOOD, ref=REF_GOOD):
    kd = tmp_path / "kernels"
    kd.mkdir()
    (kd / "ops.py").write_text(ops)
    (kd / "ref.py").write_text(ref)
    (kd / "fake_kernel.py").write_text(textwrap.dedent(kernel_src))
    return kd


def test_r5_good_registration(tmp_path):
    kd = _kernels_dir(tmp_path, """
        # tracelint: kernel-op=myop oracle=myref
        from jax.experimental import pallas as pl

        def run(x):
            return pl.pallas_call(None)(x)
    """)
    assert kernel_contract.check_kernels(kd) == []


def test_r5_unregistered_kernel_module(tmp_path):
    kd = _kernels_dir(tmp_path, """
        from jax.experimental import pallas as pl

        def run(x):
            return pl.pallas_call(None)(x)
    """)
    fs = kernel_contract.check_kernels(kd)
    assert codes(fs) == ["R5"]
    assert "no `tracelint:" in fs[0].message
    assert fs[0].line == 5                     # first pallas_call


def test_r5_missing_oracle(tmp_path):
    kd = _kernels_dir(tmp_path, """
        # tracelint: kernel-op=myop oracle=ghost
        from jax.experimental import pallas as pl

        def run(x):
            return pl.pallas_call(None)(x)
    """)
    fs = kernel_contract.check_kernels(kd)
    assert codes(fs) == ["R5"]
    assert "oracle ref.ghost does not exist" in fs[0].message


def test_r5_missing_dispatch_and_triad_violations(tmp_path):
    kd = _kernels_dir(tmp_path, """
        # tracelint: kernel-op=ghost oracle=myref
        # tracelint: kernel-op=nobackend oracle=myref
        # tracelint: kernel-op=nopick oracle=myref
        from jax.experimental import pallas as pl

        def run(x):
            return pl.pallas_call(None)(x)
    """)
    msgs = " | ".join(f.message for f in kernel_contract.check_kernels(kd))
    assert "ops.ghost does not exist" in msgs
    assert "no backend= parameter" in msgs
    assert "does not route through the _pick" in msgs


def test_r5_real_kernels_dir_is_registered():
    assert kernel_contract.check_kernels(REPO / "src/repro/kernels",
                                         rel_root=REPO) == []


# ---------------------------------------------------------------------------
# R6 — donation hazards
# ---------------------------------------------------------------------------

def test_r6_read_after_donation():
    fs = lint("""
        import jax

        step = jax.jit(lambda s: s, donate_argnums=(0,))

        def loop(state):
            out = step(state)
            return out, state.sum()
    """)
    assert codes(fs) == ["R6"]
    assert "'state'" in fs[0].message and "donated" in fs[0].message
    assert fs[0].line == 8


def test_r6_rebind_is_the_sanctioned_pattern():
    fs = lint("""
        import jax

        step = jax.jit(lambda s: s, donate_argnums=(0,))

        def loop(state, n):
            for _ in range(n):
                state = step(state)
            return state
    """)
    assert fs == []


# ---------------------------------------------------------------------------
# R0 — unknown directives; suppression + baseline + CLI end-to-end
# ---------------------------------------------------------------------------

def test_r0_unknown_directive():
    fs = lint("""
        # tracelint: keyz=cfg
        def f():
            return 1
    """)
    assert codes(fs) == ["R0"]
    assert "keyz=cfg" in fs[0].message


def _mk_repo(tmp_path):
    (tmp_path / "pyproject.toml").write_text("[project]\nname='fix'\n")
    (tmp_path / "src" / "repro").mkdir(parents=True)
    (tmp_path / "tests").mkdir()
    (tmp_path / "scripts").mkdir()
    return tmp_path


def test_cli_exit_codes_and_baseline_roundtrip(tmp_path, monkeypatch,
                                               capsys):
    root = _mk_repo(tmp_path)
    bad = root / "src" / "repro" / "mod.py"
    bad.write_text("def f(x):\n    assert x\n    return x\n")
    monkeypatch.chdir(root)

    assert cli.main([]) == 1                   # new finding -> gate fails
    out = capsys.readouterr().out
    assert "src/repro/mod.py:2 R4" in out
    assert "1 new finding(s)" in out

    assert cli.main(["--write-baseline"]) == 0
    assert cli.main([]) == 0                   # baselined -> gate passes
    out = capsys.readouterr().out
    assert "0 new finding(s), 1 baselined" in out

    assert cli.main(["--no-baseline"]) == 1    # still visible on demand

    bad.write_text("def f(x):\n    return x\n")
    assert cli.main([]) == 0                   # fixed -> stale entry noted
    out = capsys.readouterr().out
    assert "stale baseline entry" in out


def test_cli_syntax_error_is_a_finding(tmp_path, monkeypatch, capsys):
    root = _mk_repo(tmp_path)
    (root / "src" / "repro" / "mod.py").write_text("def f(:\n")
    monkeypatch.chdir(root)
    assert cli.main([]) == 1
    assert "R0 syntax error" in capsys.readouterr().out


def test_shipped_tree_lints_clean(monkeypatch, capsys):
    """Acceptance: `python -m repro.analysis` exits 0 on the repo, with
    an EMPTY baseline doing no work."""
    monkeypatch.chdir(REPO)
    assert cli.main([]) == 0
    out = capsys.readouterr().out
    assert "0 new finding(s), 0 baselined" in out


# ---------------------------------------------------------------------------
# compile_guard — the runtime sentinel
# ---------------------------------------------------------------------------

def test_compile_guard_counts_fresh_compile():
    x = jnp.ones((8,), jnp.float32)

    @jax.jit
    def fresh_fn_counts(v):
        return v * 2.0 + 1.0

    with compile_guard() as log:
        fresh_fn_counts(x).block_until_ready()
    assert log.count >= 1
    assert any("fresh_fn_counts" in n for n in log.names)


def test_compile_guard_zero_on_warm_cache():
    x = jnp.ones((8,), jnp.float32)

    @jax.jit
    def warm_fn(v):
        return v - 3.0

    warm_fn(x).block_until_ready()             # compile outside the guard
    with compile_guard(max_compiles=0) as log:
        warm_fn(x).block_until_ready()
    assert log.count == 0 and log.names == []


def test_compile_guard_budget_violation_names_the_culprit():
    x = jnp.ones((4,), jnp.float32)

    @jax.jit
    def busted_budget_fn(v):
        return v / 2.0

    with pytest.raises(CompileBudgetExceeded, match="busted_budget_fn"):
        with compile_guard(max_compiles=0):
            busted_budget_fn(x).block_until_ready()


def test_compile_guard_match_filter_and_telemetry_counter():
    x = jnp.ones((4,), jnp.float32)
    tel = telemetry.Telemetry()

    @jax.jit
    def matched_fn(v):
        return v + 7.0

    with compile_guard(match=r"matched_fn", tel=tel) as log:
        matched_fn(x).block_until_ready()
    assert log.names == ["matched_fn"]
    assert tel.counters["analysis.compiles"] == 1

    tel2 = telemetry.Telemetry()
    with compile_guard(match=r"no_such_name", tel=tel2) as log2:
        jax.jit(lambda v: v * 5.0)(x).block_until_ready()
    assert log2.count == 0
    assert tel2.counters["analysis.compiles"] == 0


def test_compile_guard_nests_and_restores_log_compiles():
    x = jnp.ones((4,), jnp.float32)
    assert isinstance(CompileLog().count, int)
    with compile_guard() as outer:
        with compile_guard() as inner:
            jax.jit(lambda v: v - 9.0)(x).block_until_ready()
        assert inner.count >= 1
    assert outer.count >= inner.count
    # log_compiles off again: a fresh compile outside any guard logs
    # nothing into a stale handler (names lists are per-guard)
    before = list(outer.names)
    jax.jit(lambda v: v * 11.0)(x).block_until_ready()
    assert outer.names == before


# ---------------------------------------------------------------------------
# R4 burn-down regressions: flagged asserts are now typed errors
# ---------------------------------------------------------------------------

def test_ops_set_backend_rejects_unknown():
    from repro.kernels import ops
    with pytest.raises(ValueError, match="kernel backend"):
        ops.set_backend("cuda")
    assert ops.get_backend() in ("xla", "pallas", "interpret")


def test_ops_set_ssm_xla_impl_rejects_unknown():
    from repro.kernels import ops
    with pytest.raises(ValueError, match="selective-scan XLA impl"):
        ops.set_ssm_xla_impl("fused")


def test_rglru_pallas_rejects_misaligned_tiling():
    from repro.kernels.rglru_scan import rglru_pallas
    x = jnp.ones((1, 6, 4), jnp.float32)
    with pytest.raises(ValueError, match="tiling must divide"):
        rglru_pallas(x, x, x, jnp.ones((4,), jnp.float32),
                     chunk=4, interpret=True)


def test_selective_scan_pallas_rejects_misaligned_tiling():
    from repro.kernels.selective_scan import selective_scan_pallas
    x = jnp.ones((1, 6, 4), jnp.float32)
    sn = jnp.ones((1, 6, 2), jnp.float32)
    with pytest.raises(ValueError, match="tiling must divide"):
        selective_scan_pallas(x, x, jnp.ones((4, 2), jnp.float32), sn, sn,
                              jnp.ones((4,), jnp.float32),
                              chunk=4, interpret=True)


def test_sublayer_spec_rejects_unknown_kind():
    from repro.configs.base import get_config
    from repro.models.transformer import sublayer_spec
    cfg = get_config("qwen2-7b").reduced()
    with pytest.raises(ValueError, match="unknown sublayer kind"):
        sublayer_spec(cfg, "conv")


def test_clusterize_rejects_uneven_batch():
    from repro.launch.dryrun import _clusterize
    structs = {"x": jax.ShapeDtypeStruct((5, 3), jnp.float32)}
    with pytest.raises(ValueError, match="split evenly"):
        _clusterize(structs, 2)
