"""Ragged continuous batching: per-row positions end-to-end.

Invariants under test:
- a mixed-length, mixed-budget (and mixed-domain) engine drain is
  token-for-token identical to serving each request alone — across the
  dense, ssm, and hybrid layer stacks;
- in-wave slot refill (slots < requests, forcing mid-wave re-prefill)
  changes nothing about any request's tokens;
- per-row retirement makes ``padded_tokens`` (wasted slot-steps) exactly
  zero when the queue keeps every slot busy to the end;
- the decode-segment jit cache is bounded by pow2 bucketing: new budget
  mixes stop adding compile entries, and repeat drains run entirely off
  warm jit caches — ZERO XLA compilations, enforced by
  ``repro.analysis.guards.compile_guard(max_compiles=0)``;
- ``attention.cache_spec`` matches the cache shapes prefill actually
  builds, across window < seq_len and window > seq_len.
- a PAGED engine drain (block-table pool, ``PagedSpec``) is
  token-for-token identical to the dense-slab drain and to solo serving,
  across the same layer-stack families, with the block pool conserved
  (allocator clean after every drain);
- cross-request prefix sharing prefills each shared block exactly once
  (counter- and refcount-audited), both inside one drain and across
  drains via the hash-retaining LRU free list;
- ``serve_trace`` timed admission serves the same tokens as front-loaded
  submission.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.guards import compile_guard
from repro.configs.base import get_config
from repro.core.adapter_bank import AdapterBank
from repro.core.paged import BlockAllocator, PagedSpec
from repro.launch.engine import DecodeEngine
from repro.models import attention as attn_mod
from repro.models import model as M

KEY = jax.random.PRNGKey(7)

# dense, ssm, hybrid (sliding-window attn + rglru) stacks per the ROADMAP.
# The dense representative stays tier-1; the recurrent sweeps are `slow`
# (their state-freezing parity also rides test_adapter_bank /
# test_models_smoke) — run with `pytest -m slow`.
ARCHS = ["qwen2-7b",
         pytest.param("falcon-mamba-7b", marks=pytest.mark.slow),
         pytest.param("recurrentgemma-2b", marks=pytest.mark.slow)]


def _ragged_requests(cfg, n=5, seed=3):
    """Mixed lengths AND mixed budgets, nothing length-aligned."""
    lens = [5, 9, 12, 7, 10][:n]
    gens = [4, 2, 6, 3, 5][:n]
    rows = [np.asarray(jax.random.randint(
        jax.random.fold_in(KEY, seed + i), (l,), 0, cfg.vocab_size,
        dtype=jnp.int32)) for i, l in enumerate(lens)]
    return rows, gens


@pytest.mark.parametrize("arch", ARCHS)
def test_ragged_drain_matches_per_request(arch):
    """One mixed-length mixed-budget drain == serving each request alone."""
    cfg = get_config(arch).reduced().with_(dtype="float32", vocab_size=64)
    params = M.init(cfg, KEY)
    rows, gens = _ragged_requests(cfg)
    engine = DecodeEngine(cfg, slots=4)        # 5 requests -> in-wave refill
    uids = [engine.submit(r, g) for r, g in zip(rows, gens)]
    comps, stats = engine.run(params)
    assert stats.requests == len(rows)
    by_uid = {c.uid: c.tokens for c in comps}
    for uid, r, g in zip(uids, rows, gens):
        want = np.asarray(M.generate_scan(params, cfg, jnp.asarray(r[None]),
                                          gen=g))[0]
        np.testing.assert_array_equal(by_uid[uid], want)
    assert engine.pending() == 0
    assert all(not s.active for s in engine.slot_table)

    # warm-cache sentinel: the SAME workload drains again with ZERO new
    # XLA compilations (the fused-fn lru keys + pow2 bucketing promise)
    engine2 = DecodeEngine(cfg, slots=4)
    uids2 = [engine2.submit(r, g) for r, g in zip(rows, gens)]
    with compile_guard(max_compiles=0):
        comps2, _ = engine2.run(params)
    by2 = {c.uid: c.tokens for c in comps2}
    for u1, u2 in zip(uids, uids2):
        np.testing.assert_array_equal(by_uid[u1], by2[u2])


def test_ragged_generate_scan_matches_solo():
    """generate_scan(prompt_lens=...) == per-row unpadded generation."""
    cfg = get_config("qwen2-7b").reduced().with_(dtype="float32",
                                                 vocab_size=64)
    params = M.init(cfg, KEY)
    rows, _ = _ragged_requests(cfg, n=3)
    S = max(len(r) for r in rows)
    padded = np.zeros((3, S), np.int32)
    for i, r in enumerate(rows):
        padded[i, :len(r)] = r
    got = np.asarray(M.generate_scan(
        params, cfg, jnp.asarray(padded), gen=4,
        prompt_lens=jnp.asarray([len(r) for r in rows])))
    for i, r in enumerate(rows):
        want = np.asarray(M.generate_scan(params, cfg, jnp.asarray(r[None]),
                                          gen=4))
        np.testing.assert_array_equal(got[i], want[0])


def test_in_wave_refill_matches_wave_boundary_refill():
    """A tight drain (slots=2, refills mid-wave) serves the same tokens as
    a wide drain (slots >= requests, no refill at all)."""
    cfg = get_config("qwen2-7b").reduced().with_(dtype="float32",
                                                 vocab_size=64)
    params = M.init(cfg, KEY)
    rows, gens = _ragged_requests(cfg)

    tight = DecodeEngine(cfg, slots=2)
    uids_t = [tight.submit(r, g) for r, g in zip(rows, gens)]
    comps_t, stats_t = tight.run(params)
    assert stats_t.waves > 1                   # refill actually happened

    wide = DecodeEngine(cfg, slots=len(rows))
    uids_w = [wide.submit(r, g) for r, g in zip(rows, gens)]
    comps_w, stats_w = wide.run(params)
    assert stats_w.waves == 1                  # everything fit up front

    by_t = {c.uid: c.tokens for c in comps_t}
    by_w = {c.uid: c.tokens for c in comps_w}
    for ut, uw in zip(uids_t, uids_w):
        np.testing.assert_array_equal(by_t[ut], by_w[uw])


def test_ragged_mixed_domain_drain():
    """Ragged rows compose with multi-tenant adapter_ids: mixed lengths,
    budgets, AND domains in one drain == solo serving per request."""
    cfg = get_config("qwen2-7b").reduced().with_(dtype="float32",
                                                 vocab_size=64)
    ks = jax.random.split(KEY, 4)
    doms = {n: M.init(cfg, ks[i])["adapters"] for i, n in enumerate("abc")}
    backbone = M.init(cfg, ks[3])["backbone"]
    bank = AdapterBank.create(doms)
    rows, gens = _ragged_requests(cfg)
    order = ["b", "c", "a", "c", "b"]

    engine = DecodeEngine(cfg, slots=3, bank=bank)
    uids = [engine.submit(r, g, domain=d)
            for r, g, d in zip(rows, gens, order)]
    comps, _ = engine.run(bank.serving_params(backbone))
    by_uid = {c.uid: c.tokens for c in comps}
    for uid, r, g, d in zip(uids, rows, gens, order):
        want = np.asarray(M.generate_scan(
            {"backbone": backbone, "adapters": doms[d]}, cfg,
            jnp.asarray(r[None]), gen=g))[0]
        np.testing.assert_array_equal(by_uid[uid], want)


def test_padded_tokens_zero_with_full_queue():
    """With per-row retirement + in-wave refill, a drain whose queue keeps
    every slot busy to the very end wastes ZERO slot-steps."""
    cfg = get_config("vit-edge").reduced().with_(dtype="float32",
                                                 vocab_size=64)
    params = M.init(cfg, KEY)
    engine = DecodeEngine(cfg, slots=2)
    prompts = np.asarray(jax.random.randint(KEY, (4, 8), 0, cfg.vocab_size,
                                            dtype=jnp.int32))
    # FIFO lanes: A serves 4 then 4, B serves 2 then refills to 2+2 — every
    # retirement is immediately refilled, so every executed step serves a
    # token in every slot
    for p, g in zip(prompts, [4, 2, 4, 2]):
        engine.submit(p, g)
    _, stats = engine.run(params)
    assert stats.tokens == 12
    assert stats.padded_tokens == 0
    assert stats.utilization == 1.0


def test_padded_tokens_counts_idle_slots():
    """Uneven budgets with an empty queue leave retired slots idle — the
    wasted steps are ledgered, and tokens still only counts served."""
    cfg = get_config("vit-edge").reduced().with_(dtype="float32",
                                                 vocab_size=64)
    params = M.init(cfg, KEY)
    engine = DecodeEngine(cfg, slots=2)
    prompts = np.asarray(jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size,
                                            dtype=jnp.int32))
    engine.submit(prompts[0], 8)
    engine.submit(prompts[1], 2)
    _, stats = engine.run(params)
    assert stats.tokens == 10
    # the budget-2 slot idles while the budget-8 row finishes: 6 steps
    assert stats.padded_tokens == 6
    assert 0.0 < stats.utilization < 1.0


def test_zero_budget_requests_rejected_at_submit():
    """max_new_tokens < 1 is malformed input: rejected with ValueError at
    submit time (never admitted to a wave), leaving the queue intact for
    well-formed requests."""
    cfg = get_config("vit-edge").reduced().with_(dtype="float32",
                                                 vocab_size=64)
    params = M.init(cfg, KEY)
    engine = DecodeEngine(cfg, slots=2)
    prompts = np.asarray(jax.random.randint(KEY, (3, 8), 0, cfg.vocab_size,
                                            dtype=jnp.int32))
    with pytest.raises(ValueError, match="max_new_tokens"):
        engine.submit(prompts[0], 0)
    u1 = engine.submit(prompts[1], 3)
    with pytest.raises(ValueError, match="max_new_tokens"):
        engine.submit(prompts[2], -1)
    assert engine.pending() == 1                          # queue not poisoned
    comps, stats = engine.run(params)
    assert stats.requests == 1 and stats.tokens == 3
    want = np.asarray(M.generate_scan(params, cfg,
                                      jnp.asarray(prompts[1:2]), gen=3))[0]
    np.testing.assert_array_equal(comps[0].tokens, want)
    assert comps[0].uid == u1
    assert all(not s.active for s in engine.slot_table)   # no slot leak


def test_segment_jit_cache_stops_growing():
    """Budgets are served via pow2-bucketed scan segments: a fresh drain
    with a DIFFERENT budget mix (same pow2 envelope) compiles nothing new."""
    cfg = get_config("vit-edge").reduced().with_(dtype="float32",
                                                 vocab_size=64)
    params = M.init(cfg, KEY)
    prompts = np.asarray(jax.random.randint(KEY, (6, 8), 0, cfg.vocab_size,
                                            dtype=jnp.int32))

    def drain(budgets):
        engine = DecodeEngine(cfg, slots=3)
        for p, g in zip(prompts, budgets):
            engine.submit(p, g)
        engine.run(params)

    before = M._segment_fn.cache_info().currsize
    drain([5, 3, 7, 2, 6, 4])
    seen = M._segment_fn.cache_info().currsize
    # every segment length is a power of two <= the largest budget (7):
    # at most {1, 2, 4} new entries regardless of how budgets mix
    assert seen - before <= 3
    # stronger than lru-cache stability: new mixes over the same pow2
    # envelope trigger ZERO XLA compilations of ANY program — the runtime
    # proof that bucketing covers segments, refills, and prompt widths
    with compile_guard(max_compiles=0):
        drain([7, 2, 5, 6, 3, 4])              # new mix, same pow2 envelope
        drain([4, 4, 6, 2, 7, 5])
    assert M._segment_fn.cache_info().currsize == seen


@pytest.mark.parametrize("window,seq_len", [(4, 12), (16, 12), (0, 12)])
def test_cache_spec_matches_built_cache(window, seq_len):
    """attention.cache_spec must describe the cache prefill actually
    builds — rolling buffer of exactly `window` slots when sliding
    (window above OR below seq_len), `seq_len` otherwise."""
    cfg = get_config("qwen2-7b").reduced().with_(dtype="float32",
                                                 vocab_size=64)
    if window:
        cfg = cfg.with_(attn_variant="sliding", sliding_window=window)
    params = M.init(cfg, KEY)
    toks = jax.random.randint(KEY, (2, seq_len), 0, cfg.vocab_size,
                              dtype=jnp.int32)
    _, caches = M.prefill(params, {"tokens": toks}, cfg, max_len=seq_len)
    spec = M.cache_spec(cfg, batch=2, seq_len=seq_len)
    built_shapes = jax.tree.map(jnp.shape, caches)
    spec_shapes = jax.tree.map(lambda s: tuple(s.shape), spec,
                               is_leaf=lambda x: hasattr(x, "shape")
                               and not isinstance(x, dict))
    assert built_shapes == spec_shapes


# ---------------------------------------------------------------------------
# Paged serving: block-table pool drains
# ---------------------------------------------------------------------------

def _solo(params, cfg, row, gen):
    return np.asarray(M.generate_scan(params, cfg, jnp.asarray(row[None]),
                                      gen=gen))[0]


@pytest.mark.parametrize("arch", ARCHS)
def test_paged_drain_matches_dense(arch):
    """A paged drain (block pool + tables, slots < requests so in-wave
    refill hits the paged commit path) == the dense-slab drain == solo
    serving, across the dense/ssm/hybrid stacks; the pool is conserved
    (allocator clean once every request retires)."""
    cfg = get_config(arch).reduced().with_(dtype="float32", vocab_size=64)
    params = M.init(cfg, KEY)
    rows, gens = _ragged_requests(cfg)

    paged = DecodeEngine(cfg, slots=3,
                         paged=PagedSpec(n_blocks=32, block_size=8))
    uids_p = [paged.submit(r, g) for r, g in zip(rows, gens)]
    comps_p, stats_p = paged.run(params)
    assert stats_p.waves > 1                   # refill actually happened

    dense = DecodeEngine(cfg, slots=3)
    uids_d = [dense.submit(r, g) for r, g in zip(rows, gens)]
    comps_d, _ = dense.run(params)

    by_p = {c.uid: c.tokens for c in comps_p}
    by_d = {c.uid: c.tokens for c in comps_d}
    for (up, ud, r, g) in zip(uids_p, uids_d, rows, gens):
        np.testing.assert_array_equal(by_p[up], by_d[ud])
        np.testing.assert_array_equal(by_p[up], _solo(params, cfg, r, g))
    assert stats_p.pool_block_size == 8
    assert stats_p.pool_peak_blocks >= 1
    assert paged._alloc.used_blocks == 0       # every row's blocks freed
    paged._alloc.check()

    # warm-cache sentinel: a second paged drain of the same workload is
    # compile-free — the paged prefill/refill/suffix dispatches key and
    # bucket exactly like the dense ones
    paged2 = DecodeEngine(cfg, slots=3,
                          paged=PagedSpec(n_blocks=32, block_size=8))
    uids_p2 = [paged2.submit(r, g) for r, g in zip(rows, gens)]
    with compile_guard(max_compiles=0):
        comps_p2, _ = paged2.run(params)
    by_p2 = {c.uid: c.tokens for c in comps_p2}
    for u1, u2 in zip(uids_p, uids_p2):
        np.testing.assert_array_equal(by_p[u1], by_p2[u2])


def _prefix_rows(cfg, bs, n_hits=2, prefix_blocks=2, seed=11):
    """One donor + n_hits rows sharing `prefix_blocks` full blocks."""
    prefix = np.asarray(jax.random.randint(
        jax.random.fold_in(KEY, seed), (prefix_blocks * bs,), 0,
        cfg.vocab_size, dtype=jnp.int32))
    rows = []
    for i in range(1 + n_hits):
        tail = np.asarray(jax.random.randint(
            jax.random.fold_in(KEY, seed + 1 + i), (3,), 0,
            cfg.vocab_size, dtype=jnp.int32))
        rows.append(np.concatenate([prefix, tail]))
    return rows


def test_paged_prefix_sharing_prefills_shared_blocks_once():
    """Same-drain prefix sharing: the donor's full prefill registers its
    prompt blocks at PLAN time, so same-wave siblings acquire the shared
    blocks instead of allocating + re-prefilling them. Exactly-once is
    audited through the allocator's books — shared blocks are allocated
    once (by the donor) and acquired, never re-allocated, by the hits —
    and every row still decodes token-identically to solo serving."""
    cfg = get_config("qwen2-7b").reduced().with_(dtype="float32",
                                                 vocab_size=64)
    params = M.init(cfg, KEY)
    bs, gen = 4, 3
    rows = _prefix_rows(cfg, bs)               # donor + 2 hits, prefix = 2 blocks
    engine = DecodeEngine(
        cfg, slots=4,
        paged=PagedSpec(n_blocks=32, block_size=bs, share_prefix=True))
    alloc = engine._alloc
    uids = [engine.submit(r, gen) for r in rows]
    comps, stats = engine.run(params)

    assert stats.prefix_hits == 2
    assert stats.prefix_hit_tokens == 2 * 2 * bs
    assert alloc.shared_acquires == 2 * 2      # 2 hits x 2 prefix blocks
    # exactly-once: total fresh allocations == naive demand minus the
    # shared prefix blocks the hits did NOT allocate
    naive = sum(-(-(len(r) + gen) // bs) for r in rows)
    assert alloc.allocated == naive - 2 * 2
    assert stats.pool_blocks_alloc == alloc.allocated
    by_uid = {c.uid: c.tokens for c in comps}
    for uid, r in zip(uids, rows):
        np.testing.assert_array_equal(by_uid[uid], _solo(params, cfg, r, gen))
    assert alloc.used_blocks == 0              # refcounts drained to zero
    alloc.check()


def test_paged_prefix_sharing_across_drains_and_refill():
    """The hash-retaining LRU free list revives a retired drain's prefix
    blocks for a LATER drain's matching prompt (no re-prefill), and
    sharing still fires on the in-wave refill path (slots < requests)."""
    cfg = get_config("qwen2-7b").reduced().with_(dtype="float32",
                                                 vocab_size=64)
    params = M.init(cfg, KEY)
    bs, gen = 4, 3
    rows = _prefix_rows(cfg, bs)
    engine = DecodeEngine(
        cfg, slots=2,                          # 3 requests -> refill wave
        paged=PagedSpec(n_blocks=32, block_size=bs, share_prefix=True))
    uids = [engine.submit(r, gen) for r in rows]
    comps, stats = engine.run(params)
    assert stats.prefix_hits == 2              # refill-path admissions share
    by_uid = {c.uid: c.tokens for c in comps}
    for uid, r in zip(uids, rows):
        np.testing.assert_array_equal(by_uid[uid], _solo(params, cfg, r, gen))

    hits_before = engine._alloc.hash_hits
    uid2 = engine.submit(rows[1], gen)         # same prompt, next drain
    comps2, stats2 = engine.run(params)
    assert stats2.prefix_hits == 1             # revived off the free list
    assert engine._alloc.hash_hits > hits_before
    np.testing.assert_array_equal(
        {c.uid: c.tokens for c in comps2}[uid2],
        _solo(params, cfg, rows[1], gen))
    assert engine._alloc.used_blocks == 0
    engine._alloc.check()


def test_paged_serve_trace_matches_solo():
    """Arrival-driven admission: a timed trace drains to the same tokens
    as solo serving, and SLA classes land in per-class stats."""
    cfg = get_config("qwen2-7b").reduced().with_(dtype="float32",
                                                 vocab_size=64)
    params = M.init(cfg, KEY)
    rows, gens = _ragged_requests(cfg, n=3)
    trace = [(0.00, rows[0], gens[0], {"sla": "gold"}),
             (0.01, rows[1], gens[1], {"sla": "best_effort"}),
             (0.02, rows[2], gens[2])]
    engine = DecodeEngine(cfg, slots=2,
                          paged=PagedSpec(n_blocks=32, block_size=8))
    comps, stats = engine.serve_trace(params, trace)
    assert stats.requests == 3
    by_uid = {c.uid: c.tokens for c in comps}
    for uid, (_, r, g, *_) in zip(sorted(by_uid), trace):
        np.testing.assert_array_equal(by_uid[uid], _solo(params, cfg, r, g))
    assert set(stats.sla_stats) == {"gold", "best_effort"}
    assert stats.sla_stats["gold"]["requests"] == 1
    assert stats.sla_stats["gold"]["deadline_miss"] == 0


def test_paged_engine_rejects_invalid_configs():
    """Fail-fast gates: paged+speculative is mutually exclusive, prefix
    sharing needs a fully paged stack, and a request that could never
    fit the pool is rejected at submit, not stalled at admission."""
    cfg = get_config("qwen2-7b").reduced().with_(dtype="float32",
                                                 vocab_size=64)

    class _FakeSpec:                           # passes validate, hits the gate
        def validate_target(self, cfg):
            pass

    with pytest.raises(ValueError, match="paged serving composes"):
        DecodeEngine(cfg, slots=2, spec=_FakeSpec(),
                     paged=PagedSpec(n_blocks=8, block_size=4))
    ssm_cfg = get_config("falcon-mamba-7b").reduced().with_(
        dtype="float32", vocab_size=64)
    with pytest.raises(ValueError, match="fully paged stack"):
        DecodeEngine(ssm_cfg, slots=2,
                     paged=PagedSpec(n_blocks=8, block_size=4,
                                     share_prefix=True))
    engine = DecodeEngine(cfg, slots=2,
                          paged=PagedSpec(n_blocks=4, block_size=4))
    with pytest.raises(ValueError, match="could never be admitted"):
        engine.submit(np.arange(15, dtype=np.int32) % 64, 8)   # needs 6 > 4


def test_block_allocator_random_walk_conserves_pool():
    """Seeded alloc/free/acquire walk: the pool is conserved (free + used
    == n_blocks at every step), refcounts never go negative, double-free
    raises, and the books always balance (allocator.check())."""
    rng = np.random.default_rng(5)
    alloc = BlockAllocator(24, 4)
    live: list[list[int]] = []
    for _ in range(400):
        op = rng.integers(3)
        if op == 0:                            # alloc a few blocks
            got = alloc.alloc(int(rng.integers(1, 5)))
            if got is not None:
                live.append(got)
        elif op == 1 and live:                 # free one holding
            alloc.free(live.pop(int(rng.integers(len(live)))))
        elif op == 2 and live:                 # share then release a block
            bid = live[int(rng.integers(len(live)))][0]
            alloc.acquire(bid)
            alloc.free([bid])
        assert all(rc >= 0 for rc in alloc.refcount)
        assert alloc.free_blocks + alloc.used_blocks == 24
        alloc.check()
    ids = live.pop() if live else alloc.alloc(2)
    alloc.free(ids)
    with pytest.raises(RuntimeError):
        alloc.free(ids)                        # double-free must raise
