"""Ragged continuous batching: per-row positions end-to-end.

Invariants under test:
- a mixed-length, mixed-budget (and mixed-domain) engine drain is
  token-for-token identical to serving each request alone — across the
  dense, ssm, and hybrid layer stacks;
- in-wave slot refill (slots < requests, forcing mid-wave re-prefill)
  changes nothing about any request's tokens;
- per-row retirement makes ``padded_tokens`` (wasted slot-steps) exactly
  zero when the queue keeps every slot busy to the end;
- the decode-segment jit cache is bounded by pow2 bucketing: new budget
  mixes stop adding compile entries;
- ``attention.cache_spec`` matches the cache shapes prefill actually
  builds, across window < seq_len and window > seq_len.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.adapter_bank import AdapterBank
from repro.launch.engine import DecodeEngine
from repro.models import attention as attn_mod
from repro.models import model as M

KEY = jax.random.PRNGKey(7)

# dense, ssm, hybrid (sliding-window attn + rglru) stacks per the ROADMAP.
# The dense representative stays tier-1; the recurrent sweeps are `slow`
# (their state-freezing parity also rides test_adapter_bank /
# test_models_smoke) — run with `pytest -m slow`.
ARCHS = ["qwen2-7b",
         pytest.param("falcon-mamba-7b", marks=pytest.mark.slow),
         pytest.param("recurrentgemma-2b", marks=pytest.mark.slow)]


def _ragged_requests(cfg, n=5, seed=3):
    """Mixed lengths AND mixed budgets, nothing length-aligned."""
    lens = [5, 9, 12, 7, 10][:n]
    gens = [4, 2, 6, 3, 5][:n]
    rows = [np.asarray(jax.random.randint(
        jax.random.fold_in(KEY, seed + i), (l,), 0, cfg.vocab_size,
        dtype=jnp.int32)) for i, l in enumerate(lens)]
    return rows, gens


@pytest.mark.parametrize("arch", ARCHS)
def test_ragged_drain_matches_per_request(arch):
    """One mixed-length mixed-budget drain == serving each request alone."""
    cfg = get_config(arch).reduced().with_(dtype="float32", vocab_size=64)
    params = M.init(cfg, KEY)
    rows, gens = _ragged_requests(cfg)
    engine = DecodeEngine(cfg, slots=4)        # 5 requests -> in-wave refill
    uids = [engine.submit(r, g) for r, g in zip(rows, gens)]
    comps, stats = engine.run(params)
    assert stats.requests == len(rows)
    by_uid = {c.uid: c.tokens for c in comps}
    for uid, r, g in zip(uids, rows, gens):
        want = np.asarray(M.generate_scan(params, cfg, jnp.asarray(r[None]),
                                          gen=g))[0]
        np.testing.assert_array_equal(by_uid[uid], want)
    assert engine.pending() == 0
    assert all(not s.active for s in engine.slot_table)


def test_ragged_generate_scan_matches_solo():
    """generate_scan(prompt_lens=...) == per-row unpadded generation."""
    cfg = get_config("qwen2-7b").reduced().with_(dtype="float32",
                                                 vocab_size=64)
    params = M.init(cfg, KEY)
    rows, _ = _ragged_requests(cfg, n=3)
    S = max(len(r) for r in rows)
    padded = np.zeros((3, S), np.int32)
    for i, r in enumerate(rows):
        padded[i, :len(r)] = r
    got = np.asarray(M.generate_scan(
        params, cfg, jnp.asarray(padded), gen=4,
        prompt_lens=jnp.asarray([len(r) for r in rows])))
    for i, r in enumerate(rows):
        want = np.asarray(M.generate_scan(params, cfg, jnp.asarray(r[None]),
                                          gen=4))
        np.testing.assert_array_equal(got[i], want[0])


def test_in_wave_refill_matches_wave_boundary_refill():
    """A tight drain (slots=2, refills mid-wave) serves the same tokens as
    a wide drain (slots >= requests, no refill at all)."""
    cfg = get_config("qwen2-7b").reduced().with_(dtype="float32",
                                                 vocab_size=64)
    params = M.init(cfg, KEY)
    rows, gens = _ragged_requests(cfg)

    tight = DecodeEngine(cfg, slots=2)
    uids_t = [tight.submit(r, g) for r, g in zip(rows, gens)]
    comps_t, stats_t = tight.run(params)
    assert stats_t.waves > 1                   # refill actually happened

    wide = DecodeEngine(cfg, slots=len(rows))
    uids_w = [wide.submit(r, g) for r, g in zip(rows, gens)]
    comps_w, stats_w = wide.run(params)
    assert stats_w.waves == 1                  # everything fit up front

    by_t = {c.uid: c.tokens for c in comps_t}
    by_w = {c.uid: c.tokens for c in comps_w}
    for ut, uw in zip(uids_t, uids_w):
        np.testing.assert_array_equal(by_t[ut], by_w[uw])


def test_ragged_mixed_domain_drain():
    """Ragged rows compose with multi-tenant adapter_ids: mixed lengths,
    budgets, AND domains in one drain == solo serving per request."""
    cfg = get_config("qwen2-7b").reduced().with_(dtype="float32",
                                                 vocab_size=64)
    ks = jax.random.split(KEY, 4)
    doms = {n: M.init(cfg, ks[i])["adapters"] for i, n in enumerate("abc")}
    backbone = M.init(cfg, ks[3])["backbone"]
    bank = AdapterBank.create(doms)
    rows, gens = _ragged_requests(cfg)
    order = ["b", "c", "a", "c", "b"]

    engine = DecodeEngine(cfg, slots=3, bank=bank)
    uids = [engine.submit(r, g, domain=d)
            for r, g, d in zip(rows, gens, order)]
    comps, _ = engine.run(bank.serving_params(backbone))
    by_uid = {c.uid: c.tokens for c in comps}
    for uid, r, g, d in zip(uids, rows, gens, order):
        want = np.asarray(M.generate_scan(
            {"backbone": backbone, "adapters": doms[d]}, cfg,
            jnp.asarray(r[None]), gen=g))[0]
        np.testing.assert_array_equal(by_uid[uid], want)


def test_padded_tokens_zero_with_full_queue():
    """With per-row retirement + in-wave refill, a drain whose queue keeps
    every slot busy to the very end wastes ZERO slot-steps."""
    cfg = get_config("vit-edge").reduced().with_(dtype="float32",
                                                 vocab_size=64)
    params = M.init(cfg, KEY)
    engine = DecodeEngine(cfg, slots=2)
    prompts = np.asarray(jax.random.randint(KEY, (4, 8), 0, cfg.vocab_size,
                                            dtype=jnp.int32))
    # FIFO lanes: A serves 4 then 4, B serves 2 then refills to 2+2 — every
    # retirement is immediately refilled, so every executed step serves a
    # token in every slot
    for p, g in zip(prompts, [4, 2, 4, 2]):
        engine.submit(p, g)
    _, stats = engine.run(params)
    assert stats.tokens == 12
    assert stats.padded_tokens == 0
    assert stats.utilization == 1.0


def test_padded_tokens_counts_idle_slots():
    """Uneven budgets with an empty queue leave retired slots idle — the
    wasted steps are ledgered, and tokens still only counts served."""
    cfg = get_config("vit-edge").reduced().with_(dtype="float32",
                                                 vocab_size=64)
    params = M.init(cfg, KEY)
    engine = DecodeEngine(cfg, slots=2)
    prompts = np.asarray(jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size,
                                            dtype=jnp.int32))
    engine.submit(prompts[0], 8)
    engine.submit(prompts[1], 2)
    _, stats = engine.run(params)
    assert stats.tokens == 10
    # the budget-2 slot idles while the budget-8 row finishes: 6 steps
    assert stats.padded_tokens == 6
    assert 0.0 < stats.utilization < 1.0


def test_zero_budget_requests_rejected_at_submit():
    """max_new_tokens < 1 is malformed input: rejected with ValueError at
    submit time (never admitted to a wave), leaving the queue intact for
    well-formed requests."""
    cfg = get_config("vit-edge").reduced().with_(dtype="float32",
                                                 vocab_size=64)
    params = M.init(cfg, KEY)
    engine = DecodeEngine(cfg, slots=2)
    prompts = np.asarray(jax.random.randint(KEY, (3, 8), 0, cfg.vocab_size,
                                            dtype=jnp.int32))
    with pytest.raises(ValueError, match="max_new_tokens"):
        engine.submit(prompts[0], 0)
    u1 = engine.submit(prompts[1], 3)
    with pytest.raises(ValueError, match="max_new_tokens"):
        engine.submit(prompts[2], -1)
    assert engine.pending() == 1                          # queue not poisoned
    comps, stats = engine.run(params)
    assert stats.requests == 1 and stats.tokens == 3
    want = np.asarray(M.generate_scan(params, cfg,
                                      jnp.asarray(prompts[1:2]), gen=3))[0]
    np.testing.assert_array_equal(comps[0].tokens, want)
    assert comps[0].uid == u1
    assert all(not s.active for s in engine.slot_table)   # no slot leak


def test_segment_jit_cache_stops_growing():
    """Budgets are served via pow2-bucketed scan segments: a fresh drain
    with a DIFFERENT budget mix (same pow2 envelope) compiles nothing new."""
    cfg = get_config("vit-edge").reduced().with_(dtype="float32",
                                                 vocab_size=64)
    params = M.init(cfg, KEY)
    prompts = np.asarray(jax.random.randint(KEY, (6, 8), 0, cfg.vocab_size,
                                            dtype=jnp.int32))

    def drain(budgets):
        engine = DecodeEngine(cfg, slots=3)
        for p, g in zip(prompts, budgets):
            engine.submit(p, g)
        engine.run(params)

    before = M._segment_fn.cache_info().currsize
    drain([5, 3, 7, 2, 6, 4])
    seen = M._segment_fn.cache_info().currsize
    # every segment length is a power of two <= the largest budget (7):
    # at most {1, 2, 4} new entries regardless of how budgets mix
    assert seen - before <= 3
    drain([7, 2, 5, 6, 3, 4])                  # new mix, same pow2 envelope
    drain([4, 4, 6, 2, 7, 5])
    assert M._segment_fn.cache_info().currsize == seen


@pytest.mark.parametrize("window,seq_len", [(4, 12), (16, 12), (0, 12)])
def test_cache_spec_matches_built_cache(window, seq_len):
    """attention.cache_spec must describe the cache prefill actually
    builds — rolling buffer of exactly `window` slots when sliding
    (window above OR below seq_len), `seq_len` otherwise."""
    cfg = get_config("qwen2-7b").reduced().with_(dtype="float32",
                                                 vocab_size=64)
    if window:
        cfg = cfg.with_(attn_variant="sliding", sliding_window=window)
    params = M.init(cfg, KEY)
    toks = jax.random.randint(KEY, (2, seq_len), 0, cfg.vocab_size,
                              dtype=jnp.int32)
    _, caches = M.prefill(params, {"tokens": toks}, cfg, max_len=seq_len)
    spec = M.cache_spec(cfg, batch=2, seq_len=seq_len)
    built_shapes = jax.tree.map(jnp.shape, caches)
    spec_shapes = jax.tree.map(lambda s: tuple(s.shape), spec,
                               is_leaf=lambda x: hasattr(x, "shape")
                               and not isinstance(x, dict))
    assert built_shapes == spec_shapes
