"""Edge-drafted speculative decoding: greedy parity, acceptance
accounting at the forced extremes (0% and 100%), mixed spec/plain waves,
and the model.py fused-fn jit-cache key audit (draft_k sweep)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.spec_decode import (SpecDecoder, drafter_config,
                                    spec_generate)
from repro.launch.engine import DecodeEngine
from repro.models import model as M


def _cfg(name):
    return get_config(name).reduced().with_(dtype="float32", vocab_size=64)


def _prompts(key, n, s, vocab=64):
    return np.asarray(jax.random.randint(key, (n, s), 1, vocab,
                                         dtype=jnp.int32))


# ---------------------------------------------------------------------------
# Greedy parity: spec output must be token-for-token the plain output
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ["vit-edge", "falcon-mamba-7b",
                                  "recurrentgemma-2b"])
def test_spec_generate_matches_generate_scan(name):
    """Exact-match acceptance + per-row rollback == plain greedy decoding,
    for every cache family (full attention, ssm state, sliding-window
    hybrid)."""
    cfg = _cfg(name)
    params = M.init(cfg, jax.random.PRNGKey(0))
    spec = SpecDecoder.init(cfg, jax.random.PRNGKey(7), k=3)
    prompts = _prompts(jax.random.PRNGKey(1), 3, 12)
    ref = np.asarray(M.generate_scan(params, cfg, jnp.asarray(prompts),
                                     gen=11))
    out, stats = spec_generate(params, cfg, spec, prompts, gen=11)
    np.testing.assert_array_equal(np.asarray(out), ref)
    # every chunk commits at least the verified carry token
    assert stats.drafted > 0
    assert 0 <= stats.accepted <= stats.drafted


def test_spec_generate_ragged_and_mixed_rows():
    """Ragged prompt lengths + per-row speculative opt-out share one wave;
    opted-out rows decode plainly THROUGH the verify pass and stay exact."""
    cfg = _cfg("recurrentgemma-2b")
    params = M.init(cfg, jax.random.PRNGKey(0))
    spec = SpecDecoder.init(cfg, jax.random.PRNGKey(7), k=3)
    prompts = np.array(_prompts(jax.random.PRNGKey(2), 4, 10))
    lens = np.asarray([10, 6, 8, 3], np.int32)
    for i, n in enumerate(lens):
        prompts[i, n:] = 0
    rows = np.asarray([True, False, True, False])
    refs = [np.asarray(M.generate_scan(
        params, cfg, jnp.asarray(prompts[i:i + 1, :lens[i]]), gen=9))[0]
        for i in range(4)]
    out, stats = spec_generate(params, cfg, spec, prompts, gen=9,
                               prompt_lens=lens, spec_rows=rows)
    np.testing.assert_array_equal(np.asarray(out), np.stack(refs))
    # plain rows draft nothing: only the 2 opted-in rows book proposals
    assert stats.drafted > 0


# ---------------------------------------------------------------------------
# Acceptance accounting at the forced extremes
# ---------------------------------------------------------------------------


def test_identical_drafter_accepts_everything():
    """Drafter == target (same ssm weights) must accept every proposal:
    acceptance_rate is exactly accepted/drafted == 1.0, and throughput
    collapses to one verify pass per k+1 tokens."""
    cfg = _cfg("falcon-mamba-7b")
    params = M.init(cfg, jax.random.PRNGKey(0))
    spec = SpecDecoder(cfg, params, k=3)      # the target IS the drafter
    prompts = _prompts(jax.random.PRNGKey(3), 2, 8)
    gen = 8                                    # 2 chunks of k+1 per row
    ref = np.asarray(M.generate_scan(params, cfg, jnp.asarray(prompts),
                                     gen=gen))
    out, stats = spec_generate(params, cfg, spec, prompts, gen=gen)
    np.testing.assert_array_equal(np.asarray(out), ref)
    assert stats.accepted == stats.drafted > 0
    assert stats.acceptance_rate == 1.0


def _disagreeing_pair():
    """(target params, SpecDecoder) rigged for 0% acceptance.

    Zeroed target: every logit 0, argmax always token 0. Rigged drafter
    (d_model == vocab == 64): zeroed layers pass the residual through, so
    the final-norm output is a positive multiple of e_tok; the rolled
    lm_head then puts all mass on tok+1. Drafts from any carry t < 60 are
    t+1, t+2, ... — never 0 — so the verify pass rejects every proposal."""
    cfg = _cfg("vit-edge")
    params = jax.tree.map(jnp.zeros_like, M.init(cfg, jax.random.PRNGKey(0)))
    dcfg = drafter_config(cfg)
    dp = jax.tree.map(jnp.zeros_like, M.init(dcfg, jax.random.PRNGKey(1)))
    eye = jnp.eye(64, dtype=jnp.float32)
    dp["backbone"]["embed"]["table"] = 5.0 * eye
    dp["backbone"]["final_norm"]["scale"] = jnp.ones(64, jnp.float32)
    dp["backbone"]["lm_head"]["table"] = 5.0 * jnp.roll(eye, 1, axis=0)
    return cfg, params, SpecDecoder(dcfg, dp, k=3)


def test_forced_disagreement_accepts_nothing():
    """Guaranteed progress under a pathological drafter: every chunk
    commits exactly the 1 verified carry token, accepted == 0, and the
    booked drafted count is exactly k per chunk per row."""
    cfg, params, spec = _disagreeing_pair()
    B, gen = 2, 6
    prompts = _prompts(jax.random.PRNGKey(4), B, 5, vocab=50)
    ref = np.asarray(M.generate_scan(params, cfg, jnp.asarray(prompts),
                                     gen=gen))
    assert (ref == 0).all()                    # zeroed target: argmax 0
    out, stats = spec_generate(params, cfg, spec, prompts, gen=gen)
    np.testing.assert_array_equal(np.asarray(out), ref)
    assert stats.accepted == 0
    assert stats.acceptance_rate == 0.0
    # commit=1/chunk -> gen chunks per row, k drafts booked per chunk
    assert stats.drafted == B * gen * spec.k


# ---------------------------------------------------------------------------
# Engine integration: spec drains == plain drains, mixed waves == solo
# ---------------------------------------------------------------------------


def test_engine_spec_serving_matches_plain():
    cfg = _cfg("vit-edge")
    params = M.init(cfg, jax.random.PRNGKey(0))
    spec = SpecDecoder.init(cfg, jax.random.PRNGKey(7), k=3)
    prompts = _prompts(jax.random.PRNGKey(5), 5, 12)
    plain = DecodeEngine(cfg, slots=3)
    eng = DecodeEngine(cfg, slots=3, spec=spec)
    ref, _ = plain.serve(params, prompts, gen=7)
    out, stats = eng.serve(params, prompts, gen=7)
    np.testing.assert_array_equal(out, ref)
    assert stats.requests == 5
    assert stats.tokens == 35
    assert stats.drafted > 0
    assert stats.acceptance_rate == stats.accepted / stats.drafted
    # padded_tokens now counts verify slot-steps beyond served tokens
    assert stats.utilization <= 1.0


def test_engine_mixed_spec_plain_wave_matches_solo():
    """One drain freely mixing speculative and plain rows (ragged budgets
    included) must serve every request its solo tokens."""
    cfg = _cfg("recurrentgemma-2b")
    params = M.init(cfg, jax.random.PRNGKey(0))
    spec = SpecDecoder.init(cfg, jax.random.PRNGKey(7), k=3)
    eng = DecodeEngine(cfg, slots=3, spec=spec)
    prompts = _prompts(jax.random.PRNGKey(6), 5, 9)
    gens = [8, 5, 11, 6, 9]
    uids = [eng.submit(p, g, speculative=(i % 2 == 0))
            for i, (p, g) in enumerate(zip(prompts, gens))]
    comps, stats = eng.run(params)
    by = {c.uid: c.tokens for c in comps}
    for p, g, u in zip(prompts, gens, uids):
        solo = np.asarray(M.generate_scan(params, cfg,
                                          jnp.asarray(p[None, :]), gen=g))
        np.testing.assert_array_equal(by[u], solo[0])
    assert stats.drafted > 0                   # the spec rows drafted
    assert stats.tokens == sum(gens)


def test_engine_spec_rejects_sampling():
    cfg = _cfg("vit-edge")
    spec = SpecDecoder.init(cfg, jax.random.PRNGKey(7), k=2)
    with pytest.raises(ValueError, match="greedy-only"):
        DecodeEngine(cfg, greedy=False, spec=spec)


def test_validate_target_guards():
    cfg = _cfg("vit-edge")
    spec = SpecDecoder.init(cfg, jax.random.PRNGKey(7), k=2)
    with pytest.raises(NotImplementedError, match="audio"):
        spec.validate_target(_cfg("whisper-small"))
    with pytest.raises(ValueError, match="vocab"):
        spec.validate_target(cfg.with_(vocab_size=32))
    # sliding-window wrap guard: chunk may not exceed the rolling buffer
    win = _cfg("recurrentgemma-2b")
    big = SpecDecoder.init(win, jax.random.PRNGKey(7), k=64)
    with pytest.raises(ValueError, match="sliding window"):
        big.validate_target(win)
    with pytest.raises(ValueError, match="k=0"):
        SpecDecoder.init(cfg, jax.random.PRNGKey(7), k=0)


# ---------------------------------------------------------------------------
# jit-cache key audit: draft_k sweep keeps every fused-fn cache bounded
# ---------------------------------------------------------------------------


def test_fused_fn_caches_bounded_by_draft_k_sweep():
    """Sweeping k must grow _draft_fn by one entry per k (k+1 is the scan
    length -> k IS a trace shape) and _verify_fn by at most one entry
    total (T is the traced shape; k is deliberately NOT in its key).
    See the cache-key audit block in models/model.py."""
    cfg = _cfg("falcon-mamba-7b")
    params = M.init(cfg, jax.random.PRNGKey(0))
    dcfg = drafter_config(cfg)
    dparams = M.init(dcfg, jax.random.PRNGKey(1))
    d0 = M._draft_fn.cache_info().currsize
    v0 = M._verify_fn.cache_info().currsize
    s0 = M._spec_segment_fn.cache_info().currsize
    ks = [1, 2, 3]
    for k in ks:
        M._draft_fn(dcfg, k)
        M._verify_fn(cfg)
        spec = SpecDecoder(dcfg, dparams, k=k)
        prompts = _prompts(jax.random.PRNGKey(k), 2, 6)
        spec_generate(params, cfg, spec, prompts, gen=4)
    assert M._draft_fn.cache_info().currsize - d0 == len(ks)
    assert M._verify_fn.cache_info().currsize - v0 <= 1
    # one segment fn per distinct (chunks, k) actually dispatched; the
    # sweep above uses gen=4 so chunks stays pow2-bucketed and small
    grew = M._spec_segment_fn.cache_info().currsize - s0
    assert 0 < grew <= 2 * len(ks)
    # repeating the sweep is all cache hits: no new entries
    for k in ks:
        M._draft_fn(dcfg, k)
        M._verify_fn(cfg)
    assert M._draft_fn.cache_info().currsize - d0 == len(ks)
    assert M._verify_fn.cache_info().currsize - v0 <= 1
