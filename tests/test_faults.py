"""Fault-tolerant virtuous cycle: chaos end-to-end.

The contract under test (ISSUE 6 acceptance):

- a FaultPlan with every rate 0.0 is invisible — masked rounds, the relay,
  and the integrated runtime are BITWISE identical to running with no plan;
- under 25-40% dropout + corruption + lossy backhaul, every round still
  completes, the serving bank never holds a non-finite adapter, and every
  skipped/dropped/retried event is ledgered;
- a poisoned publish never reaches live traffic (validation + LKG
  rollback), and over-deadline requests retire as timed_out instead of
  stalling a drain;
- a chaos run checkpointed mid-stream resumes step-for-step identically.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core import hfsl
from repro.core.adapter_bank import AdapterBank
from repro.core.faults import FaultPlan, NO_FAULTS, payload_checksum
from repro.core.relay import KnowledgeRelay, RelayTransferError
from repro.data.noniid import partition_by_classes
from repro.data.pipeline import BatchBank
from repro.data.synthetic import ClassificationTask, LMStream
from repro.launch.engine import DecodeEngine
from repro.models import model as M
from repro.optim.optimizers import adamw

pytestmark = pytest.mark.chaos            # `pytest -m chaos` runs this file

KEY = jax.random.PRNGKey(0)
N, K, BATCH, SEQ = 3, 6, 4, 16


def small_cfg():
    cfg = get_config("vit-edge").reduced().with_(dtype="float32",
                                                 vocab_size=64)
    return cfg.with_(peft=dataclasses.replace(cfg.peft, head_dim_out=5))


def classify_bank(cfg, seed=0):
    task = ClassificationTask(5, cfg.vocab_size, SEQ, seed=seed)
    data = task.dataset(40 * N, seed=seed + 1)
    parts = partition_by_classes(data["label"], N, 3, seed=seed)
    return BatchBank.pack(data, parts, BATCH, seed=seed)


def lm_bank(cfg, seed=0):
    streams = [LMStream(cfg.vocab_size, BATCH, SEQ, seed=seed + i)
               for i in range(N)]
    its = [iter(s) for s in streams]

    def gen():
        while True:
            bs = [next(i) for i in its]
            yield {k: jnp.stack([b[k] for b in bs]) for k in bs[0]}

    return BatchBank.from_iterator(gen(), K)


def assert_trees_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def tiny_adapters(cfg, n=2, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), n)
    names = [f"d{i}" for i in range(n)]
    return {d: M.init(cfg, ks[i])["adapters"] for i, d in enumerate(names)}


# ---------------------------------------------------------------------------
# The plan itself
# ---------------------------------------------------------------------------

class TestFaultPlan:
    def test_all_off_plan_is_inactive(self):
        assert not NO_FAULTS.active
        assert not FaultPlan(seed=7).active
        assert FaultPlan(dropout=0.1).active
        # inactive schedules never fire
        mask, dropped, strag = NO_FAULTS.participation(0, 8)
        assert mask.all() and not dropped.any() and not strag.any()
        assert not NO_FAULTS.corrupt_mask(3, 8).any()
        assert not NO_FAULTS.link_drops(0, 0)
        assert not NO_FAULTS.payload_corrupted(0, 0)

    def test_rates_validated(self):
        for f in ("dropout", "straggler", "grad_nan", "link_loss",
                  "payload_corrupt"):
            with pytest.raises(ValueError, match=f):
                FaultPlan(**{f: 1.0})
            with pytest.raises(ValueError, match=f):
                FaultPlan(**{f: -0.1})

    def test_schedules_replay_order_independent(self):
        """Every draw is a pure function of (seed, coords): querying in any
        order — or twice — replays the same faults."""
        p = FaultPlan(seed=5, dropout=0.4, straggler=0.2, grad_nan=0.3,
                      link_loss=0.3, payload_corrupt=0.3)
        fwd = [p.participation(r, 6)[0] for r in range(8)]
        bwd = [p.participation(r, 6)[0] for r in reversed(range(8))]
        for a, b in zip(fwd, reversed(bwd)):
            np.testing.assert_array_equal(a, b)
        assert p.link_drops(11, 2) == p.link_drops(11, 2)
        # distinct plans/coords decorrelate
        q = FaultPlan(seed=6, dropout=0.4)
        assert any((p.participation(r, 64)[0]
                    != q.participation(r, 64)[0]).any() for r in range(4))

    def test_participation_partitions_clusters(self):
        p = FaultPlan(seed=1, dropout=0.5, straggler=0.5)
        mask, dropped, strag = p.participation(0, 256)
        # stragglers and dropped are disjoint; mask is everyone else
        assert not (dropped & strag).any()
        np.testing.assert_array_equal(mask, ~(dropped | strag))
        assert 0 < mask.sum() < 256

    def test_corrupt_payload_always_caught_by_checksum(self):
        p = FaultPlan(seed=2, payload_corrupt=0.5)
        tree = {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
                "b": jnp.ones((5,), jnp.float32)}
        chk = payload_checksum(tree)
        for t in range(5):
            bad = p.corrupt_payload(tree, t, 0)
            assert payload_checksum(bad) != chk
        # the original is never mutated in place
        assert payload_checksum(tree) == chk


# ---------------------------------------------------------------------------
# Partial-participation fused rounds
# ---------------------------------------------------------------------------

class TestMaskedRound:
    # classify (the integrated runtime's loss) stays tier-1; LM rides slow
    @pytest.mark.parametrize("kind", [
        "classify", pytest.param("lm", marks=pytest.mark.slow)])
    def test_all_ones_mask_bitwise_identical(self, kind):
        """A fully-participating masked round IS the plain round — bitwise,
        state and metrics (the all-off plan costs nothing, ISSUE 6)."""
        cfg = small_cfg()
        opt = adamw(5e-3)
        state = hfsl.init_hfsl_state(KEY, cfg, N, opt, M.init)
        if kind == "classify":
            bank, loss_fn = classify_bank(cfg), M.classify_loss
        else:
            bank, loss_fn = lm_bank(cfg), M.lm_loss
        rnd = hfsl.make_hfsl_round(cfg, opt, loss_fn, steps=K, sync_every=3)
        s_plain, m_plain = rnd(state, bank.arrays, 0)
        s_mask, m_mask = rnd(state, bank.arrays, 0,
                             mask=jnp.ones((N,), jnp.float32),
                             corrupt=jnp.zeros((N,), bool))
        assert_trees_equal(s_plain["adapters_c"], s_mask["adapters_c"])
        assert_trees_equal(s_plain["opt"], s_mask["opt"])
        np.testing.assert_array_equal(np.asarray(m_plain["loss"]),
                                      np.asarray(m_mask["loss"]))

    def test_dropped_cluster_carried_bit_unchanged(self):
        """A masked-out cluster trains nothing and syncs nothing: its
        replica and opt state come back BIT-identical; survivors move."""
        cfg = small_cfg()
        opt = adamw(5e-3)
        state = hfsl.init_hfsl_state(KEY, cfg, N, opt, M.init)
        bank = classify_bank(cfg)
        rnd = hfsl.make_hfsl_round(cfg, opt, M.classify_loss, steps=K,
                                   sync_every=3)
        mask = jnp.asarray([0.0, 1.0, 1.0])
        s, m = rnd(state, bank.arrays, 0, mask=mask)

        def row(tree, i):
            return jax.tree.map(lambda x: x[i], tree)

        assert_trees_equal(row(s["adapters_c"], 0), row(state["adapters_c"], 0))
        assert_trees_equal(row(s["opt"], 0), row(state["opt"], 0))
        moved = any(
            not np.array_equal(np.asarray(x[1]), np.asarray(y[1]))
            for x, y in zip(jax.tree.leaves(s["adapters_c"]),
                            jax.tree.leaves(state["adapters_c"])))
        assert moved
        # the ledger saw it every step
        np.testing.assert_array_equal(np.asarray(m["participating"]),
                                      np.full(K, 2.0, np.float32))
        np.testing.assert_array_equal(np.asarray(m["dropped"]),
                                      np.full(K, 1.0, np.float32))
        assert np.isfinite(np.asarray(m["loss"])).all()

    def test_corrupt_cluster_skipped_and_state_stays_finite(self):
        """A NaN-poisoned cluster trips the in-scan non-finite guard: its
        update is where-skipped every step, nothing non-finite ever lands
        in any replica, and the skip is counted."""
        cfg = small_cfg()
        opt = adamw(5e-3)
        state = hfsl.init_hfsl_state(KEY, cfg, N, opt, M.init)
        bank = classify_bank(cfg)
        rnd = hfsl.make_hfsl_round(cfg, opt, M.classify_loss, steps=K,
                                   sync_every=3)
        corrupt = jnp.asarray([True, False, False])
        s, m = rnd(state, bank.arrays, 0, corrupt=corrupt)
        for x in jax.tree.leaves(s["adapters_c"]):
            assert np.isfinite(np.asarray(x, np.float32)).all()
        assert np.asarray(m["skipped"]).sum() == K     # poisoned every step
        assert np.isfinite(np.asarray(m["loss"])).all()

    def test_fedavg_masked_semantics(self):
        """Survivors average over survivors ONLY; masked-out clusters keep
        their own replica (carried, not overwritten)."""
        tree = {"w": jnp.asarray([[1.0], [5.0], [9.0]])}
        out = hfsl.fedavg_masked(tree, jnp.asarray([1.0, 0.0, 1.0]))
        np.testing.assert_allclose(np.asarray(out["w"]),
                                   [[5.0], [5.0], [5.0]])
        # all-ones == plain fedavg bitwise
        ones = hfsl.fedavg_masked(tree, jnp.ones((3,)))
        assert_trees_equal(ones, hfsl.fedavg(tree))


# ---------------------------------------------------------------------------
# Lossy relay: retry, backoff, checksum
# ---------------------------------------------------------------------------

def _relay_roundtrip(relay, ups):
    relay.cloud_deliver("a")
    relay.edge_deliver("a", N)
    relay.edge_absorb("a", ups)
    relay.cloud_aggregate()


class TestLossyRelay:
    def _adapters(self):
        return {"w": jnp.arange(8, dtype=jnp.float32).reshape(2, 4)}

    def test_all_off_plan_bitwise_identical_accounting(self):
        """faults=None, faults=NO_FAULTS, and no-kwarg construction produce
        the SAME ledger and the SAME RoundCost."""
        ad = self._adapters()
        ups = [jax.tree.map(lambda x: x + i, ad) for i in range(2)]
        relays = [KnowledgeRelay(ad, ["a", "b"]),
                  KnowledgeRelay(ad, ["a", "b"], faults=None),
                  KnowledgeRelay(ad, ["a", "b"], faults=NO_FAULTS)]
        for r in relays:
            _relay_roundtrip(r, ups)
        for r in relays[1:]:
            assert r.ledger == relays[0].ledger
            assert r.cost == relays[0].cost
        assert relays[0].ledger.retries == 0
        assert relays[0].ledger.retransmit_bytes == 0

    def test_lossy_link_retries_are_ledgered(self):
        ad = self._adapters()
        ups = [jax.tree.map(lambda x: x + i, ad) for i in range(2)]
        plan = FaultPlan(seed=3, link_loss=0.5)
        r = KnowledgeRelay(ad, ["a", "b"], faults=plan, max_retries=50,
                           backoff_s=0.0)
        clean = KnowledgeRelay(ad, ["a", "b"])
        for _ in range(3):
            _relay_roundtrip(r, ups)
            _relay_roundtrip(clean, ups)
        assert r.ledger.retries > 0
        assert r.ledger.retransmit_bytes > 0
        # the RoundCost ledger mirrors the byte ledger exactly
        assert r.cost.retries == r.ledger.retries
        assert r.cost.retransmit_bytes == r.ledger.retransmit_bytes
        # wire bytes = logical bytes + retransmissions
        assert r.ledger.total() == clean.ledger.total() + \
            r.ledger.retransmit_bytes
        # payloads still arrive intact: same final state as the clean relay
        assert_trees_equal(r.cloud, clean.cloud)
        assert_trees_equal(r.edges["a"], clean.edges["a"])

    def test_checksum_rejects_corruption_payload_survives(self):
        """Bit-corrupted deliveries are rejected by CRC32 and retried — the
        receiver NEVER sees a corrupted tree."""
        ad = self._adapters()
        ups = [jax.tree.map(lambda x: x + i, ad) for i in range(3)]
        plan = FaultPlan(seed=4, payload_corrupt=0.6)
        r = KnowledgeRelay(ad, ["a"], faults=plan, max_retries=50,
                           backoff_s=0.0)
        clean = KnowledgeRelay(ad, ["a"])
        _relay_roundtrip(r, ups)
        _relay_roundtrip(clean, ups)
        assert r.ledger.retries > 0                 # corruption actually fired
        assert_trees_equal(r.edges["a"], clean.edges["a"])
        assert_trees_equal(r.cloud, clean.cloud)

    def test_exhausted_retry_budget_raises(self):
        plan = FaultPlan(seed=0, link_loss=0.99)
        r = KnowledgeRelay(self._adapters(), ["a"], faults=plan,
                           max_retries=2, backoff_s=0.0)
        with pytest.raises(RelayTransferError, match="giving up"):
            for _ in range(50):
                r.cloud_deliver("a")

    def test_backoff_latency_is_booked(self):
        plan = FaultPlan(seed=3, link_loss=0.5)
        r = KnowledgeRelay(self._adapters(), ["a"], faults=plan,
                           max_retries=50, backoff_s=0.25, backoff_cap_s=1.0)
        clean = KnowledgeRelay(self._adapters(), ["a"])
        for _ in range(5):
            r.cloud_deliver("a")
            clean.cloud_deliver("a")
        assert r.ledger.retries > 0
        assert r.cost.latency_s >= clean.cost.latency_s + \
            0.25 * r.ledger.retries * 0.99  # capped exp backoff >= base each

    def test_backoff_jitter_is_seeded_and_replayable(self):
        """Retry backoff carries a per-(transfer, attempt) jitter draw from
        the plan's SeedSequence: replaying the same plan books the exact
        same latency; a different seed books a different one. The jitter
        is multiplicative in [1, 2) so it never undercuts the base delay."""
        def run(seed):
            plan = FaultPlan(seed=seed, link_loss=0.5)
            r = KnowledgeRelay(self._adapters(), ["a"], faults=plan,
                               max_retries=50, backoff_s=0.25,
                               backoff_cap_s=1.0)
            for _ in range(5):
                r.cloud_deliver("a")
            return r
        a, b, c = run(3), run(3), run(11)
        assert a.ledger.retries > 0
        assert a.cost.latency_s == b.cost.latency_s      # exact replay
        assert a.ledger.retries == b.ledger.retries
        assert a.cost.latency_s != c.cost.latency_s      # seed matters
        # raw draws are deterministic, in [0, 1), and distinct across
        # attempts (the de-synchronization the jitter exists for)
        plan = FaultPlan(seed=3, link_loss=0.5)
        d1 = [plan.retry_jitter(0, i) for i in range(4)]
        d2 = [plan.retry_jitter(0, i) for i in range(4)]
        assert d1 == d2
        assert all(0.0 <= u < 1.0 for u in d1)
        assert len(set(d1)) == len(d1)

    def test_inactive_plan_books_no_jitter(self):
        """The all-off plan takes the exact pre-jitter happy path: booked
        cost is bitwise identical to running with no plan at all."""
        off = KnowledgeRelay(self._adapters(), ["a"],
                             faults=FaultPlan(seed=0))
        none = KnowledgeRelay(self._adapters(), ["a"])
        for _ in range(3):
            off.cloud_deliver("a")
            none.cloud_deliver("a")
        assert off.cost.latency_s == none.cost.latency_s
        assert off.ledger.retries == 0 and off.cost.retries == 0


# ---------------------------------------------------------------------------
# Last-known-good serving
# ---------------------------------------------------------------------------

class TestBankLKG:
    def _bank(self, cfg):
        return AdapterBank.create(tiny_adapters(cfg))

    def test_publish_rejects_nonfinite(self):
        cfg = small_cfg()
        bank = self._bank(cfg)
        before = bank.snapshot("d0")
        bad = jax.tree.map(lambda x: x * jnp.nan, before)
        v0 = bank.version("d0")
        with pytest.raises(ValueError, match="non-finite"):
            bank.publish("d0", bad)
        assert bank.version("d0") == v0             # still serving the old one
        assert_trees_equal(bank.snapshot("d0"), before)

    def test_publish_rejects_wrong_shape_and_structure(self):
        cfg = small_cfg()
        bank = self._bank(cfg)
        good = bank.snapshot("d0")
        wrong = jax.tree.map(
            lambda x: jnp.zeros(x.shape + (2,), x.dtype), good)
        with pytest.raises(ValueError, match="shape"):
            bank.publish("d0", wrong)
        with pytest.raises(ValueError, match="missing subtree"):
            bank.publish("d0", {"head": good["head"]})
        with pytest.raises(KeyError, match="no adapter slot"):
            bank.publish("nope", good)

    def test_rollback_restores_pre_publish_state(self):
        """LKG is the slot as it was BEFORE the last validated publish:
        rollback serves exactly that, bitwise, and is idempotent."""
        cfg = small_cfg()
        bank = self._bank(cfg)
        before = bank.snapshot("d0")
        v_before = bank.version("d0")
        new = jax.tree.map(lambda x: x + 1.0, before)
        bank.publish("d0", new)
        assert bank.last_known_good_version("d0") == v_before
        v_back = bank.rollback("d0")
        assert v_back == v_before
        assert bank.rollbacks["d0"] == 1
        assert_trees_equal(bank.snapshot("d0"), before)
        bank.rollback("d0")                          # idempotent
        assert_trees_equal(bank.snapshot("d0"), before)
        # the untouched tenant never moved
        assert bank.rollbacks["d1"] == 0

    def test_rollback_without_validated_publish_raises(self):
        cfg = small_cfg()
        bank = self._bank(cfg)
        with pytest.raises(ValueError, match="no last-known-good"):
            bank.rollback("d0")


# ---------------------------------------------------------------------------
# Per-request serving deadlines
# ---------------------------------------------------------------------------

class TestEngineDeadline:
    def test_over_deadline_row_retires_survivor_unaffected(self):
        """A deadline-0 row times out mid-drain with partial tokens; the
        co-scheduled row still serves token-identically to solo decode."""
        cfg = get_config("vit-edge").reduced().with_(dtype="float32",
                                                     vocab_size=64)
        params = M.init(cfg, KEY)
        prompts = np.asarray(jax.random.randint(KEY, (2, 8), 0,
                                                cfg.vocab_size,
                                                dtype=jnp.int32))
        engine = DecodeEngine(cfg, slots=2)
        u_dead = engine.submit(prompts[0], 6, deadline_s=0.0)
        u_live = engine.submit(prompts[1], 6)
        comps, stats = engine.run(params)
        assert stats.timed_out == 1
        by_uid = {c.uid: c for c in comps}
        assert by_uid[u_dead].timed_out
        assert len(by_uid[u_dead].tokens) < 6        # partial, never stalls
        assert not by_uid[u_live].timed_out
        want = np.asarray(M.generate_scan(params, cfg,
                                          jnp.asarray(prompts[1:2]), gen=6))
        np.testing.assert_array_equal(by_uid[u_live].tokens, want[0])
        assert all(not s.active for s in engine.slot_table)   # no slot leak

    def test_submit_validation(self):
        cfg = get_config("vit-edge").reduced().with_(dtype="float32",
                                                     vocab_size=64)
        engine = DecodeEngine(cfg, slots=2)
        with pytest.raises(ValueError, match="non-empty 1-D"):
            engine.submit(np.zeros((0,), np.int32), 2)
        with pytest.raises(ValueError, match="non-empty 1-D"):
            engine.submit(np.zeros((2, 8), np.int32), 2)
        with pytest.raises(ValueError, match="deadline_s"):
            engine.submit(np.zeros(8, np.int32), 2, deadline_s=-1.0)
        assert engine.pending() == 0


# ---------------------------------------------------------------------------
# The whole virtuous cycle under chaos
# ---------------------------------------------------------------------------

def _runtime(faults=None, deadline_s=None, seed=0):
    from repro.core.integrated import IntegratedRuntime
    cfg = small_cfg()
    tasks = {n: ClassificationTask(5, cfg.vocab_size, SEQ, seed=i)
             for i, n in enumerate(["nlp", "cv"])}
    return IntegratedRuntime(cfg, tasks, n_clusters=4, steps_per_upgrade=4,
                             batch=4, sync_every=2, serve_batch=8,
                             serve_gen=2, serve_slots=4, seed=seed,
                             faults=faults, deadline_s=deadline_s)


# a policy that actually exercises upgrades (the default MLCP policy on a
# flat value model would produce every round and never touch the chaos path)
def _alternating_policy(r, levels):
    return r % 2 if r < 4 else 2


class TestIntegratedChaos:
    def test_all_off_plan_is_bitwise_invisible(self):
        """NO_FAULTS runtime == plan-less runtime: same records, same
        adapters, token-for-token (the happy path pays nothing)."""
        demand = ["nlp", "cv", "nlp", "cv", "nlp", "cv"]
        a = _runtime(faults=None)
        b = _runtime(faults=NO_FAULTS)
        ra = a.run(demand, policy=_alternating_policy)
        rb = b.run(demand, policy=_alternating_policy)
        assert [(x.action, x.domain, x.profit, x.accuracy) for x in ra] \
            == [(x.action, x.domain, x.profit, x.accuracy) for x in rb]
        for n in a.domains:
            assert_trees_equal(a.domains[n].adapters_c,
                               b.domains[n].adapters_c)

    def test_chaos_run_completes_and_serves_finite(self):
        """25-40% dropout + corruption: every round completes, drops and
        skips are ledgered, and the serving bank stays finite throughout."""
        plan = FaultPlan(seed=3, dropout=0.4, straggler=0.1, grad_nan=0.4)
        rt = _runtime(faults=plan)
        recs = rt.run(["nlp", "cv", "nlp", "cv", "nlp", "cv"],
                      policy=_alternating_policy)
        assert len(recs) == 6
        ups = [r for r in recs if r.action == "upgrade"]
        assert ups and all(np.isfinite(r.accuracy) for r in recs)
        assert sum(r.cost.dropped_clusters for r in ups) > 0
        assert sum(r.cost.skipped_updates for r in ups) > 0
        for x in jax.tree.leaves(rt.bank.stacked):
            assert np.isfinite(np.asarray(x, np.float32)).all()
        # survivors-only comm: chaos rounds book <= the full-strength bytes
        full = _runtime(faults=None)
        f = [r for r in full.run(["nlp", "cv"], policy=_alternating_policy)
             if r.action == "upgrade"][0]
        assert all(r.cost.comm_bytes <= f.cost.comm_bytes for r in ups)

    def test_poisoned_publish_rolls_back_to_lkg(self):
        """A consensus gone non-finite is refused at the bank door and the
        slot rolls back to last-known-good — live traffic never sees NaN."""
        rt = _runtime()
        rt.upgrade("nlp")                            # a validated publish
        good = rt.bank.snapshot("nlp")
        poisoned = jax.tree.map(lambda x: x * jnp.nan, good)
        with pytest.raises(ValueError, match="non-finite"):
            rt.bank.publish("nlp", poisoned)
        assert_trees_equal(rt.bank.snapshot("nlp"), good)   # still serving
        rt.bank.publish("nlp", jax.tree.map(lambda x: x + 1.0, good))
        rt.bank.rollback("nlp")
        assert_trees_equal(rt.bank.snapshot("nlp"), good)
        # and end-to-end: a runtime whose round NaNs out refuses the publish
        # (counted) instead of serving it
        assert rt.publish_rejects == 0

    def test_deadline_timeouts_are_ledgered(self):
        rt = _runtime(deadline_s=0.0)
        profit, cost = rt.produce(["nlp", "cv"])
        assert cost.timed_out == 8                   # every request over budget
        assert np.isfinite(profit)

    def test_chaos_save_restore_resumes_identically(self, tmp_path):
        """Checkpoint mid-chaos, restore into a FRESH same-config runtime:
        the continuation replays the same fault schedule and produces the
        SAME records and the SAME adapters as the uninterrupted run."""
        plan = FaultPlan(seed=9, dropout=0.3, grad_nan=0.3)
        demand1 = ["nlp", "cv", "nlp", "cv"]
        demand2 = ["cv", "nlp", "cv", "nlp"]

        gold = _runtime(faults=plan)
        gold.run(demand1, policy=_alternating_policy)
        tail_gold = gold.run(demand2, policy=_alternating_policy)[4:]

        a = _runtime(faults=plan)
        a.run(demand1, policy=_alternating_policy)
        p = str(tmp_path / "chaos_ck")
        a.save(p)

        b = _runtime(faults=plan, seed=0)
        b.restore(p)
        tail_b = b.run(demand2, policy=_alternating_policy)
        assert [(x.action, x.domain, x.profit, x.accuracy)
                for x in tail_b] \
            == [(x.action, x.domain, x.profit, x.accuracy)
                for x in tail_gold]
        for n in gold.domains:
            assert_trees_equal(gold.domains[n].adapters_c,
                               b.domains[n].adapters_c)
            assert int(gold.domains[n].step) == int(b.domains[n].step)
            assert gold.versions_of(n) == b.versions_of(n)
