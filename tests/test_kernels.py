"""Per-kernel allclose sweeps: Pallas (interpret=True) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(42)


def tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# Flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,T,Hq,Hkv,D", [
    (1, 16, 16, 1, 1, 8),
    (2, 48, 56, 4, 2, 32),          # GQA + prefix slots
    (1, 64, 64, 4, 4, 64),
    (2, 33, 40, 2, 1, 16),          # ragged (padding path)
])
@pytest.mark.parametrize("window", [0, 16])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("backend", ["xla", "interpret"])
def test_flash_attention(B, S, T, Hq, Hkv, D, window, dtype, backend):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, Hq, D), dtype)
    k = jax.random.normal(ks[1], (B, T, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, T, Hkv, D), dtype)
    n_p = T - S
    q_pos = jnp.arange(S)
    kv_pos = jnp.arange(T) - n_p
    want = ref.attention(q, k, v, q_pos=q_pos, kv_pos=kv_pos, window=window)
    got = ops.flash_attention(q, k, v, q_pos=q_pos, kv_pos=kv_pos,
                              window=window, block_q=16, block_kv=16,
                              backend=backend)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


def test_flash_attention_noncausal():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 24, 2, 16))
    k = jax.random.normal(ks[1], (2, 30, 2, 16))
    v = jax.random.normal(ks[2], (2, 30, 2, 16))
    qp, kp = jnp.arange(24), jnp.arange(30)
    want = ref.attention(q, k, v, q_pos=qp, kv_pos=kp, causal=False)
    for backend in ("xla", "interpret"):
        got = ops.flash_attention(q, k, v, q_pos=qp, kv_pos=kp, causal=False,
                                  block_q=8, block_kv=8, backend=backend)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# Selective scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,Di,N", [(1, 32, 128, 8), (2, 64, 256, 16),
                                      (2, 128, 512, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("with_h0", [False, True])
def test_selective_scan(B, S, Di, N, dtype, with_h0):
    ks = jax.random.split(KEY, 6)
    x = (jax.random.normal(ks[0], (B, S, Di)) * 0.5).astype(dtype)
    dt = (jax.nn.softplus(jax.random.normal(ks[1], (B, S, Di))) * 0.1).astype(dtype)
    A = -jnp.exp(jax.random.normal(ks[2], (Di, N)) * 0.3)
    Bm = (jax.random.normal(ks[3], (B, S, N)) * 0.5).astype(dtype)
    C = (jax.random.normal(ks[4], (B, S, N)) * 0.5).astype(dtype)
    D = jnp.ones((Di,))
    h0 = jax.random.normal(ks[5], (B, Di, N)) * 0.1 if with_h0 else None
    y_ref, h_ref = ref.selective_scan(x, dt, A, Bm, C, D, h0)
    y, h = ops.selective_scan(x, dt, A, Bm, C, D, h0, backend="interpret")
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32), **tol(dtype))
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                               atol=1e-3, rtol=1e-3)


def test_selective_scan_step_matches_seq():
    """Decode step telescopes to the full scan."""
    B, S, Di, N = 2, 8, 64, 8
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, S, Di)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, Di))) * 0.2
    A = -jnp.exp(jax.random.normal(ks[2], (Di, N)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, N)) * 0.5
    C = jax.random.normal(ks[4], (B, S, N)) * 0.5
    D = jnp.ones((Di,))
    y_ref, h_ref = ref.selective_scan(x, dt, A, Bm, C, D)
    h = jnp.zeros((B, Di, N))
    ys = []
    for t in range(S):
        y, h = ops.selective_scan_step(x[:, t], dt[:, t], A, Bm[:, t],
                                       C[:, t], D, h)
        ys.append(y)
    np.testing.assert_allclose(np.stack(ys, 1), np.asarray(y_ref),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,W", [(1, 32, 128), (2, 64, 256), (2, 96, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rglru(B, S, W, dtype):
    ks = jax.random.split(KEY, 5)
    x = (jax.random.normal(ks[0], (B, S, W)) * 0.5).astype(dtype)
    r = jax.random.normal(ks[1], (B, S, W)).astype(dtype)
    i = jax.random.normal(ks[2], (B, S, W)).astype(dtype)
    a = jax.random.normal(ks[3], (W,))
    h0 = jax.random.normal(ks[4], (B, W)) * 0.1
    hs_ref, hT_ref = ref.rglru(x, r, i, a, h0)
    hs, hT = ops.rglru(x, r, i, a, h0, backend="interpret")
    np.testing.assert_allclose(np.asarray(hs, np.float32),
                               np.asarray(hs_ref, np.float32), **tol(dtype))
    np.testing.assert_allclose(np.asarray(hT), np.asarray(hT_ref),
                               atol=1e-3, rtol=1e-3)


def test_rglru_step_matches_seq():
    B, S, W = 2, 12, 64
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (B, S, W)) * 0.5
    r = jax.random.normal(ks[1], (B, S, W))
    i = jax.random.normal(ks[2], (B, S, W))
    a = jax.random.normal(ks[3], (W,))
    hs_ref, hT_ref = ref.rglru(x, r, i, a)
    h = jnp.zeros((B, W))
    for t in range(S):
        y, h = ops.rglru_step(x[:, t], r[:, t], i[:, t], a, h)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hT_ref),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# LoRA matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("M,K,N,r", [(32, 64, 48, 4), (100, 200, 300, 8),
                                     (256, 512, 512, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("with_bias", [False, True])
def test_lora_matmul(M, K, N, r, dtype, with_bias):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (M, K), dtype)
    w = (jax.random.normal(ks[1], (K, N)) * 0.05).astype(dtype)
    a = (jax.random.normal(ks[2], (K, r)) * 0.05).astype(dtype)
    b = (jax.random.normal(ks[3], (r, N)) * 0.05).astype(dtype)
    bias = jax.random.normal(ks[4], (N,)).astype(dtype) if with_bias else None
    want = ref.lora_matmul(x, w, a, b, 2.0, bias)
    got = ops.lora_matmul(x, w, a, b, 2.0, bias, backend="interpret")
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


@pytest.mark.parametrize("M,K,N,r", [(32, 64, 48, 4), (100, 200, 144, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("backend", ["xla", "interpret"])
def test_lora_matmul_custom_vjp(M, K, N, r, dtype, backend):
    """grad through the fused kernel == einsum oracle: dx, dA, dB, dbias
    (adapter grads only — the frozen dW is never formed)."""
    ks = jax.random.split(KEY, 6)
    x = jax.random.normal(ks[0], (M, K), dtype)
    w = (jax.random.normal(ks[1], (K, N)) * 0.05).astype(dtype)
    a = (jax.random.normal(ks[2], (K, r)) * 0.05).astype(dtype)
    b = (jax.random.normal(ks[3], (r, N)) * 0.05).astype(dtype)
    bias = jax.random.normal(ks[4], (N,)).astype(dtype)
    dy = jax.random.normal(ks[5], (M, N), dtype)

    def f(x_, a_, b_, bias_):
        y = ops.lora_matmul(x_, w, a_, b_, 2.0, bias_, backend=backend)
        return jnp.sum(y.astype(jnp.float32) * dy.astype(jnp.float32))

    dx, da, db, dbias = jax.grad(f, argnums=(0, 1, 2, 3))(x, a, b, bias)
    rdx, rda, rdb = ref.lora_matmul_bwd(x, w, a, b, 2.0, dy)
    # grads accumulate over M rows — bf16 native-dtype dots round harder
    # than the single forward pass
    t = dict(atol=1e-1, rtol=5e-2) if dtype == jnp.bfloat16 else tol(dtype)
    np.testing.assert_allclose(np.asarray(dx, np.float32),
                               np.asarray(rdx, np.float32), **t)
    np.testing.assert_allclose(np.asarray(da, np.float32),
                               np.asarray(rda, np.float32), **t)
    np.testing.assert_allclose(np.asarray(db, np.float32),
                               np.asarray(rdb, np.float32), **t)
    np.testing.assert_allclose(
        np.asarray(dbias, np.float32),
        np.asarray(jnp.sum(dy.astype(jnp.float32), 0)), **t)


@pytest.mark.parametrize("backend", ["xla", "interpret"])
def test_lora_matmul_vjp_full_ft_dw(backend):
    """Full fine-tuning (peft trainable='all') must still receive the exact
    frozen-weight grad dW = x^T dy through the custom VJP."""
    ks = jax.random.split(KEY, 5)
    M, K, N, r = 24, 32, 40, 4
    x = jax.random.normal(ks[0], (M, K))
    w = jax.random.normal(ks[1], (K, N)) * 0.05
    a = jax.random.normal(ks[2], (K, r)) * 0.05
    b = jax.random.normal(ks[3], (r, N)) * 0.05
    dy = jax.random.normal(ks[4], (M, N))

    def f(w_):
        return jnp.vdot(ops.lora_matmul(x, w_, a, b, 2.0, backend=backend),
                        dy)

    dw = jax.grad(f)(w)
    np.testing.assert_allclose(np.asarray(dw), np.asarray(x.T @ dy),
                               atol=2e-5, rtol=2e-5)


def test_lora_matmul_vjp_under_vmap():
    """The HFSL shape: per-cluster adapters vmapped over the cluster dim."""
    ks = jax.random.split(KEY, 5)
    M, K, N, r, C = 16, 32, 24, 4, 3
    x = jax.random.normal(ks[0], (M, K))
    w = jax.random.normal(ks[1], (K, N)) * 0.05
    av = jax.random.normal(ks[2], (C, K, r)) * 0.05
    bv = jax.random.normal(ks[3], (C, r, N)) * 0.05
    dy = jax.random.normal(ks[4], (M, N))

    def f(a_, b_):
        return jnp.vdot(ops.lora_matmul(x, w, a_, b_, 2.0,
                                        backend="interpret"), dy)

    da, db = jax.vmap(jax.grad(f, argnums=(0, 1)))(av, bv)
    for c in range(C):
        _, rda, rdb = ref.lora_matmul_bwd(x, w, av[c], bv[c], 2.0, dy)
        np.testing.assert_allclose(np.asarray(da[c]), np.asarray(rda),
                                   atol=2e-5, rtol=2e-5)
        np.testing.assert_allclose(np.asarray(db[c]), np.asarray(rdb),
                                   atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# Batched multi-LoRA (BGMV, multi-tenant serving)
# ---------------------------------------------------------------------------

def _bgmv_operands(M, K, N, r, n_slots, dtype, with_bias, seq=None):
    ks = jax.random.split(KEY, 6)
    shape = (M, K) if seq is None else (M, seq, K)
    x = jax.random.normal(ks[0], shape, dtype)
    w = (jax.random.normal(ks[1], (K, N)) * 0.05).astype(dtype)
    a = (jax.random.normal(ks[2], (n_slots, K, r)) * 0.05).astype(dtype)
    b = (jax.random.normal(ks[3], (n_slots, r, N)) * 0.05).astype(dtype)
    bias = jax.random.normal(ks[4], (N,)).astype(dtype) if with_bias else None
    ids = jax.random.randint(ks[5], (M,), 0, n_slots, dtype=jnp.int32)
    return x, w, a, b, bias, ids


@pytest.mark.parametrize("M,K,N,r,n_slots", [
    (16, 32, 24, 4, 3),
    (100, 200, 144, 8, 5),           # padding path
    (8, 64, 48, 4, 1),               # degenerate single tenant
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("with_bias", [False, True])
@pytest.mark.parametrize("backend", ["xla", "interpret"])
def test_lora_bgmv_rows(M, K, N, r, n_slots, dtype, with_bias, backend):
    """Decode shape: one adapter_id per row, vs the gather oracle."""
    x, w, a, b, bias, ids = _bgmv_operands(M, K, N, r, n_slots, dtype,
                                           with_bias)
    want = ref.lora_bgmv(x, w, a, b, ids, 2.0, bias)
    got = ops.lora_bgmv(x, w, a, b, ids, 2.0, bias, backend=backend)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


@pytest.mark.parametrize("B,S,K,N,r,n_slots", [
    (4, 12, 32, 24, 4, 3),
    (3, 9, 96, 80, 8, 4),            # padding path
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("backend", ["xla", "interpret"])
def test_lora_bgmv_seq(B, S, K, N, r, n_slots, dtype, backend):
    """Prefill shape: one adapter_id per sequence (gathered path)."""
    x, w, a, b, bias, ids = _bgmv_operands(B, K, N, r, n_slots, dtype,
                                           True, seq=S)
    want = ref.lora_bgmv(x, w, a, b, ids, 2.0, bias)
    got = ops.lora_bgmv(x, w, a, b, ids, 2.0, bias, backend=backend)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


def test_lora_bgmv_matches_single_lora_per_row():
    """The multi-tenant == single-tenant parity the engine relies on:
    every row's result is bit-identical to the single-LoRA fast path run
    with that row's adapter pair (XLA backends share the same dot
    structure and cast points)."""
    M, K, N, r, n_slots = 24, 32, 40, 4, 3
    x, w, a, b, bias, ids = _bgmv_operands(M, K, N, r, n_slots,
                                           jnp.float32, True)
    got = np.asarray(ops.lora_bgmv(x, w, a, b, ids, 2.0, bias,
                                   backend="xla"))
    for s in range(n_slots):
        rows = np.asarray(ids) == s
        want = ops.lora_matmul(x[rows], w, a[s], b[s], 2.0, bias,
                               backend="xla")
        np.testing.assert_array_equal(got[rows], np.asarray(want))


# ---------------------------------------------------------------------------
# Paged flash decode (block-table indirection)
# ---------------------------------------------------------------------------

def _paged_operands(B, maxb, bs, Hq, Hkv, D, n_blocks, dtype, seed=0):
    """A random block pool plus per-row tables of distinct live blocks."""
    ks = jax.random.split(jax.random.fold_in(KEY, seed), 3)
    q = jax.random.normal(ks[0], (B, Hq, D), dtype)
    k_pool = jax.random.normal(ks[1], (n_blocks, bs, Hkv, D), dtype)
    v_pool = jax.random.normal(ks[2], (n_blocks, bs, Hkv, D), dtype)
    rng = np.random.default_rng(seed)
    table = np.stack([rng.choice(n_blocks, maxb, replace=False)
                      for _ in range(B)]).astype(np.int32)
    return q, k_pool, v_pool, jnp.asarray(table)


@pytest.mark.parametrize("B,maxb,bs,Hq,Hkv,D", [
    (1, 2, 16, 1, 1, 8),
    (2, 4, 8, 4, 2, 32),            # GQA + ragged q_pos
    (3, 3, 16, 2, 1, 16),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("backend", ["xla", "interpret"])
def test_paged_flash_decode_matches_ref(B, maxb, bs, Hq, Hkv, D, dtype,
                                        backend):
    """Block-table-indirected decode == the pure-jnp paged oracle, with
    ragged per-row positions leaving trailing pool slots invisible."""
    q, k_pool, v_pool, table = _paged_operands(B, maxb, bs, Hq, Hkv, D,
                                               n_blocks=maxb * B + 3,
                                               dtype=dtype)
    q_pos = jnp.asarray([(maxb * bs - 1 - 3 * i) % (maxb * bs)
                         for i in range(B)], jnp.int32)
    want = ref.paged_decode_attention(q, k_pool, v_pool, table, q_pos=q_pos)
    got = ops.flash_decode_paged(q, k_pool, v_pool, table, q_pos=q_pos,
                                 backend=backend)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


@pytest.mark.parametrize("backend", ["xla", "interpret"])
def test_paged_flash_decode_bit_parity_with_dense(backend):
    """fp32 paged-vs-dense: gathering the pool through the table into the
    dense layout and running the dense decode path sees the SAME visible
    values, so on xla (identical accumulation order — the path engine
    drains take) the outputs are BITWISE equal; the pallas kernels chunk
    kv differently (one chunk per block vs block_kv), so interpret holds
    to fp32 tolerance instead."""
    B, maxb, bs, Hq, Hkv, D = 2, 4, 8, 4, 2, 32
    q, k_pool, v_pool, table = _paged_operands(B, maxb, bs, Hq, Hkv, D,
                                               n_blocks=16, dtype=jnp.float32)
    q_pos = jnp.asarray([maxb * bs - 1, maxb * bs - 9], jnp.int32)
    k = k_pool[table].reshape(B, maxb * bs, Hkv, D)
    v = v_pool[table].reshape(B, maxb * bs, Hkv, D)
    kv_pos = jnp.arange(maxb * bs, dtype=jnp.int32)
    dense = ops.flash_decode(q, k, v, q_pos=q_pos, kv_pos=kv_pos,
                             window=0, causal=True, backend=backend)
    paged = ops.flash_decode_paged(q, k_pool, v_pool, table, q_pos=q_pos,
                                   backend=backend)
    if backend == "xla":
        np.testing.assert_array_equal(np.asarray(paged), np.asarray(dense))
    else:
        np.testing.assert_allclose(np.asarray(paged), np.asarray(dense),
                                   **tol(jnp.float32))
