"""Integrated fine-tune-or-infer runtime tests (paper §IV on real models)."""
import dataclasses

import jax
import pytest

from repro.configs.base import get_config
from repro.core.integrated import IntegratedRuntime
from repro.data.synthetic import ClassificationTask


@pytest.fixture(scope="module")
def runtime():
    cfg = get_config("vit-edge").reduced().with_(dtype="float32",
                                                 vocab_size=64)
    cfg = cfg.with_(peft=dataclasses.replace(cfg.peft, head_dim_out=5))
    tasks = {
        "nlp": ClassificationTask(5, 64, 48, class_strength=0.6, seed=0),
        "cv": ClassificationTask(5, 64, 48, class_strength=0.6, seed=7),
    }
    return IntegratedRuntime(cfg, tasks, n_clusters=2, steps_per_upgrade=15,
                             serve_batch=32, seed=0)


def test_upgrade_improves_accuracy(runtime):
    before = runtime.domains["nlp"].accuracy
    profit, cost = runtime.upgrade("nlp")
    after = runtime.domains["nlp"].accuracy
    assert profit == -runtime.upgrade_cost
    assert after > before - 0.05            # fine-tuning helps (noise slack)
    assert runtime.domains["nlp"].level == 1
    assert cost.comm_bytes > 0
    # fine-tuning throughput ledger (the serving tok/s twin)
    assert cost.examples == runtime.steps * runtime.n_clusters * runtime.batch
    assert cost.ex_per_s > 0


def test_upgrade_persists_hfsl_step_counter(runtime):
    """The sync_every FedAvg phase must continue across upgrade rounds
    instead of restarting at zero each round."""
    start = int(runtime.domains["cv"].step)
    runtime.upgrade("cv")
    mid = int(runtime.domains["cv"].step)
    runtime.upgrade("cv")
    assert mid == start + runtime.steps
    assert int(runtime.domains["cv"].step) == start + 2 * runtime.steps


def test_produce_books_accuracy_profit(runtime):
    profit, cost = runtime.produce("cv")
    assert 0.0 <= profit <= runtime.profit_scale
    assert cost.latency_s > 0


def test_scheduled_run_mixes_services(runtime):
    demand = ["nlp", "nlp", "cv", "nlp", "nlp", "nlp"]
    records = runtime.run(demand)
    assert len(records) == len(demand)
    actions = {r.action for r in records}
    assert "produce" in actions             # serving happens
    assert records[-1].cumulative == runtime.total_profit()
    # upgraded domains end above their cold-start accuracy
    for name, d in runtime.domains.items():
        if d.level > 0:
            assert d.accuracy >= 0.2        # at least chance after tuning
