"""int8 adapter transport: size and fidelity (beyond-paper edge optimization)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import io as ckpt
from repro.configs.base import get_config
from repro.models import model as M

KEY = jax.random.PRNGKey(0)


def _cfg():
    cfg = get_config("vit-edge").reduced().with_(dtype="float32",
                                                 vocab_size=64)
    return cfg.with_(peft=dataclasses.replace(cfg.peft, head_dim_out=5))


def test_quantized_smaller_than_fp(tmp_path):
    cfg = _cfg()
    params = M.init(cfg, KEY)
    fp = ckpt.save_adapters(str(tmp_path / "fp"), params)
    q8 = ckpt.save_adapters_quantized(str(tmp_path / "q8"), params)
    assert q8 < fp * 0.6, (q8, fp)


def test_quantized_roundtrip_preserves_predictions(tmp_path):
    cfg = _cfg()
    params = M.init(cfg, KEY)
    # non-trivial adapters
    params["adapters"] = jax.tree.map(
        lambda x: x + 0.05 * jax.random.normal(KEY, x.shape, x.dtype),
        params["adapters"])
    p = str(tmp_path / "q8")
    ckpt.save_adapters_quantized(p, params)
    restored = ckpt.load_adapters_quantized(p, params)
    # elementwise error bounded by the int8 step size per row
    for a, b in zip(jax.tree.leaves(params["adapters"]),
                    jax.tree.leaves(restored["adapters"])):
        af = np.asarray(a, np.float32)
        step = np.abs(af).max() / 127.0 + 1e-12
        assert np.abs(af - np.asarray(b, np.float32)).max() <= step + 1e-6
    # predictions survive quantization
    batch = {"tokens": jax.random.randint(KEY, (8, 16), 0, cfg.vocab_size)}
    la = M.classify(params, batch, cfg)
    lb = M.classify(restored, batch, cfg)
    agree = float(np.mean(np.argmax(np.asarray(la), -1)
                          == np.argmax(np.asarray(lb), -1)))
    assert agree >= 0.75, agree
