"""Dry-run pipeline smoke test (subprocess: needs forced host devices).

Runs the REAL dryrun code path (build_lowered -> compile -> roofline walk)
on a small 4x4 mesh with reduced configs — proving the lower/compile/
roofline machinery works per family without the 512-way cost.
"""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")

CASES = [
    ("qwen2-7b", "train_4k"),
    ("granite-moe-1b-a400m", "decode_32k"),
    ("falcon-mamba-7b", "prefill_32k"),
    ("whisper-small", "train_4k"),
]


@pytest.mark.slow
@pytest.mark.parametrize("arch,shape", CASES)
def test_dryrun_reduced_subprocess(arch, shape):
    script = textwrap.dedent(f"""
        import os
        os.environ["REPRO_DRYRUN_DEVICES"] = "16"
        import sys; sys.path.insert(0, "src")
        from repro.launch import dryrun
        from repro.launch import mesh as mesh_lib
        from repro.launch.roofline import Roofline, analyze_hlo_text, model_flops_for
        import jax

        mesh = mesh_lib.make_test_mesh(4, 4)
        lowered, meta = dryrun.build_lowered(
            "{arch}", "{shape}", reduced=True, mesh=mesh)
        compiled = lowered.compile()
        costs = analyze_hlo_text(compiled.as_text())
        assert costs.flops > 0, "no FLOPs found in HLO"
        assert costs.bytes_accessed > 0
        roof = Roofline.from_costs(
            costs, arch=meta["arch"], shape=meta["shape"], mesh=meta["mesh"],
            chips=16, model_flops=model_flops_for(meta["cfg"], meta["shape_obj"]))
        assert roof.bottleneck in ("compute", "memory", "collective")
        print("DRYRUN_OK", roof.bottleneck, f"{{costs.flops:.2e}}")
    """)
    r = subprocess.run([sys.executable, "-c", script], cwd=ROOT,
                       capture_output=True, text=True, timeout=900)
    assert "DRYRUN_OK" in r.stdout, (r.stdout[-2000:] + r.stderr[-3000:])


def test_skip_table():
    from repro.launch.dryrun import SKIPS
    assert ("whisper-small", "long_500k") in SKIPS


def test_variant_for_long_context():
    from repro.configs.base import get_config
    from repro.launch.dryrun import variant_for
    cfg = variant_for(get_config("qwen2-7b"), "long_500k")
    assert cfg.attn_variant == "sliding"
    cfg = variant_for(get_config("falcon-mamba-7b"), "long_500k")
    assert cfg.family == "ssm"            # untouched: natively sub-quadratic
