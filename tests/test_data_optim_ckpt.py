"""Substrate tests: data pipeline, optimizers, checkpointing."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import io as ckpt
from repro.data.pipeline import batches, cluster_batches
from repro.data.synthetic import ClassificationTask, LMStream, sample_markov
from repro.data.noniid import partition_by_classes
from repro.optim.optimizers import (adamw, apply_updates, clip_by_global_norm,
                                    global_norm, sgd)
from repro.optim.schedules import linear_decay, warmup_cosine

KEY = jax.random.PRNGKey(0)


class TestData:
    def test_lm_stream_shapes_and_learnability(self):
        s = LMStream(vocab=64, batch=4, seq=16, seed=0)
        b = next(iter(s))
        assert b["tokens"].shape == (4, 16) and b["labels"].shape == (4, 16)
        # labels are next tokens
        raw = np.asarray(b["tokens"])
        lab = np.asarray(b["labels"])
        assert (raw[:, 1:] == lab[:, :-1]).all()

    def test_classification_classes_are_distinguishable(self):
        task = ClassificationTask(n_classes=3, vocab=32, seq=64,
                                  class_strength=0.8, seed=0)
        d = task.dataset(300)
        # bigram histograms should separate classes
        def hist(toks):
            h = np.zeros((32, 32))
            for row in toks:
                np.add.at(h, (row[:-1], row[1:]), 1)
            return h / h.sum()
        h0 = hist(d["tokens"][d["label"] == 0])
        h1 = hist(d["tokens"][d["label"] == 1])
        assert np.abs(h0 - h1).sum() > 0.1

    def test_cluster_batches_stacks_leading_dim(self):
        task = ClassificationTask(3, 32, 8, seed=1)
        d = task.dataset(120)
        parts = partition_by_classes(d["label"], 4, 2)
        it = cluster_batches(d, parts, batch_size=4)
        b = next(it)
        assert b["tokens"].shape == (4, 4, 8)
        assert b["label"].shape == (4, 4)

    def test_markov_sampler_respects_transitions(self):
        rng = np.random.default_rng(0)
        trans = np.eye(8)[np.roll(np.arange(8), -1)]   # deterministic cycle
        out = sample_markov(rng, trans, 3, 10)
        for row in out:
            for t in range(9):
                assert row[t + 1] == (row[t] + 1) % 8


class TestOptim:
    def _quadratic(self, opt, steps=200):
        target = jnp.asarray([1.0, -2.0, 3.0])
        params = {"w": jnp.zeros(3)}
        state = opt.init(params)
        for _ in range(steps):
            grads = {"w": 2 * (params["w"] - target)}
            updates, state = opt.update(grads, state, params)
            params = apply_updates(params, updates)
        return float(jnp.max(jnp.abs(params["w"] - target)))

    def test_sgd_converges(self):
        assert self._quadratic(sgd(0.1)) < 1e-3

    def test_sgd_momentum_converges(self):
        assert self._quadratic(sgd(0.05, momentum=0.9)) < 1e-3

    def test_adamw_converges(self):
        assert self._quadratic(adamw(0.1), steps=400) < 1e-2

    def test_adamw_weight_decay_shrinks(self):
        opt = adamw(0.1, weight_decay=0.5)
        params = {"w": jnp.ones(4) * 5.0}
        state = opt.init(params)
        for _ in range(50):
            updates, state = opt.update({"w": jnp.zeros(4)}, state, params)
            params = apply_updates(params, updates)
        assert float(jnp.max(jnp.abs(params["w"]))) < 5.0

    def test_clip_by_global_norm(self):
        tree = {"a": jnp.ones(100) * 10}
        clipped, n = clip_by_global_norm(tree, 1.0)
        assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)

    def test_schedules(self):
        f = warmup_cosine(1.0, 10, 100)
        assert float(f(jnp.asarray(0))) == 0.0
        assert float(f(jnp.asarray(10))) == pytest.approx(1.0, rel=1e-5)
        assert float(f(jnp.asarray(100))) == pytest.approx(0.0, abs=1e-5)
        g = linear_decay(1.0, 100)
        assert float(g(jnp.asarray(50))) == pytest.approx(0.5)


class TestCheckpoint:
    def test_roundtrip_exact(self, tmp_path):
        tree = {"a": {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4)},
                "b": jnp.asarray([1, 2, 3], jnp.int32),
                "c": (jax.random.normal(KEY, (4,)).astype(jnp.bfloat16))}
        p = str(tmp_path / "ck")
        nb = ckpt.save(p, tree)
        assert nb > 0
        back = ckpt.load(p, tree)
        for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
            assert x.dtype == y.dtype
            np.testing.assert_array_equal(np.asarray(x, np.float32),
                                          np.asarray(y, np.float32))

    def test_load_missing_key_raises_keyerror(self, tmp_path):
        """A checkpoint lacking a leaf the template expects must raise a
        real KeyError (not a bare assert that vanishes under python -O)."""
        p = str(tmp_path / "ck")
        ckpt.save(p, {"a": jnp.ones((2,))})
        with pytest.raises(KeyError, match="missing"):
            ckpt.load(p, {"a": jnp.ones((2,)), "b": jnp.ones((3,))})

    def test_save_is_atomic_under_crash(self, tmp_path, monkeypatch):
        """A crash mid-save must leave the previous checkpoint intact: the
        write goes to a temp file and only os.replace publishes it."""
        p = str(tmp_path / "ck")
        tree_v1 = {"w": jnp.arange(4, dtype=jnp.float32)}
        ckpt.save(p, tree_v1)

        def boom(*a, **kw):
            raise RuntimeError("disk died mid-write")

        monkeypatch.setattr(np, "savez", boom)
        with pytest.raises(RuntimeError, match="disk died"):
            ckpt.save(p, {"w": jnp.zeros(4, jnp.float32)})
        monkeypatch.undo()
        back = ckpt.load(p, tree_v1)           # previous file still loads
        np.testing.assert_array_equal(np.asarray(back["w"]),
                                      np.asarray(tree_v1["w"]))
        # and no temp-file litter in the checkpoint dir
        assert [f for f in os.listdir(tmp_path) if f.endswith(".tmp")] == []

    def test_adapter_only_checkpoint_smaller(self, tmp_path):
        from repro.configs.base import get_config
        from repro.models import model as M
        cfg = get_config("vit-edge").reduced()
        params = M.init(cfg, KEY)
        pa = str(tmp_path / "adapters")
        pf = str(tmp_path / "full")
        na = ckpt.save_adapters(pa, params)
        nf = ckpt.save(pf, params)
        assert na < nf / 3            # parameter-efficient transport
        loaded = ckpt.load_adapters(pa, params)
        for x, y in zip(jax.tree.leaves(loaded["adapters"]),
                        jax.tree.leaves(params["adapters"])):
            np.testing.assert_array_equal(np.asarray(x, np.float32),
                                          np.asarray(y, np.float32))
