"""Mesh-sharded serving and training (ISSUE 5).

Two layers of coverage:

- in-process: rule-set contents, dim_sharding divisibility fallback, the
  ParamSpec/_mesh ValueError bugfixes, AdapterBank publish donation, and
  the engine's extra_batch validation.
- subprocess (forced 4 host devices, like test_dryrun_smoke): on a
  2x2 (`data`, `model`) mesh, a mixed-domain ragged engine drain and a
  K-step HFSL round must match the unsharded path token-for-token /
  step-for-step, with the BatchBank `cluster` dim and the AdapterBank
  slot dim placed on `data` (asserted from the live array shardings and
  via jax.debug.visualize_array_sharding).
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.adapter_bank import AdapterBank
from repro.launch.engine import DecodeEngine
from repro.models import model as M
from repro.sharding import rules as R

ROOT = os.path.join(os.path.dirname(__file__), "..")


# ---------------------------------------------------------------------------
# Rule sets + helpers (in-process, no mesh needed beyond 1 device)
# ---------------------------------------------------------------------------

def test_serving_rules_shape():
    r = R.serving_rules()
    assert r["batch"] == ("pod", "data")      # wave batch over data
    assert r["heads"] == "model"              # TP attention
    assert r["kv_seq"] is None                # per-row scatter stays local
    assert r["slots"] == ("pod", "data")      # bank slot parallelism


def test_hfsl_round_rules_disable_sequence_parallelism():
    r = R.hfsl_round_rules("dense")
    assert r["seq"] is None and r["cluster"] == ("pod", "data")
    # recurrent families keep their per-cluster batch rule
    assert R.hfsl_round_rules("ssm")["batch"] == "model"


def test_dim_sharding_divisibility_fallback():
    mesh = R.Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                  ("data", "model"))
    # size divides trivially on a 1-way axis
    sh = R.dim_sharding(mesh, 3, "slots", index=1)
    assert sh.spec == R.P(None, "data")
    # unknown logical name -> replicated
    assert R.dim_sharding(mesh, 4, "nonexistent").spec == R.P()


def test_param_spec_mismatch_raises_value_error():
    # bugfix: was a bare assert (vanishes under python -O)
    with pytest.raises(ValueError, match="logical axis per dim"):
        R.ParamSpec((4, 4), axes=("batch",))
    R.ParamSpec((4, 4), axes=("batch", None))          # valid: one per dim
    R.ParamSpec((4, 4))                                # valid: no axes


def test_mesh_too_few_devices_raises_value_error():
    # bugfix: was a bare assert (vanishes under python -O)
    from repro.launch.mesh import _mesh
    with pytest.raises(ValueError, match="devices"):
        _mesh((512, 512), ("data", "model"))


# ---------------------------------------------------------------------------
# AdapterBank publish donation (bugfix: hot-publish copied the whole bank)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_bank_setup():
    cfg = get_config("vit-edge").reduced().with_(dtype="float32",
                                                 vocab_size=64)
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    adapters = {d: M.init(cfg, ks[i])["adapters"]
                for i, d in enumerate(["a", "b", "c"])}
    backbone = M.init(cfg, ks[-1])["backbone"]
    return cfg, backbone, adapters


def test_publish_donates_the_stacked_bank(small_bank_setup):
    """The hot-swap must reuse the resident buffers (donated input), not
    allocate a second bank — and serving behavior must be unchanged."""
    cfg, backbone, adapters = small_bank_setup
    bank = AdapterBank.create(adapters)
    before = jax.tree.leaves(bank.stacked)
    new = M.init(cfg, jax.random.PRNGKey(7))["adapters"]
    bank.publish("b", new)
    # donation invalidated the old buffers: the update was in place
    assert all(x.is_deleted() for x in before)
    # publish-then-serve parity: the published slot serves exactly like a
    # bank freshly created with the published adapters, other slots are
    # untouched, and snapshot() (non-donated) leaves the bank serving
    fresh = AdapterBank.create({**adapters, "b": new})
    prompts = np.asarray(jax.random.randint(
        jax.random.PRNGKey(9), (3, 10), 0, cfg.vocab_size))
    for g, w in zip(jax.tree.leaves(bank.snapshot("b")),
                    jax.tree.leaves(new)):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
    got, _ = DecodeEngine(cfg, slots=3, bank=bank).serve(
        bank.serving_params(backbone), prompts, gen=4,
        domains=["a", "b", "c"])
    want, _ = DecodeEngine(cfg, slots=3, bank=fresh).serve(
        fresh.serving_params(backbone), prompts, gen=4,
        domains=["a", "b", "c"])
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# Engine serve() validates extra_batch (bugfix)
# ---------------------------------------------------------------------------

def test_serve_validates_extra_batch_rows():
    vcfg = get_config("llava-next-mistral-7b").reduced().with_(
        dtype="float32", vocab_size=64)
    params = M.init(vcfg, jax.random.PRNGKey(0))
    engine = DecodeEngine(vcfg, slots=2)
    prompts = np.zeros((3, 6), np.int32)
    short = np.zeros((2, vcfg.vlm.n_vis_tokens, vcfg.d_model), np.float32)
    with pytest.raises(ValueError, match="extra_batch\\['vision_embeds'\\]"):
        engine.serve(params, prompts, gen=2,
                     extra_batch={"vision_embeds": short})
    # a LONGER leading dim must also be rejected (silent truncation before)
    long = np.zeros((5, vcfg.vlm.n_vis_tokens, vcfg.d_model), np.float32)
    with pytest.raises(ValueError, match="one\\s+row per prompt"):
        engine.serve(params, prompts, gen=2,
                     extra_batch={"vision_embeds": long})
    assert engine.pending() == 0              # nothing half-submitted


# ---------------------------------------------------------------------------
# Host-device mesh parity (subprocess: needs forced host devices)
# ---------------------------------------------------------------------------

_MESH_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    os.environ["JAX_PLATFORMS"] = "cpu"
    import sys; sys.path.insert(0, "src")
    import numpy as np
    import jax, jax.numpy as jnp
    jax.config.update("jax_default_matmul_precision", "highest")

    from repro.configs.base import get_config
    from repro.core import hfsl
    from repro.core.adapter_bank import AdapterBank
    from repro.data.noniid import partition_by_classes
    from repro.data.pipeline import BatchBank
    from repro.data.synthetic import ClassificationTask
    from repro.launch.engine import DecodeEngine
    from repro.launch.mesh import make_test_mesh
    from repro.models import model as M
    from repro.optim.optimizers import adamw
    from repro.sharding import rules as R

    mesh = make_test_mesh(2, 2)
    cfg = get_config("vit-edge").reduced().with_(dtype="float32",
                                                 vocab_size=64)
    DOMS = ["d0", "d1", "d2", "d3"]
    ks = jax.random.split(jax.random.PRNGKey(0), len(DOMS) + 1)
    adapters = {d: M.init(cfg, ks[i])["adapters"]
                for i, d in enumerate(DOMS)}
    backbone = M.init(cfg, ks[-1])["backbone"]

    # --- mixed-domain ragged drain: sharded == unsharded, token for token
    key = jax.random.PRNGKey(5)
    short = np.asarray(jax.random.randint(key, (4, 8), 0, cfg.vocab_size))
    long = np.asarray(jax.random.randint(key, (4, 12), 0, cfg.vocab_size))
    reqs = [(short[0], "d0", 4), (long[0], "d1", 3), (short[1], "d2", 5),
            (long[1], "d3", 4), (short[2], "d0", 2), (long[2], "d1", 6),
            (short[3], "d2", 3), (long[3], "d3", 4)]
    bank_u = AdapterBank.create(adapters)
    eng_u = DecodeEngine(cfg, slots=4, bank=bank_u)
    uids_u = [eng_u.submit(t, g, domain=d) for t, d, g in reqs]
    comps_u, _ = eng_u.run(bank_u.serving_params(backbone))
    want = {c.uid: c.tokens for c in comps_u}

    bank_s = AdapterBank.create(adapters, mesh=mesh)
    bb_s = M.place_params({"backbone": backbone}, cfg, mesh)["backbone"]
    eng_s = DecodeEngine(cfg, slots=4, bank=bank_s, mesh=mesh)
    uids_s = [eng_s.submit(t, g, domain=d) for t, d, g in reqs]
    comps_s, stats_s = eng_s.run(bank_s.serving_params(bb_s))
    got = {c.uid: c.tokens for c in comps_s}
    for uu, us in zip(uids_u, uids_s):
        np.testing.assert_array_equal(got[us], want[uu])
    assert stats_s.requests == len(reqs)
    print("DRAIN_PARITY_OK", stats_s.tokens)

    # --- placements: slot dims on `data` (4 slots over the 2-way axis)
    stack_leaf = jax.tree.leaves(bank_s.stacked["stack"])[0]
    head_leaf = bank_s.stacked["head"]["w"]
    assert stack_leaf.sharding.spec == R.P(None, "data"), \\
        stack_leaf.sharding.spec
    assert head_leaf.sharding.spec[0] == "data", head_leaf.sharding.spec
    jax.debug.visualize_array_sharding(
        head_leaf.reshape(head_leaf.shape[0], -1))
    print("BANK_PLACEMENT_OK")

    # --- K-step HFSL round: sharded == unsharded, step for step
    C, BATCH, STEPS = 4, 4, 4
    opt = adamw(5e-3)
    task = ClassificationTask(5, 64, 24, class_strength=0.6, seed=0)
    data = task.dataset(40 * C, seed=11)
    parts = partition_by_classes(data["label"], C, cfg.peft.head_dim_out,
                                 seed=1)
    state0 = hfsl.init_hfsl_state(jax.random.PRNGKey(3), cfg, C, opt, M.init)
    bank_ut = BatchBank.pack(data, parts, BATCH, seed=2)
    round_u = hfsl.make_hfsl_round(cfg, opt, M.classify_loss, steps=STEPS,
                                   sync_every=2)
    su, mu = round_u(state0, bank_ut.arrays, 0)

    rules = R.hfsl_round_rules(cfg.family)
    spec = hfsl.hfsl_state_spec(cfg, C, opt, M.model_spec)
    sh = hfsl.hfsl_state_shardings(cfg, C, opt, M.model_spec, mesh, rules)
    state_s = jax.device_put(state0, sh)
    bank_st = BatchBank.pack(data, parts, BATCH, seed=2, mesh=mesh,
                             rules=rules)
    assert jax.tree.leaves(bank_st.arrays)[0].sharding.spec \\
        == R.P(None, "data")
    round_s = hfsl.make_hfsl_round(cfg, opt, M.classify_loss, steps=STEPS,
                                   sync_every=2, mesh=mesh, rules=rules,
                                   state_spec=spec, donate=True)
    ss, ms = round_s(state_s, bank_st.arrays, 0)
    # per-STEP losses match (the scan replays the same local steps +
    # FedAvg boundaries; only cross-device reduction order may differ)
    np.testing.assert_allclose(np.asarray(ms["loss"]),
                               np.asarray(mu["loss"]),
                               rtol=2e-5, atol=1e-6)
    for g, w in zip(jax.tree.leaves(ss["adapters_c"]),
                    jax.tree.leaves(su["adapters_c"])):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-3, atol=3e-5)
    assert int(ss["step"]) == STEPS
    # train state stays resident on its mesh slice (pinned out_shardings)
    a_leaf = jax.tree.leaves(ss["adapters_c"])[0]
    assert a_leaf.sharding.spec[0] == "data", a_leaf.sharding.spec
    jax.debug.visualize_array_sharding(
        a_leaf.reshape(a_leaf.shape[0], -1))
    print("ROUND_PARITY_OK", float(ms["loss"][-1]))

    # --- publish the sharded round's consensus; serve it sharded; tokens
    # must equal the unsharded round's consensus served unsharded
    cons_s = hfsl.consensus_params({"backbone": bb_s,
                                    "adapters_c": ss["adapters_c"]})
    cons_u = hfsl.consensus_params({"backbone": backbone,
                                    "adapters_c": su["adapters_c"]})
    bank_s.publish("d1", cons_s["adapters"])
    bank_u.publish("d1", cons_u["adapters"])
    p = np.asarray(jax.random.randint(key, (2, 9), 0, cfg.vocab_size))
    got2, _ = DecodeEngine(cfg, slots=2, bank=bank_s, mesh=mesh).serve(
        bank_s.serving_params(bb_s), p, gen=4, domains=["d1", "d1"])
    want2, _ = DecodeEngine(cfg, slots=2, bank=bank_u).serve(
        bank_u.serving_params(backbone), p, gen=4, domains=["d1", "d1"])
    np.testing.assert_array_equal(got2, want2)
    print("TRAIN_TO_SERVE_OK")

    # --- GaisNet(mesh=...) glue: the runtime wires BOTH sides itself
    # (init-time state/backbone placement, round shardings, bank, engine,
    # classify) — component parity is proven above; this guards the wiring
    import dataclasses
    from repro.core.integrated import GaisNet
    icfg = cfg.with_(peft=dataclasses.replace(cfg.peft, head_dim_out=5))
    tasks = {n: ClassificationTask(5, 64, 24, class_strength=0.6, seed=s)
             for n, s in [("nlp", 0), ("cv", 7)]}
    rt = GaisNet(icfg, tasks, mesh=mesh, n_clusters=2, steps_per_upgrade=2,
                 serve_batch=4, serve_gen=3, serve_slots=4, seed=0)
    assert jax.tree.leaves(rt.bank.stacked["stack"])[0].sharding.spec \\
        == R.P(None, "data")
    assert jax.tree.leaves(rt._banks["cv"].arrays)[0].sharding.spec \\
        == R.P(None, "data")
    assert jax.tree.leaves(
        rt.domains["nlp"].adapters_c)[0].sharding.spec[0] == "data"
    profit, cost = rt.produce(["nlp", "cv"])       # mixed sharded drain
    assert 0.0 <= profit <= rt.profit_scale and cost.tokens == 4 * 3
    v0 = rt.bank.version("nlp")
    rt.upgrade("nlp")                              # sharded donated round
    assert rt.bank.version("nlp") == v0 + 1
    assert jax.tree.leaves(                        # placement survives
        rt.domains["nlp"].adapters_c)[0].sharding.spec[0] == "data"
    profit2, _ = rt.produce("nlp")                 # serves the publish
    assert 0.0 <= profit2 <= rt.profit_scale
    print("GAISNET_MESH_OK")
""")


@pytest.fixture(scope="module")
def mesh_parity_run():
    r = subprocess.run([sys.executable, "-c", _MESH_SCRIPT], cwd=ROOT,
                       capture_output=True, text=True, timeout=900)
    return r


def test_mesh_drain_parity(mesh_parity_run):
    r = mesh_parity_run
    assert "DRAIN_PARITY_OK" in r.stdout, \
        (r.stdout[-2000:] + r.stderr[-3000:])
    assert "BANK_PLACEMENT_OK" in r.stdout, \
        (r.stdout[-2000:] + r.stderr[-3000:])


def test_mesh_round_parity(mesh_parity_run):
    r = mesh_parity_run
    assert "ROUND_PARITY_OK" in r.stdout, \
        (r.stdout[-2000:] + r.stderr[-3000:])


def test_mesh_train_to_serve_loop(mesh_parity_run):
    r = mesh_parity_run
    assert "TRAIN_TO_SERVE_OK" in r.stdout, \
        (r.stdout[-2000:] + r.stderr[-3000:])


def test_gaisnet_mesh_wiring(mesh_parity_run):
    r = mesh_parity_run
    assert "GAISNET_MESH_OK" in r.stdout, \
        (r.stdout[-2000:] + r.stderr[-3000:])


# ---------------------------------------------------------------------------
# CI budget: the default suite deselects `slow`
# ---------------------------------------------------------------------------

def test_default_suite_excludes_slow_marker():
    """Tier-1 (`pytest -x -q`) must stay inside the CI budget: the
    exhaustive sweeps are `slow`-marked and deselected by default addopts
    (run them explicitly with `pytest -m slow` / `-m ""`)."""
    with open(os.path.join(ROOT, "pyproject.toml")) as f:
        txt = f.read()
    assert "not slow" in txt and "addopts" in txt
    assert "slow:" in txt                     # marker stays registered
