"""Telemetry overhead benchmark: the disabled path must be free.

The telemetry layer (core/telemetry.py) rides inside the decode engine's
drain loop and the HFSL round path, so its cost model is the whole design:
disabled (the default) every hook must collapse to one attribute check,
and enabled it must stay cheap enough to leave on in CI smokes.

Emits ``name,us_per_call,derived`` rows:

- ``telemetry_noop_call``      — empty-function-call floor (the baseline
  every hook is compared against).
- ``telemetry_disabled_count`` / ``_observe`` / ``_span`` — per-hook cost
  with telemetry OFF; ``overhead_ns`` is the delta vs the no-op floor and
  should be within noise of zero (a handful of ns for the guard check).
- ``telemetry_enabled_count`` / ``_observe`` / ``_span`` — the real
  recording cost with telemetry ON.
- ``telemetry_drain_overhead`` — end-to-end: a small ragged engine drain
  with telemetry off vs on; derived reports both tok/s and the relative
  wall-time delta (expected ~0: a drain records a few dozen events
  against seconds of device work).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import telemetry
from repro.core.telemetry import Telemetry
from repro.configs.base import get_config
from repro.launch.engine import DecodeEngine
from repro.models import model as M


def _per_call_ns(fn, n: int, repeat: int = 5) -> float:
    """Best-of-``repeat`` mean ns/call over ``n`` calls."""
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        for _ in range(n):
            fn()
        best = min(best, time.perf_counter() - t0)
    return best / n * 1e9


def _noop():
    pass


def _drain(params, cfg, trace, slots, tel):
    engine = DecodeEngine(cfg, slots=slots, tel=tel)
    for toks, g in trace:
        engine.submit(toks, g)
    _, stats = engine.run(params)
    return stats


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--calls", type=int, default=200_000)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--repeat", type=int, default=3)
    # benchmarks/run.py imports main() with argv=None -> defaults
    args = ap.parse_args([] if argv is None else argv)
    n = args.calls

    floor = _per_call_ns(_noop, n)
    emit("telemetry_noop_call", floor * 1e-3, "baseline=1")

    off = Telemetry(enabled=False)
    on = Telemetry(enabled=True)
    results = {"floor_ns": floor}
    for mode, tel in (("disabled", off), ("enabled", on)):
        def span_hook(t=tel):
            with t.span("bench.s"):
                pass

        hooks = {
            "count": lambda t=tel: t.count("bench.c"),
            "observe": lambda t=tel: t.observe("bench.h", 0.5),
            "span": span_hook,
        }
        for hook, fn in hooks.items():
            ns = _per_call_ns(fn, n)
            results[f"{mode}_{hook}_ns"] = ns
            emit(f"telemetry_{mode}_{hook}", ns * 1e-3,
                 f"overhead_ns={ns - floor:.1f}")
        tel.reset()

    # end-to-end: the same ragged drain with telemetry off vs on
    cfg = get_config("qwen2-7b").reduced().with_(dtype="float32")
    params = M.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    trace = [(rng.integers(0, cfg.vocab_size, 6 + 3 * (i % 4))
              .astype(np.int32), [4, 8, 2, 6][i % 4])
             for i in range(args.requests)]
    ntok = sum(g for _, g in trace)

    def best_of(tel):
        _drain(params, cfg, trace, args.slots, tel)   # warmup / compile
        best = float("inf")
        for _ in range(max(args.repeat, 1)):
            if tel is not None:
                tel.reset()
            t0 = time.perf_counter()
            _drain(params, cfg, trace, args.slots, tel)
            best = min(best, time.perf_counter() - t0)
        return best

    t_off = best_of(Telemetry(enabled=False))
    t_on = best_of(Telemetry(enabled=True))
    delta = (t_on - t_off) / t_off
    results.update({"drain_off_s": t_off, "drain_on_s": t_on,
                    "drain_delta": delta})
    emit("telemetry_drain_overhead", (t_on - t_off) * 1e6,
         f"off_tok_s={ntok / t_off:.1f};on_tok_s={ntok / t_on:.1f};"
         f"delta={delta * 100:+.1f}%")
    return results


if __name__ == "__main__":
    import sys
    out = main(sys.argv[1:])
    print(f"# disabled-span overhead vs no-op call: "
          f"{out['disabled_span_ns'] - out['floor_ns']:.1f} ns; "
          f"enabled span: {out['enabled_span_ns']:.0f} ns; "
          f"drain delta {out['drain_delta'] * 100:+.1f}%")
