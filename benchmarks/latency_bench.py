"""Serving latency under offered load: Poisson arrivals, paged vs dense.

The throughput benches (ragged_bench, serve_bench) front-load the whole
queue, so they measure drain bandwidth, not latency — every request's
queue wait is an artifact of submission order. This bench drives the
engine the way traffic actually arrives: a Poisson arrival trace through
``DecodeEngine.serve_trace`` (arrival-driven admission), on a compressed
timescale so the run stays CPU-friendly. Both engines serve the SAME
trace; the paged engine additionally block-gates admission, so a burst
beyond pool capacity queues head-of-line until blocks retire.

Emits ``name,us_per_call,derived`` rows:

- ``latency_dense`` / ``latency_paged`` — wall time of the traced drain;
  derived carries p50/p99 TTFT and per-token decode latency (seconds,
  from the engine's log-bucketed histograms).
- ``latency_paged_occupancy`` — pool occupancy (useful tokens per
  allocated pool-block token) vs the dense slab's utilization
  (every row padded to the drain-wide pow2 cap). Paged must dominate:
  blocks are sized per request, the slab pads to the worst row.

Compile time is excluded (warmup drain per engine).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs.base import get_config
from repro.core.paged import PagedSpec
from repro.launch.engine import DecodeEngine
from repro.models import model as M


def _pow2ceil(n: int) -> int:
    return 1 << max(int(n) - 1, 0).bit_length()


def _poisson_trace(n, lengths, budgets, vocab, *, mean_gap_s, seed=0):
    """Timed arrivals: exponential inter-arrival gaps (Poisson process),
    round-robin mixed prompt lengths and token budgets."""
    rng = np.random.default_rng(seed)
    t, out = 0.0, []
    for i in range(n):
        t += float(rng.exponential(mean_gap_s))
        L = lengths[i % len(lengths)]
        out.append((t, rng.integers(0, vocab, L).astype(np.int32),
                    int(budgets[i % len(budgets)])))
    return out


def _drain(engine, params, trace):
    comps, stats = engine.serve_trace(params, trace)
    assert len(comps) == len(trace)
    return stats


def _pcts(hist):
    return (hist or {}).get("p50", 0.0), (hist or {}).get("p99", 0.0)


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--mean-gap-ms", type=float, default=5.0,
                    help="mean Poisson inter-arrival gap (compressed time)")
    ap.add_argument("--n-blocks", type=int, default=64)
    ap.add_argument("--block-size", type=int, default=8)
    # benchmarks/run.py imports main() with argv=None -> defaults
    args = ap.parse_args([] if argv is None else argv)

    cfg = get_config(args.arch).reduced().with_(dtype="float32")
    params = M.init(cfg, jax.random.PRNGKey(0))
    lengths = [6, 12, 9, 18, 7, 15]
    budgets = [4, 12, 8, 2, 16, 6]
    trace = _poisson_trace(args.requests, lengths, budgets, cfg.vocab_size,
                           mean_gap_s=args.mean_gap_ms / 1e3)
    ntok = sum(g for _, _, g in trace)
    paged_spec = PagedSpec(n_blocks=args.n_blocks,
                           block_size=args.block_size)

    results = {}
    for name, mk in (("dense", lambda: DecodeEngine(cfg, slots=args.slots)),
                     ("paged", lambda: DecodeEngine(cfg, slots=args.slots,
                                                    paged=paged_spec))):
        _drain(mk(), params, trace)            # warmup: compile + first drain
        t0 = time.time()
        stats = _drain(mk(), params, trace)
        dt = time.time() - t0
        t50, t99 = _pcts(stats.ttft_hist)
        d50, d99 = _pcts(stats.tok_latency_hist)
        emit(f"latency_{name}", dt * 1e6,
             f"tok_s={ntok / dt:.1f};ttft_p50_s={t50:.4f};"
             f"ttft_p99_s={t99:.4f};tok_p50_s={d50:.4f};tok_p99_s={d99:.4f}")
        results[name] = {"wall_s": dt, "ttft_p50_s": t50, "ttft_p99_s": t99,
                         "tok_p50_s": d50, "tok_p99_s": d99, "stats": stats}

    # cache-footprint comparison on the same trace: the dense slab pads
    # every row to the drain-wide pow2 cap; paged blocks are sized per
    # request, so occupancy must dominate the slab's utilization
    demand = [len(t) + g for _, t, g in trace]
    slab_util = sum(demand) / (len(demand) * _pow2ceil(max(demand)))
    occ = results["paged"]["stats"].pool_occupancy
    assert occ >= slab_util, (occ, slab_util)
    emit("latency_paged_occupancy", 0,
         f"pool_occupancy={occ:.3f};dense_slab_util={slab_util:.3f};"
         f"peak_blocks={results['paged']['stats'].pool_peak_blocks}")
    results["pool_occupancy"] = occ
    results["dense_slab_util"] = slab_util
    for r in results.values():
        if isinstance(r, dict):
            r.pop("stats", None)
    return results


if __name__ == "__main__":
    import sys
    out = main(sys.argv[1:])
    print(f"# paged occupancy {out['pool_occupancy']:.3f} vs dense slab "
          f"{out['dense_slab_util']:.3f}; paged ttft p99 "
          f"{out['paged']['ttft_p99_s'] * 1e3:.1f} ms")
