"""Shared benchmark utilities: tiny-but-real training loops on CPU."""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import PEFTConfig, get_config
from repro.core import hfsl
from repro.data.noniid import partition_by_classes
from repro.data.pipeline import batches, cluster_batches
from repro.data.synthetic import ClassificationTask
from repro.models import model as M
from repro.optim.optimizers import adamw
from repro.core.peft import peft_value_and_grad
from repro.optim.optimizers import apply_updates

N_CLASSES = 5


def edge_cfg(seed_head: bool = True):
    """The paper's case-study backbone at benchmark scale.

    vocab=64 keeps per-sample token statistics dense enough that the
    synthetic 'flower' classes are separable from mean-pooled features
    (vocab 512 + seq 64 is hopelessly sparse — measured)."""
    cfg = get_config("vit-edge").reduced().with_(dtype="float32",
                                                 vocab_size=64)
    return cfg.with_(peft=dataclasses.replace(cfg.peft,
                                              head_dim_out=N_CLASSES))


def make_task(cfg, seq: int = 64, seed: int = 0) -> ClassificationTask:
    return ClassificationTask(N_CLASSES, cfg.vocab_size, seq,
                              class_strength=0.6, seed=seed)


def pretrain(cfg, task, steps: int = 300, lr: float = 3e-3, seed: int = 0):
    """LM pretraining on the class mixture (the 'cloud corpus')."""
    params = M.init(cfg, jax.random.PRNGKey(seed))
    opt = adamw(lr)
    vg = peft_value_and_grad(M.lm_loss, trainable="all")
    state = opt.init(params)

    @jax.jit
    def step(params, state, batch):
        (loss, _), grads = vg(params, batch, cfg)
        updates, state = opt.update(grads, state, params)
        return apply_updates(params, updates), state, loss

    it = task.pretrain_stream(16)
    loss = None
    for i in range(steps):
        params, state, loss = step(params, state, next(it))
    return params, float(loss)


def eval_accuracy(params, cfg, data, batch_size: int = 32) -> float:
    n, correct = 0, 0
    logits_fn = jax.jit(lambda p, b: M.classify(p, b, cfg))
    for lo in range(0, len(data["label"]), batch_size):
        b = {k: jnp.asarray(v[lo:lo + batch_size]) for k, v in data.items()}
        pred = np.argmax(np.asarray(logits_fn(params, b)), -1)
        correct += int((pred == np.asarray(b["label"])).sum())
        n += len(pred)
    return correct / max(n, 1)


def hfsl_finetune(params, cfg, task, *, n_clusters: int = 4,
                  classes_per_client: int = N_CLASSES, epochs: int = 4,
                  steps_per_epoch: int = 25, lr: float = 5e-3,
                  sync_every: int = 5, n_train: int = 600,
                  n_eval: int = 200, seed: int = 0,
                  trainable: str = "adapters"):
    """HFSL fine-tuning; returns (per-epoch accuracy, s/epoch, consensus)."""
    train = task.dataset(n_train, seed=seed + 1)
    evald = task.dataset(n_eval, seed=seed + 2)
    parts = partition_by_classes(train["label"], n_clusters,
                                 classes_per_client, seed=seed)
    it = cluster_batches(train, parts, batch_size=16, seed=seed)
    opt = adamw(lr)

    if trainable == "all":
        # full fine-tuning baseline (paper Fig 7): backbone unfrozen
        def loss_fn(p, b, c):
            return M.classify_loss(p, b, c)
        state = {
            "backbone": params["backbone"],
            "adapters_c": jax.tree.map(
                lambda x: jnp.broadcast_to(x[None], (n_clusters, *x.shape)),
                params["adapters"]),
            "step": jnp.zeros((), jnp.int32),
        }
        # full-FT optimizes backbone too -> emulate by single-cluster SGD
        # over merged params (simplest faithful baseline)
        opt_state = opt.init(params)
        vg = peft_value_and_grad(M.classify_loss, trainable="all")

        @jax.jit
        def fstep(p, s, b):
            (loss, aux), grads = vg(p, b, cfg)
            updates, s = opt.update(grads, s, p)
            return apply_updates(p, updates), s, loss

        accs, times = [], []
        flat_it = batches(train, 16, seed=seed)
        p = params
        for e in range(epochs):
            t0 = time.time()
            for _ in range(steps_per_epoch * n_clusters):
                p, opt_state, loss = fstep(p, opt_state, next(flat_it))
            times.append(time.time() - t0)
            accs.append(eval_accuracy(p, cfg, evald))
        return accs, times, p

    state = hfsl.init_hfsl_state(jax.random.PRNGKey(seed), cfg, n_clusters,
                                 opt, lambda c, k: params)
    step = jax.jit(hfsl.make_hfsl_step(cfg, opt, M.classify_loss,
                                       sync_every=sync_every))
    accs, times = [], []
    for e in range(epochs):
        t0 = time.time()
        for _ in range(steps_per_epoch):
            state, metrics = step(state, next(it))
        times.append(time.time() - t0)
        accs.append(eval_accuracy(hfsl.consensus_params(state), cfg, evald))
    return accs, times, hfsl.consensus_params(state)


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")
