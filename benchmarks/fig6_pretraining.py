"""Fig 6 reproduction: fine-tuning with pre-training vs without.

Paper: pretrained FM reaches 96.8% at epoch 1 vs 57.0% converged from
scratch. Here: LM-pretraining on the class-mixture corpus vs random init,
both PEFT-fine-tuned identically.
"""
from __future__ import annotations

import time

import jax

from benchmarks.common import (edge_cfg, emit, eval_accuracy, hfsl_finetune,
                               make_task, pretrain)
from repro.models import model as M


def main() -> dict:
    cfg = edge_cfg()
    task = make_task(cfg)
    t0 = time.time()

    pre_params, pre_loss = pretrain(cfg, task)
    accs_pre, _, _ = hfsl_finetune(pre_params, cfg, task)

    scratch = M.init(cfg, jax.random.PRNGKey(123))
    accs_scratch, _, _ = hfsl_finetune(scratch, cfg, task)

    dt = (time.time() - t0) * 1e6
    emit("fig6_first_epoch_acc_pretrained", dt,
         f"acc={accs_pre[0]:.3f}")
    emit("fig6_final_acc_pretrained", dt, f"acc={accs_pre[-1]:.3f}")
    emit("fig6_final_acc_scratch", dt, f"acc={accs_scratch[-1]:.3f}")
    ok = accs_pre[0] > accs_scratch[-1] - 0.05 and accs_pre[-1] > accs_scratch[-1]
    emit("fig6_pretraining_helps", dt, f"claim_holds={ok}")
    return {"pre": accs_pre, "scratch": accs_scratch, "claim": ok}


if __name__ == "__main__":
    main()
