"""Chaos benchmark: graceful degradation of the virtuous cycle vs fault rate.

Sweeps a seeded FaultPlan (core/faults.py) across the integrated runtime
and the knowledge relay and reports HOW the system degrades — the claim
under test is *graceful*: every round completes at every fault rate, the
bank never serves a non-finite adapter, and the only casualties are
accuracy (fewer effective cluster-updates per round) and wire bytes
(retransmissions):

- ``chaos_round@<rate>`` — one mixed produce/upgrade demand under
  ``dropout=rate, grad_nan=rate/2``: derived reports the end accuracy,
  serving tok/s, and the dropped/skipped cluster-update counts.
- ``chaos_relay@<rate>`` — relay round-trips over a ``link_loss=rate``
  backhaul: derived reports the retransmit overhead (wire bytes / logical
  bytes) and retries per transfer.

Emits ``name,us_per_call,derived`` rows (benchmarks/common.emit).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks.common import edge_cfg, emit
from repro.core.faults import FaultPlan
from repro.core.integrated import IntegratedRuntime
from repro.core.relay import KnowledgeRelay
from repro.data.synthetic import ClassificationTask
from repro.models import model as M

DROPOUT_SWEEP = (0.0, 0.25, 0.5)
LINK_SWEEP = (0.0, 0.2, 0.4)


def _runtime(cfg, faults):
    tasks = {n: ClassificationTask(cfg.peft.head_dim_out, cfg.vocab_size,
                                   16, class_strength=0.6, seed=i)
             for i, n in enumerate(["nlp", "cv"])}
    return IntegratedRuntime(cfg, tasks, n_clusters=4, steps_per_upgrade=4,
                             batch=4, sync_every=2, serve_batch=8,
                             serve_gen=2, serve_slots=4, seed=0,
                             faults=faults)


def bench_rounds(cfg, rounds: int) -> None:
    # alternate upgrades across both domains, then produce (forces the
    # masked-round path every sweep — the default policy would only serve)
    demand = ["nlp", "cv"] * (rounds // 2)
    policy = lambda r, levels: r % 2 if r < rounds - 2 else 2
    for rate in DROPOUT_SWEEP:
        plan = FaultPlan(seed=7, dropout=rate, grad_nan=rate / 2) \
            if rate else None
        rt = _runtime(cfg, plan)
        t0 = time.time()
        recs = rt.run(demand, policy=policy)
        dt = time.time() - t0
        assert len(recs) == len(demand)              # every round completed
        for x in jax.tree.leaves(rt.bank.stacked):   # never serves non-finite
            assert np.isfinite(np.asarray(x, np.float32)).all()
        acc = float(np.mean([rt.domains[n].accuracy for n in rt.domains]))
        serve = [r.cost for r in recs if r.action == "produce"]
        tok_s = sum(c.tokens for c in serve) / max(
            sum(c.latency_s for c in serve), 1e-9)
        dropped = sum(r.cost.dropped_clusters for r in recs)
        skipped = sum(r.cost.skipped_updates for r in recs)
        emit(f"chaos_round@{rate:g}", dt / len(demand) * 1e6,
             f"acc={acc:.3f};tok_per_s={tok_s:.1f};"
             f"dropped={dropped};skipped={skipped}")


def bench_relay(cfg, trips: int) -> None:
    adapters = M.init(cfg, jax.random.PRNGKey(0))["adapters"]
    for rate in LINK_SWEEP:
        plan = FaultPlan(seed=11, link_loss=rate) if rate else None
        r = KnowledgeRelay(adapters, ["nlp", "cv"], faults=plan,
                           max_retries=50, backoff_s=0.0)
        ups = [jax.tree.map(lambda x: x + i, adapters) for i in range(2)]
        t0 = time.time()
        for _ in range(trips):
            r.cloud_deliver("nlp")
            r.edge_absorb("nlp", ups)
            r.cloud_aggregate()
        dt = time.time() - t0
        logical = r.ledger.total() - r.ledger.retransmit_bytes
        emit(f"chaos_relay@{rate:g}", dt / trips * 1e6,
             f"overhead={r.ledger.total() / max(logical, 1):.3f};"
             f"retries_per_transfer="
             f"{r.ledger.retries / max(r.ledger.transfers, 1):.3f}")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--trips", type=int, default=5)
    # benchmarks/run.py imports main() with argv=None -> defaults (it must
    # not see run.py's own CLI args); direct runs pass sys.argv[1:] below.
    args = ap.parse_args([] if argv is None else argv)
    cfg = edge_cfg()
    bench_rounds(cfg, args.rounds)
    bench_relay(cfg, args.trips)


if __name__ == "__main__":
    import sys
    main(sys.argv[1:])
