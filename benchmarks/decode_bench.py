"""Decode-path benchmark: per-token loop vs scan generation vs engine.

Tracks the decode-throughput trajectory (BENCH json via benchmarks/run.py):

- ``decode_loop``   — legacy per-token Python loop (one jitted dispatch +
                      host round-trip per token).
- ``decode_scan``   — single-dispatch ``generate_scan`` (prefill + lax.scan).
- ``decode_engine`` — batched serving: a queue of ``--requests`` requests
                      drained through fixed slots in scan-generation waves.

Emits ``name,us_per_call,derived`` rows with tok/s, per-token latency, and
the scan/loop speedup. Compile time is excluded (one warmup call per impl).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs.base import get_config
from repro.launch.engine import DecodeEngine
from repro.launch.serve import generate_loop
from repro.models import model as M


def _time(fn, iters: int = 3) -> float:
    """Median-free mean wall time (s) after one warmup call."""
    np.asarray(fn())                       # warmup: compile + first run
    t0 = time.time()
    for _ in range(iters):
        np.asarray(fn())                   # host sync each call
    return (time.time() - t0) / iters


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--no-reduced", dest="reduced", action="store_false",
                    help="benchmark the full-size config (default: reduced)")
    ap.set_defaults(reduced=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--iters", type=int, default=3)
    # benchmarks/run.py imports main() with argv=None -> defaults (it must
    # not see run.py's own CLI args); direct runs pass sys.argv[1:] below.
    args = ap.parse_args([] if argv is None else argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced().with_(dtype="float32")
    key = jax.random.PRNGKey(0)
    params = M.init(cfg, key)
    B, S, gen = args.batch, args.prompt_len, args.gen
    prompts = jax.random.randint(key, (B, S), 0, cfg.vocab_size,
                                 dtype=jnp.int32)
    ntok = B * gen

    dt_loop = _time(lambda: generate_loop(params, cfg, prompts, gen=gen),
                    args.iters)
    emit("decode_loop", dt_loop * 1e6,
         f"tok_s={ntok / dt_loop:.1f};ms_per_tok={dt_loop / gen * 1e3:.2f}")

    dt_scan = _time(lambda: M.generate_scan(params, cfg, prompts, gen=gen),
                    args.iters)
    emit("decode_scan", dt_scan * 1e6,
         f"tok_s={ntok / dt_scan:.1f};ms_per_tok={dt_scan / gen * 1e3:.2f};"
         f"speedup_vs_loop={dt_loop / dt_scan:.2f}x")

    engine = DecodeEngine(cfg, slots=B)
    reqs = np.asarray(jax.random.randint(key, (args.requests, S), 0,
                                         cfg.vocab_size, dtype=jnp.int32))
    engine.serve(params, reqs, gen=gen)          # warmup waves
    t0 = time.time()
    _, stats = engine.serve(params, reqs, gen=gen)
    dt_eng = time.time() - t0
    emit("decode_engine", dt_eng * 1e6,
         f"tok_s={stats.tok_per_s:.1f};requests={stats.requests};"
         f"waves={stats.waves}")
    return {"loop_s": dt_loop, "scan_s": dt_scan, "engine_s": dt_eng,
            "speedup": dt_loop / dt_scan}


if __name__ == "__main__":
    import sys
    out = main(sys.argv[1:])
    print(f"# scan speedup vs loop: {out['speedup']:.2f}x")
