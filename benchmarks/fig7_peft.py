"""Fig 7 reproduction: parameter-efficient (frozen backbone) vs full FT.

Paper: PEFT converges to higher accuracy in few-shot AND runs ~6x faster
per epoch (35s vs 3m30s on their GPU). We measure both on the same data.
"""
from __future__ import annotations

import time

from benchmarks.common import edge_cfg, emit, hfsl_finetune, make_task, pretrain
from repro.core.peft import trainable_fraction


def main() -> dict:
    cfg = edge_cfg()
    task = make_task(cfg)
    params, _ = pretrain(cfg, task)
    frac = trainable_fraction(params)

    t0 = time.time()
    accs_peft, times_peft, _ = hfsl_finetune(params, cfg, task,
                                             trainable="adapters")
    accs_full, times_full, _ = hfsl_finetune(params, cfg, task,
                                             trainable="all")
    dt = (time.time() - t0) * 1e6
    emit("fig7_acc_peft", dt, f"acc={accs_peft[-1]:.3f}")
    emit("fig7_acc_full_ft", dt, f"acc={accs_full[-1]:.3f}")
    emit("fig7_epoch_s_peft", sum(times_peft) / len(times_peft) * 1e6,
         f"trainable_frac={frac:.4f}")
    emit("fig7_epoch_s_full", sum(times_full) / len(times_full) * 1e6,
         f"speedup={sum(times_full)/max(sum(times_peft),1e-9):.2f}x")
    return {"peft": accs_peft, "full": accs_full,
            "speedup": sum(times_full) / max(sum(times_peft), 1e-9)}


if __name__ == "__main__":
    main()
