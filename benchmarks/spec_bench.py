"""Speculative serving benchmark: edge drafter vs plain decode through
the ragged engine (core/spec_decode.py).

The decode-bound profile makes the speedup mechanism visible on any
backend: plain greedy decoding reads EVERY target weight once per token
(GEMV-bound), while a speculative chunk reads them once per k+1 tokens in
ONE batched verify pass, plus a drafter that is orders of magnitude
smaller. To isolate the serving mechanics from draft quality, both target
and drafter run ZEROED weights — every logit is 0, argmax is 0, so the
drafter agrees with the target everywhere and acceptance is exactly 1.0
with fully realistic FLOPs and weight traffic. Real drafters land between
this upper bound and the plain baseline in proportion to their measured
``acceptance_rate`` (booked in EngineStats / RoundCost).

Emits ``name,us_per_call,derived`` rows:

- ``spec_plain_decode``  — plain engine drain (tok/s in derived).
- ``spec_drafted``       — speculative drain (tok/s, acceptance).
- ``spec_speedup``       — drafted tok/s over plain tok/s.

Compile time is excluded (warmup drain per impl).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs.base import get_config
from repro.core.spec_decode import SpecDecoder
from repro.launch.engine import DecodeEngine
from repro.models import model as M


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    # decode-bound profile: few rows, wide-enough model that per-token
    # weight reads dominate, long generation to amortize prefill
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--requests", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=96)
    ap.add_argument("--draft-k", type=int, default=7)
    ap.add_argument("--repeat", type=int, default=3)
    args = ap.parse_args([] if argv is None else argv)

    cfg = get_config(args.arch).reduced().with_(
        dtype="float32", vocab_size=64, d_model=args.d_model,
        n_layers=args.layers, n_heads=8, n_kv_heads=8, head_dim=0,
        d_ff=2 * args.d_model)
    # zeroed weights: target argmax == drafter argmax == 0 everywhere ->
    # acceptance 1.0 at full real compute (see module docstring)
    params = jax.tree.map(jnp.zeros_like, M.init(cfg, jax.random.PRNGKey(0)))
    spec = SpecDecoder.init(cfg, jax.random.PRNGKey(1), k=args.draft_k)
    spec = SpecDecoder(spec.cfg, jax.tree.map(jnp.zeros_like, spec.params),
                       k=args.draft_k)
    prompts = np.asarray(jax.random.randint(
        jax.random.PRNGKey(2), (args.requests, args.prompt_len), 1,
        cfg.vocab_size, dtype=jnp.int32))
    ntok = args.requests * args.gen

    def drain(engine) -> tuple[float, object]:
        engine.serve(params, prompts, gen=args.gen)        # warmup/compile
        best, stats = float("inf"), None
        for _ in range(args.repeat):
            t0 = time.time()
            _, st = engine.serve(params, prompts, gen=args.gen)
            dt = time.time() - t0
            if dt < best:
                best, stats = dt, st
        return best, stats

    t_plain, _ = drain(DecodeEngine(cfg, slots=args.requests))
    t_spec, st = drain(DecodeEngine(cfg, slots=args.requests, spec=spec))

    plain_tps = ntok / t_plain
    spec_tps = ntok / t_spec
    emit("spec_plain_decode", t_plain * 1e6 / ntok,
         f"tok_per_s={plain_tps:.0f}")
    emit("spec_drafted", t_spec * 1e6 / ntok,
         f"tok_per_s={spec_tps:.0f};acceptance={st.acceptance_rate:.2f}")
    emit("spec_speedup", 0.0, f"x{spec_tps / plain_tps:.2f}")
    return {"speedup": spec_tps / plain_tps,
            "acceptance": st.acceptance_rate}


if __name__ == "__main__":
    main()
