"""Ragged continuous batching benchmark: mixed-length trace, ragged vs
length-bucketed waves.

Realistic edge traffic (paper §IV: many tenants, heterogeneous requests)
never arrives length-aligned. The PR-1..3 engine bucketed waves by exact
prompt length, so a trace with ``--n-lengths`` distinct lengths fragments
into mostly-underfull waves, and every row in a wave decodes the wave's
MAX budget (smaller budgets ride as padding). The ragged engine packs any
mix of lengths/budgets into one wave (per-row cache positions), retires
rows at their own budget, and re-prefills freed slots mid-wave.

Emits ``name,us_per_call,derived`` rows:

- ``ragged_bucketed_baseline`` — host re-implementation of the PR-3
  length-bucketed wave packer driving ``generate_scan`` directly (equal
  length per wave, wave gen = max budget in the wave).
- ``ragged_engine``            — the ragged continuous-batching drain.

Compile time is excluded (warmup drain per impl).
"""
from __future__ import annotations

import argparse
import time
from collections import defaultdict

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs.base import get_config
from repro.launch.engine import DecodeEngine
from repro.models import model as M


def _make_trace(n_requests, lengths, budgets, vocab, seed=0):
    """Round-robin mixed-length/mixed-budget request trace."""
    rng = np.random.default_rng(seed)
    trace = []
    for i in range(n_requests):
        L = lengths[i % len(lengths)]
        g = budgets[i % len(budgets)]
        trace.append((rng.integers(0, vocab, L).astype(np.int32), int(g)))
    return trace


def _drain_bucketed(params, cfg, trace, slots):
    """PR-3 engine behavior: equal-length waves, wave gen = max budget."""
    buckets = defaultdict(list)
    for toks, g in trace:
        buckets[len(toks)].append((toks, g))
    served = 0
    for reqs in buckets.values():
        for w0 in range(0, len(reqs), slots):
            wave = reqs[w0:w0 + slots]
            gen = max(g for _, g in wave)
            prompts = np.stack([t for t, _ in wave])
            if len(wave) < slots:              # pad: replicate a live row
                prompts = np.concatenate(
                    [prompts, np.repeat(prompts[-1:], slots - len(wave), 0)])
            toks = M.generate_scan(params, cfg, jnp.asarray(prompts), gen=gen)
            np.asarray(toks)                   # sync
            served += sum(g for _, g in wave)
    return served


def _drain_ragged(params, cfg, trace, slots):
    engine = DecodeEngine(cfg, slots=slots)
    for toks, g in trace:
        engine.submit(toks, g)
    _, stats = engine.run(params)
    return stats


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--no-reduced", dest="reduced", action="store_false",
                    help="benchmark the full-size config (default: reduced)")
    ap.set_defaults(reduced=True)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--n-lengths", type=int, default=6,
                    help="distinct prompt lengths in the trace")
    ap.add_argument("--repeat", type=int, default=3,
                    help="timed drains per impl (best-of, noise control)")
    # benchmarks/run.py imports main() with argv=None -> defaults
    args = ap.parse_args([] if argv is None else argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced().with_(dtype="float32")
    params = M.init(cfg, jax.random.PRNGKey(0))
    lengths = [6 + 3 * i for i in range(args.n_lengths)]
    budgets = [4, 16, 8, 2, 12, 6]
    trace = _make_trace(args.requests, lengths, budgets, cfg.vocab_size)
    ntok = sum(g for _, g in trace)

    def best_of(fn):
        fn()                                   # warmup: compile + first drain
        times, res = [], None
        for _ in range(max(args.repeat, 1)):
            t0 = time.time()
            res = fn()
            times.append(time.time() - t0)
        return min(times), res

    dt_bucketed, _ = best_of(
        lambda: _drain_bucketed(params, cfg, trace, args.slots))
    dt_ragged, stats = best_of(
        lambda: _drain_ragged(params, cfg, trace, args.slots))

    emit("ragged_bucketed_baseline", dt_bucketed * 1e6,
         f"tok_s={ntok / dt_bucketed:.1f};requests={args.requests};"
         f"n_lengths={args.n_lengths}")
    emit("ragged_engine", dt_ragged * 1e6,
         f"tok_s={ntok / dt_ragged:.1f};util={stats.utilization:.2f};"
         f"waves={stats.waves};segments={stats.segments}")
    emit("ragged_vs_bucketed", 0,
         f"speedup={dt_bucketed / dt_ragged:.2f}x")
    return {"bucketed_s": dt_bucketed, "ragged_s": dt_ragged,
            "speedup": dt_bucketed / dt_ragged,
            "utilization": stats.utilization}


if __name__ == "__main__":
    import sys
    out = main(sys.argv[1:])
    print(f"# ragged vs length-bucketed: {out['speedup']:.2f}x "
          f"(engine utilization {out['utilization']:.2f})")
