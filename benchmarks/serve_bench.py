"""Multi-tenant serving benchmark: mixed-domain demand, bank vs per-domain.

When demand interleaves ``--domains`` domains, single-tenant serving must
drain the engine once per domain with that domain's merged params — each
drain gets only ``1/n_domains`` of the requests, so waves run near-empty
(or serially per domain). The AdapterBank path packs ALL domains into
shared waves: per-row ``adapter_ids`` select each request's (A, B) pair
inside the batched multi-LoRA kernel (kernels/lora_bgmv.py), so one drain
serves the full mixed demand at (ideally) single-domain throughput.

Emits ``name,us_per_call,derived`` rows:

- ``serve_single_domain`` — all requests one domain (the upper bound).
- ``serve_per_domain``    — mixed demand, one engine drain per domain
                            (the pre-bank baseline).
- ``serve_mixed_bank``    — mixed demand, ONE drain against the bank.

Compile time is excluded (warmup drain per impl).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs.base import get_config
from repro.core.adapter_bank import AdapterBank
from repro.launch.engine import DecodeEngine
from repro.models import model as M


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--no-reduced", dest="reduced", action="store_false",
                    help="benchmark the full-size config (default: reduced)")
    ap.set_defaults(reduced=True)
    # defaults model interleaved demand: per-domain share (requests /
    # domains) UNDER-fills a wave, so the per-domain baseline pays a
    # mostly-padded drain per domain while the bank packs one full wave
    ap.add_argument("--domains", type=int, default=3)
    ap.add_argument("--requests", type=int, default=8,
                    help="total mixed-demand requests per drain")
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    # benchmarks/run.py imports main() with argv=None -> defaults
    args = ap.parse_args([] if argv is None else argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced().with_(dtype="float32")
    names = [f"dom{i}" for i in range(args.domains)]
    ks = jax.random.split(jax.random.PRNGKey(0), args.domains + 2)
    adapters = {d: M.init(cfg, ks[i])["adapters"]
                for i, d in enumerate(names)}
    backbone = M.init(cfg, ks[-2])["backbone"]
    bank = AdapterBank.create(adapters)
    prompts = np.asarray(jax.random.randint(
        ks[-1], (args.requests, args.prompt_len), 0, cfg.vocab_size,
        dtype=jnp.int32))
    # round-robin mixed demand: consecutive requests hit different domains
    demand = [names[i % args.domains] for i in range(args.requests)]
    ntok = args.requests * args.gen

    def drain_single() -> float:
        """Upper bound: the whole demand is one domain (full waves)."""
        engine = DecodeEngine(cfg, slots=args.slots)
        params = {"backbone": backbone, "adapters": adapters[names[0]]}
        t0 = time.time()
        engine.serve(params, prompts, gen=args.gen)
        return time.time() - t0

    def drain_per_domain() -> float:
        """Pre-bank baseline: one engine drain (and one host-side param
        tree) per domain in the mixed demand."""
        engine = DecodeEngine(cfg, slots=args.slots)
        t0 = time.time()
        for d in names:
            rows = [i for i, dd in enumerate(demand) if dd == d]
            params = {"backbone": backbone, "adapters": adapters[d]}
            engine.serve(params, prompts[rows], gen=args.gen)
        return time.time() - t0

    util = {}

    def drain_mixed_bank() -> float:
        """ONE drain: mixed-domain waves against the device-resident bank."""
        engine = DecodeEngine(cfg, slots=args.slots, bank=bank)
        t0 = time.time()
        _, stats = engine.serve(bank.serving_params(backbone), prompts,
                                gen=args.gen, domains=demand)
        util["serve_mixed_bank"] = stats.utilization
        return time.time() - t0

    results = {}
    for name, fn in [("serve_single_domain", drain_single),
                     ("serve_per_domain", drain_per_domain),
                     ("serve_mixed_bank", drain_mixed_bank)]:
        fn()                                   # warmup: compile + first drain
        dt = fn()
        results[name] = dt
        u = f";util={util[name]:.2f}" if name in util else ""
        emit(name, dt * 1e6, f"tok_s={ntok / dt:.1f};domains={args.domains};"
             f"requests={args.requests}" + u)
    emit("serve_mixed_vs_per_domain", 0,
         f"speedup={results['serve_per_domain'] / results['serve_mixed_bank']:.2f}x;"
         f"frac_of_single="
         f"{results['serve_single_domain'] / results['serve_mixed_bank']:.2f}")
    return results


if __name__ == "__main__":
    import sys
    out = main(sys.argv[1:])
    print(f"# mixed-bank vs per-domain: "
          f"{out['serve_per_domain'] / out['serve_mixed_bank']:.2f}x; "
          f"fraction of single-domain throughput: "
          f"{out['serve_single_domain'] / out['serve_mixed_bank']:.2f}")
