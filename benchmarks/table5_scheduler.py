"""Table V / Fig 8 reproduction: integrated fine-tuning-and-inference
scheduling. Exact: MLCP=650, MSIP=500 on the paper's demand sequence."""
from __future__ import annotations

import time

from benchmarks.common import emit
from repro.core.scheduler import (mlcp_policy, mlcp_value_iteration,
                                  msip_policy, paper_env, rs_policy,
                                  run_policy, total_profit)


def main() -> dict:
    env = paper_env()
    t0 = time.time()
    res = {}
    for name, pol in [("MLCP", mlcp_policy(env)), ("MSIP", msip_policy(env)),
                      ("RS", rs_policy(env, seed=3))]:
        rec = run_policy(env, pol)
        res[name] = total_profit(rec)
        trace = " ".join(f"{r.action[:4]}{r.device}/{r.profit:+d}" for r in rec)
        emit(f"table5_{name}", (time.time() - t0) * 1e6,
             f"total={res[name]};trace={trace}")
    # beyond-paper: stochastic demand via value iteration
    vi = mlcp_value_iteration(env, [0.2, 0.1, 0.7])
    res["VI"] = total_profit(run_policy(env, vi))
    emit("table5_value_iteration_stochastic", (time.time() - t0) * 1e6,
         f"total={res['VI']}")
    emit("table5_matches_paper", 0.0,
         f"claim_holds={res['MLCP'] == 650 and res['MSIP'] == 500}")
    return res


if __name__ == "__main__":
    main()
