"""Benchmark driver — one entry per paper table/figure (+ kernels, roofline).

Prints ``name,us_per_call,derived`` CSV lines (benchmarks/common.emit).
With ``--json PATH`` the same results are also written machine-readable:
one record per bench with name/status/wall seconds plus every CSV metric
line the bench emitted (for dashboards and regression diffing — the CSV
stream on stdout is unchanged).

Usage: PYTHONPATH=src python -m benchmarks.run [--only fig6,table5]
       [--json results.json]
"""
from __future__ import annotations

import argparse
import contextlib
import io
import json
import sys
import time
import traceback

ALL = ["table5_scheduler", "fig2_comm", "kernels_bench", "decode_bench",
       "serve_bench", "ragged_bench", "latency_bench", "spec_bench",
       "finetune_bench", "shard_bench", "chaos_bench", "telemetry_bench",
       "fig6_pretraining", "fig7_peft", "table3_noniid", "table4_clusters",
       "roofline_report"]


def _parse_metrics(text: str) -> list[dict]:
    """Pick the ``name,us_per_call,derived`` lines out of a bench's stdout."""
    out = []
    for line in text.splitlines():
        parts = line.split(",", 2)
        if len(parts) != 3:
            continue
        try:
            us = float(parts[1])
        except ValueError:
            continue
        out.append({"name": parts[0], "us_per_call": us,
                    "derived": parts[2]})
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated module prefixes")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write per-bench results as JSON")
    args = ap.parse_args()
    mods = ALL if not args.only else [
        m for m in ALL if any(m.startswith(p) for p in args.only.split(","))]
    print("name,us_per_call,derived")
    records = []
    failures = 0
    for name in mods:
        t0 = time.time()
        status, error = "ok", None
        # tee: the bench's stdout still streams to the console CSV, and the
        # captured copy is parsed into the JSON record's metric list
        buf = io.StringIO()

        class _Tee:
            def write(self, s):
                buf.write(s)
                return sys.__stdout__.write(s)

            def flush(self):
                sys.__stdout__.flush()

        try:
            with contextlib.redirect_stdout(_Tee()):
                mod = __import__(f"benchmarks.{name}", fromlist=["main"])
                mod.main()
            wall = time.time() - t0
            print(f"bench_{name}_total,{wall * 1e6:.0f},ok")
        except Exception as e:
            failures += 1
            wall = time.time() - t0
            status, error = "failed", f"{type(e).__name__}: {e}"
            traceback.print_exc()
            print(f"bench_{name}_total,{wall * 1e6:.0f},"
                  f"FAILED:{type(e).__name__}")
        records.append({"name": name, "status": status, "wall_s": wall,
                        "error": error,
                        "metrics": _parse_metrics(buf.getvalue())})
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"benches": records,
                       "failures": failures,
                       "wall_s": sum(r["wall_s"] for r in records)},
                      f, indent=1)
        print(f"# wrote {len(records)} bench records to {args.json}",
              file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
