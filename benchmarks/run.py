"""Benchmark driver — one entry per paper table/figure (+ kernels, roofline).

Prints ``name,us_per_call,derived`` CSV lines (benchmarks/common.emit).
Usage: PYTHONPATH=src python -m benchmarks.run [--only fig6,table5]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

ALL = ["table5_scheduler", "fig2_comm", "kernels_bench", "decode_bench",
       "serve_bench", "ragged_bench", "spec_bench", "finetune_bench",
       "shard_bench", "chaos_bench",
       "fig6_pretraining", "fig7_peft", "table3_noniid", "table4_clusters",
       "roofline_report"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated module prefixes")
    args = ap.parse_args()
    mods = ALL if not args.only else [
        m for m in ALL if any(m.startswith(p) for p in args.only.split(","))]
    print("name,us_per_call,derived")
    failures = 0
    for name in mods:
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["main"])
            mod.main()
            print(f"bench_{name}_total,{(time.time()-t0)*1e6:.0f},ok")
        except Exception as e:
            failures += 1
            traceback.print_exc()
            print(f"bench_{name}_total,{(time.time()-t0)*1e6:.0f},"
                  f"FAILED:{type(e).__name__}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
