"""Fine-tuning-path benchmark: per-step loop vs fused scanned round.

The fine-tuning twin of decode_bench.py — tracks the second hot path's
throughput trajectory (BENCH json via benchmarks/run.py):

- ``finetune_loop`` — legacy per-step engine (one jitted dispatch + host
                      batch assembly (data/pipeline.cluster_batches) +
                      host->device copy per HFSL step).
- ``finetune_scan`` — fused round engine: K steps in ONE ``lax.scan``
                      dispatch over a device-resident BatchBank
                      (hfsl.make_hfsl_round), in-scan FedAvg.

The default ``engine`` profile shrinks the reduced config further (d=32) so
per-step XLA execution is small and the measured gap is the *engine*
overhead the refactor removes — on CPU a full reduced-config step costs
10-20ms of kernel execution either way, which floors the ratio near 1; the
``reduced`` profile reports that compute-bound regime honestly. Emits
``name,us_per_call,derived`` rows with steps/s, examples/s, and the
scan/loop speedup. Compile time is excluded (one warmup round per impl).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax

from benchmarks.common import emit
from repro.configs.base import get_config
from repro.core import hfsl
from repro.data.noniid import partition_by_classes
from repro.data.pipeline import BatchBank, cluster_batches
from repro.data.synthetic import ClassificationTask
from repro.models import model as M
from repro.optim.optimizers import adamw

# per-profile (extra cfg shrink, clusters, batch, seq, steps)
PROFILES = {
    "engine": (dict(d_model=32, n_heads=2, n_kv_heads=2, head_dim=16,
                    d_ff=64, vocab_size=32), 4, 1, 4, 40),
    "reduced": ({}, 2, 8, 32, 20),
}


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="vit-edge")
    ap.add_argument("--profile", choices=tuple(PROFILES), default="engine",
                    help="engine: tiny per-step compute isolates dispatch/"
                         "copy overhead; reduced: stock reduced config "
                         "(compute-bound on CPU)")
    ap.add_argument("--clusters", type=int, default=None)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--steps", type=int, default=None,
                    help="HFSL steps per measured round")
    ap.add_argument("--sync-every", type=int, default=5)
    ap.add_argument("--iters", type=int, default=3)
    # benchmarks/run.py imports main() with argv=None -> defaults (it must
    # not see run.py's own CLI args); direct runs pass sys.argv[1:] below.
    args = ap.parse_args([] if argv is None else argv)

    shrink, n, batch, seq, K = PROFILES[args.profile]
    n = args.clusters or n
    batch = args.batch or batch
    seq = args.seq or seq
    K = args.steps or K

    cfg = get_config(args.arch).reduced().with_(dtype="float32", **shrink)
    if not cfg.peft.head_dim_out:
        cfg = cfg.with_(peft=dataclasses.replace(cfg.peft, head_dim_out=5))
    opt = adamw(5e-3)
    state0 = hfsl.init_hfsl_state(jax.random.PRNGKey(0), cfg, n, opt, M.init)

    task = ClassificationTask(cfg.peft.head_dim_out, cfg.vocab_size, seq,
                              seed=0)
    data = task.dataset(max(200, K * batch) * n, seed=1)
    parts = partition_by_classes(data["label"], n, cfg.peft.head_dim_out,
                                 seed=0)
    bank = BatchBank.pack(data, parts, batch, seed=0, steps=K)
    ex_per_round = K * n * batch

    def time_rounds(fn) -> float:
        jax.block_until_ready(fn())           # warmup: compile + first round
        t0 = time.time()
        for _ in range(args.iters):
            jax.block_until_ready(fn())
        return (time.time() - t0) / args.iters

    # legacy engine exactly as launch/train.py --impl loop runs it: host
    # batch assembly via the cluster iterator + one dispatch per step
    step_fn = jax.jit(hfsl.make_hfsl_step(cfg, opt, M.classify_loss,
                                          sync_every=args.sync_every))

    def run_loop():
        it = cluster_batches(data, parts, batch, seed=0)
        s = state0
        for _ in range(K):
            s, _ = step_fn(s, next(it))
        return s["adapters_c"]

    dt_loop = time_rounds(run_loop)
    emit("finetune_loop", dt_loop * 1e6,
         f"steps_s={K / dt_loop:.2f};ex_s={ex_per_round / dt_loop:.1f}")

    round_fn = hfsl.make_hfsl_round(cfg, opt, M.classify_loss, steps=K,
                                    sync_every=args.sync_every)

    def run_scan():
        s, _ = round_fn(state0, bank.arrays, 0)
        return s["adapters_c"]

    dt_scan = time_rounds(run_scan)
    emit("finetune_scan", dt_scan * 1e6,
         f"steps_s={K / dt_scan:.2f};ex_s={ex_per_round / dt_scan:.1f};"
         f"speedup_vs_loop={dt_loop / dt_scan:.2f}x")
    return {"loop_s": dt_loop, "scan_s": dt_scan,
            "speedup": dt_loop / dt_scan}


if __name__ == "__main__":
    import sys
    out = main(sys.argv[1:])
    print(f"# scan speedup vs loop: {out['speedup']:.2f}x")
