"""Kernel microbenchmarks (CPU wall-time of the XLA-blocked algorithms,
plus derived achieved-GFLOP/s; the Pallas kernels' target perf is assessed
structurally in the roofline, not by CPU timing)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.kernels import ops


def _time(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        fn(*args).block_until_ready()
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
        (out[0] if isinstance(out, tuple) else out).block_until_ready()
    return (time.time() - t0) / iters * 1e6


def main() -> dict:
    key = jax.random.PRNGKey(0)
    out = {}

    B, S, H, D = 2, 1024, 8, 64
    q = jax.random.normal(key, (B, S, H, D), jnp.float32)
    k = jax.random.normal(key, (B, S, H // 2, D), jnp.float32)
    v = jax.random.normal(key, (B, S, H // 2, D), jnp.float32)
    pos = jnp.arange(S)
    f = jax.jit(lambda q, k, v: ops.flash_attention(
        q, k, v, q_pos=pos, kv_pos=pos, backend="xla"))
    us = _time(f, q, k, v)
    flops = 4 * B * H * S * S * D
    emit("kernel_flash_attention_1k", us, f"GFLOPs={flops/us/1e3:.1f}")
    out["flash"] = us

    Di, N = 512, 16
    x = jax.random.normal(key, (B, S, Di)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(key, (B, S, Di))) * 0.1
    A = -jnp.exp(jax.random.normal(key, (Di, N)) * 0.3)
    Bm = jax.random.normal(key, (B, S, N))
    C = jax.random.normal(key, (B, S, N))
    Dp = jnp.ones((Di,))
    f = jax.jit(lambda *a: ops.selective_scan(*a, backend="xla"))
    us = _time(f, x, dt, A, Bm, C, Dp)
    emit("kernel_selective_scan_1k", us,
         f"Melem_per_s={B*S*Di*N/us:.0f}")
    out["sscan"] = us

    W = 512
    xg = jax.random.normal(key, (B, S, W))
    rg = jax.random.normal(key, (B, S, W))
    ig = jax.random.normal(key, (B, S, W))
    ap = jax.random.normal(key, (W,))
    f = jax.jit(lambda *a: ops.rglru(*a, backend="xla"))
    us = _time(f, xg, rg, ig, ap)
    emit("kernel_rglru_1k", us, f"Melem_per_s={B*S*W/us:.0f}")
    out["rglru"] = us

    M, K, Nn, r = 512, 1024, 1024, 8
    x2 = jax.random.normal(key, (M, K))
    w2 = jax.random.normal(key, (K, Nn)) * 0.02
    a2 = jax.random.normal(key, (K, r)) * 0.02
    b2 = jax.random.normal(key, (r, Nn)) * 0.02
    f = jax.jit(lambda *a: ops.lora_matmul(*a, scale=2.0, backend="xla"))
    us = _time(f, x2, w2, a2, b2)
    emit("kernel_lora_matmul", us, f"GFLOPs={2*M*K*Nn/us/1e3:.1f}")
    out["lora"] = us
    return out


if __name__ == "__main__":
    main()
