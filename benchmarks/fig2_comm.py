"""Fig 2 reproduction: parameter-full vs parameter-efficient inference
(model-distribution communication cost), for every assigned architecture.

Uses the declared ParamSpec trees (no initialization), so full-size models
are priced exactly.
"""
from __future__ import annotations

import time

from benchmarks.common import emit
from repro.configs.base import get_config
from repro.core.comm import CostModel
from repro.launch.dryrun import ASSIGNED
from repro.models.model import adapter_spec, backbone_spec
from repro.sharding.rules import param_bytes


def main() -> dict:
    cm = CostModel()
    out = {}
    t0 = time.time()
    for arch in ASSIGNED + ["vit-edge"]:
        cfg = get_config(arch)
        a = param_bytes(adapter_spec(cfg))
        b = param_bytes(backbone_spec(cfg))
        full_lat = cm.cs.latency(a + b)
        eff_lat = cm.cs.latency(a)
        out[arch] = (a, a + b, (a + b) / a)
        emit(f"fig2_comm_{arch}", (time.time() - t0) * 1e6,
             f"full_MB={(a+b)/1e6:.1f};efficient_MB={a/1e6:.2f};"
             f"reduction={(a+b)/a:.0f}x;full_s={full_lat:.1f};eff_s={eff_lat:.3f}")
    return out


if __name__ == "__main__":
    main()
