"""Roofline summary from dry-run artifacts (results/dryrun_*.json).

Not a paper table — this is deliverable (g): per (arch x shape) roofline
terms and bottleneck from the compiled 512-way SPMD modules.
"""
from __future__ import annotations

import json
import os

from benchmarks.common import emit

RESULTS = [
    ("single_pod", "results/dryrun_single_pod.json"),
    ("multi_pod", "results/dryrun_multi_pod.json"),
]


def main() -> dict:
    out = {}
    for tag, path in RESULTS:
        if not os.path.exists(path):
            emit(f"roofline_{tag}", 0.0, "missing (run launch/dryrun.py --all)")
            continue
        rows = json.load(open(path))
        for r in rows:
            if r.get("status") != "ok":
                emit(f"roofline_{tag}_{r['arch']}_{r['shape']}", 0.0,
                     r.get("status", "?"))
                continue
            rf = r["roofline"]
            emit(f"roofline_{tag}_{r['arch']}_{r['shape']}",
                 r.get("compile_s", 0) * 1e6,
                 f"bottleneck={rf['bottleneck']};compute_s={rf['compute_s']:.4f};"
                 f"memory_s={rf['memory_s']:.4f};collective_s={rf['collective_s']:.4f};"
                 f"useful={rf['useful_ratio']:.3f}")
            out[(tag, r["arch"], r["shape"])] = rf["bottleneck"]
    return out


if __name__ == "__main__":
    main()
