"""Mesh-sharded serving/training bench: throughput + per-device placement.

Runs the mesh-native hot paths (ISSUE 5) on a forced 4-host-device
(`data`, `model`) test mesh and reports, next to the unsharded baseline:

- ``shard_drain_tok_s_{unsharded,mesh}`` — mixed-domain ragged engine
  drain throughput (tokens/s; host-device meshes add collective overhead
  on CPU, so the mesh number is a *correctness+plumbing* figure — the
  speedup story needs real TPUs, see ROADMAP).
- ``shard_round_steps_s_{unsharded,mesh}`` — fused HFSL round steps/s.
- ``shard_devices_used`` / ``shard_bank_bytes_dev{i}`` — how many devices
  hold live shards of the AdapterBank + BatchBank and the per-device
  byte share (per-device utilization of the placement: equal shares =
  balanced slot/cluster parallelism).

The parent process may already own a single-device jax runtime (the
benchmarks/run.py driver), so the measurement runs in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=4``; pass ``--child``
to run the measurement directly.
"""
from __future__ import annotations

import os
import subprocess
import sys

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _child() -> None:
    import time

    import numpy as np
    import jax
    import jax.numpy as jnp

    from repro.configs.base import get_config
    from repro.core import hfsl
    from repro.core.adapter_bank import AdapterBank
    from repro.data.noniid import partition_by_classes
    from repro.data.pipeline import BatchBank
    from repro.data.synthetic import ClassificationTask
    from repro.launch.engine import DecodeEngine
    from repro.launch.mesh import make_test_mesh
    from repro.models import model as M
    from repro.optim.optimizers import adamw
    from repro.sharding import rules as R

    def emit(name, us, derived=""):
        print(f"{name},{us:.0f},{derived}")

    mesh = make_test_mesh(2, 2)
    cfg = get_config("vit-edge").reduced().with_(dtype="float32",
                                                 vocab_size=64)
    doms = [f"d{i}" for i in range(4)]
    ks = jax.random.split(jax.random.PRNGKey(0), len(doms) + 1)
    adapters = {d: M.init(cfg, ks[i])["adapters"]
                for i, d in enumerate(doms)}
    backbone = M.init(cfg, ks[-1])["backbone"]
    key = jax.random.PRNGKey(5)
    prompts = np.asarray(jax.random.randint(key, (16, 10), 0,
                                            cfg.vocab_size))
    row_doms = [doms[i % len(doms)] for i in range(len(prompts))]
    GEN = 8

    def drain(engine, bank, bb):
        t0 = time.time()
        out, stats = engine.serve(bank.serving_params(bb), prompts,
                                  gen=GEN, domains=row_doms)
        return out, stats, time.time() - t0

    # -- serving: unsharded baseline vs mesh drain (warm both jits first)
    bank_u = AdapterBank.create(adapters)
    eng_u = DecodeEngine(cfg, slots=8, bank=bank_u)
    drain(eng_u, bank_u, backbone)
    out_u, stats_u, dt_u = drain(eng_u, bank_u, backbone)
    emit("shard_drain_tok_s_unsharded", dt_u * 1e6,
         f"{stats_u.tokens / dt_u:.1f}")

    bank_s = AdapterBank.create(adapters, mesh=mesh)
    bb_s = M.place_params({"backbone": backbone}, cfg, mesh)["backbone"]
    eng_s = DecodeEngine(cfg, slots=8, bank=bank_s, mesh=mesh)
    drain(eng_s, bank_s, bb_s)
    out_s, stats_s, dt_s = drain(eng_s, bank_s, bb_s)
    np.testing.assert_array_equal(out_s, out_u)    # parity is the contract
    emit("shard_drain_tok_s_mesh", dt_s * 1e6,
         f"{stats_s.tokens / dt_s:.1f}")

    # -- training: fused round, unsharded vs mesh
    C, BATCH, STEPS = 4, 8, 8
    opt = adamw(5e-3)
    task = ClassificationTask(5, 64, 24, class_strength=0.6, seed=0)
    data = task.dataset(60 * C, seed=11)
    parts = partition_by_classes(data["label"], C, cfg.peft.head_dim_out,
                                 seed=1)
    state0 = hfsl.init_hfsl_state(jax.random.PRNGKey(3), cfg, C, opt,
                                  M.init)
    bank_ut = BatchBank.pack(data, parts, BATCH, seed=2)
    round_u = hfsl.make_hfsl_round(cfg, opt, M.classify_loss, steps=STEPS,
                                   sync_every=2)
    round_u(state0, bank_ut.arrays, 0)             # warm
    t0 = time.time()
    su, _ = round_u(state0, bank_ut.arrays, 0)
    jax.block_until_ready(su["adapters_c"])
    dt = time.time() - t0
    emit("shard_round_steps_s_unsharded", dt * 1e6, f"{STEPS / dt:.2f}")

    rules = R.hfsl_round_rules(cfg.family)
    spec = hfsl.hfsl_state_spec(cfg, C, opt, M.model_spec)
    sh = hfsl.hfsl_state_shardings(cfg, C, opt, M.model_spec, mesh, rules)
    state_s = jax.device_put(state0, sh)
    bank_st = BatchBank.pack(data, parts, BATCH, seed=2, mesh=mesh,
                             rules=rules)
    round_s = hfsl.make_hfsl_round(cfg, opt, M.classify_loss, steps=STEPS,
                                   sync_every=2, mesh=mesh, rules=rules,
                                   state_spec=spec)
    round_s(state_s, bank_st.arrays, 0)            # warm
    t0 = time.time()
    ss, ms = round_s(state_s, bank_st.arrays, 0)
    jax.block_until_ready(ss["adapters_c"])
    dt = time.time() - t0
    # parity is the contract here too: same per-step losses as unsharded
    _, mu = round_u(state0, bank_ut.arrays, 0)
    np.testing.assert_allclose(np.asarray(ms["loss"]),
                               np.asarray(mu["loss"]),
                               rtol=2e-5, atol=1e-6)
    emit("shard_round_steps_s_mesh", dt * 1e6, f"{STEPS / dt:.2f}")

    # -- per-device placement utilization: each device's resident share of
    # the banks' LOGICAL bytes (AdapterBank slots + BatchBank clusters).
    # Slot/cluster dims split over the 2-way `data` axis and replicate
    # over `model`, so balanced placement prints 0.500 per device; a bank
    # that silently degraded to fully replicated prints ~1.000 PER DEVICE
    # — placement regressions are visible in the numbers, not hidden by
    # physical-total normalization (and the specs are hard-asserted).
    assert jax.tree.leaves(bank_s.stacked["stack"])[0].sharding.spec \
        == R.P(None, "data")
    assert jax.tree.leaves(bank_st.arrays)[0].sharding.spec \
        == R.P(None, "data")
    per_dev = {d.id: 0 for d in jax.devices()}
    logical = 0
    for leaf in (jax.tree.leaves(bank_s.stacked)
                 + jax.tree.leaves(bank_st.arrays)):
        logical += leaf.nbytes
        for s in leaf.addressable_shards:
            per_dev[s.device.id] += s.data.nbytes
    used = sum(1 for v in per_dev.values() if v > 0)
    emit("shard_devices_used", 0, f"{used}/{len(per_dev)}")
    for i, v in sorted(per_dev.items()):
        emit(f"shard_bank_bytes_dev{i}", 0, f"{v / logical:.3f}")
    import contextlib
    for name, leaf in (
            ("bank_head", bank_s.stacked["head"]["w"]),
            ("batch_bank", jax.tree.leaves(bank_st.arrays)[0])):
        print(f"# {name} sharding: {leaf.sharding.spec}", file=sys.stderr)
        with contextlib.redirect_stdout(sys.stderr):   # keep CSV clean
            jax.debug.visualize_array_sharding(
                leaf.reshape(leaf.shape[0], -1)
                if name == "bank_head" else leaf[0, :, 0])


def main() -> None:
    if "--child" in sys.argv or os.environ.get("REPRO_SHARD_BENCH_CHILD"):
        _child()
        return
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               JAX_PLATFORMS=os.environ.get("JAX_PLATFORMS", "cpu"),
               REPRO_SHARD_BENCH_CHILD="1",
               PYTHONPATH="src" + (os.pathsep + os.environ["PYTHONPATH"]
                                   if os.environ.get("PYTHONPATH") else ""))
    r = subprocess.run([sys.executable, "-m", "benchmarks.shard_bench"],
                       cwd=ROOT, env=env, capture_output=True, text=True,
                       timeout=1800)
    sys.stdout.write(r.stdout)
    if r.returncode != 0:
        sys.stderr.write(r.stderr[-4000:])
        raise RuntimeError("shard_bench child failed")


if __name__ == "__main__":
    main()
