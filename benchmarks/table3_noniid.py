"""Table III reproduction: effect of #data-classes per client (Non-IID).

Paper: accuracy degrades monotonically as clients hold fewer classes
(1 class: 0.200 -> 5 classes: 0.933/0.967).
"""
from __future__ import annotations

import time

from benchmarks.common import (N_CLASSES, edge_cfg, emit, hfsl_finetune,
                               make_task, pretrain)


def main() -> dict:
    cfg = edge_cfg()
    task = make_task(cfg)
    params, _ = pretrain(cfg, task)
    out = {}
    for k in range(1, N_CLASSES + 1):
        t0 = time.time()
        accs, _, _ = hfsl_finetune(params, cfg, task,
                                   classes_per_client=k)
        out[k] = (accs[0], accs[-1])
        emit(f"table3_classes_{k}", (time.time() - t0) * 1e6,
             f"first={accs[0]:.3f};end={accs[-1]:.3f}")
    mono = out[N_CLASSES][1] > out[1][1]
    emit("table3_noniid_degrades", 0.0, f"claim_holds={mono}")
    return out


if __name__ == "__main__":
    main()
