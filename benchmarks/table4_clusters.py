"""Table IV reproduction: effect of the number of client clusters.

Paper: accuracy improves with more clusters (more personalized data), with
diminishing returns (1: 0.950 -> 6: 0.975).
"""
from __future__ import annotations

import time

from benchmarks.common import edge_cfg, emit, hfsl_finetune, make_task, pretrain


def main() -> dict:
    cfg = edge_cfg()
    task = make_task(cfg)
    params, _ = pretrain(cfg, task)
    out = {}
    for n in (1, 2, 4, 6):
        t0 = time.time()
        accs, _, _ = hfsl_finetune(params, cfg, task, n_clusters=n,
                                   n_train=150 * n)
        out[n] = (accs[0], accs[-1])
        emit(f"table4_clusters_{n}", (time.time() - t0) * 1e6,
             f"first={accs[0]:.3f};end={accs[-1]:.3f}")
    emit("table4_more_clusters_help", 0.0,
         f"claim_holds={out[6][1] >= out[1][1]}")
    return out


if __name__ == "__main__":
    main()
